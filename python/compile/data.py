"""Synthetic corpus generator (build-time side).

Mirrored line-for-line by ``rust/src/data/corpus.rs``; golden tokens are
embedded in the AOT manifest so the rust test-suite can verify parity.

The corpus is a seeded stochastic process over a 256-token alphabet
mixing four mechanisms (DESIGN.md §4):

* **Zipf unigrams** — heavy-tailed marginal distribution (integer CDF).
* **Order-1 Markov structure** — each token has 4 preferred successors
  derived from a stateless hash; taken with probability 0.65.
* **Copy motifs** — with probability 0.04 the process replays the 8
  tokens seen 16 positions ago, rewarding models that use context.
* **Super-token chains** — rare tokens >= 248 deterministically chain
  (p=0.9) to a hashed successor, a stand-in for the rare-but-critical
  "super weight / activation outlier" structure in real LLMs.

Everything is 64-bit integer arithmetic via SplitMix64 so python and rust
produce bit-identical streams.
"""

from __future__ import annotations

from typing import List

import numpy as np

from .prng import SplitMix64, mix64

VOCAB = 256

P_COPY = 0.04
P_MARKOV = 0.65
P_SUPER = 0.90
COPY_BACK = 16
COPY_LEN = 8
SUPER_MIN_TOKEN = 248
N_SUCCESSORS = 4

SUCC_SALT = 0xC0FFEE
SUPER_SALT = 0x5EEDBEEF

ZIPF_SCALE = 1 << 20


def zipf_cdf(vocab: int = VOCAB) -> List[int]:
    """Integer cumulative weights, w_i = ZIPF_SCALE // (i + 4)."""
    cdf, acc = [], 0
    for i in range(vocab):
        acc += ZIPF_SCALE // (i + 4)
        cdf.append(acc)
    return cdf


_ZIPF_CDF = zipf_cdf()
_ZIPF_TOTAL = _ZIPF_CDF[-1]


def _zipf_sample(rng: SplitMix64) -> int:
    u = rng.next_below(_ZIPF_TOTAL)
    # binary search for first cdf entry > u
    lo, hi = 0, VOCAB - 1
    while lo < hi:
        mid = (lo + hi) // 2
        if _ZIPF_CDF[mid] > u:
            hi = mid
        else:
            lo = mid + 1
    return lo


def successor(prev: int, slot: int) -> int:
    """slot-th preferred successor of token ``prev``."""
    return mix64(prev * N_SUCCESSORS + slot + SUCC_SALT) % VOCAB


def super_successor(prev: int) -> int:
    return mix64(prev + SUPER_SALT) % VOCAB


def generate(seed: int, n_tokens: int) -> np.ndarray:
    """Generate ``n_tokens`` corpus tokens for ``seed`` (uint8 array)."""
    rng = SplitMix64(seed)
    out: List[int] = []
    copy_remaining = 0
    while len(out) < n_tokens:
        if copy_remaining > 0:
            t = out[len(out) - COPY_BACK]
            copy_remaining -= 1
        else:
            r = rng.next_f64()
            n = len(out)
            if n > 0 and out[n - 1] >= SUPER_MIN_TOKEN and r < P_SUPER:
                t = super_successor(out[n - 1])
            elif n >= COPY_BACK + COPY_LEN and r < P_COPY:
                copy_remaining = COPY_LEN - 1
                t = out[n - COPY_BACK]
            elif n > 0 and r < P_COPY + P_MARKOV:
                slot = rng.next_below(N_SUCCESSORS)
                t = successor(out[n - 1], slot)
            else:
                t = _zipf_sample(rng)
        out.append(t)
    return np.asarray(out, dtype=np.uint8)


def write_bin(path: str, tokens: np.ndarray) -> None:
    assert tokens.dtype == np.uint8
    tokens.tofile(path)


def golden_tokens(seed: int, n: int = 64) -> List[int]:
    """First ``n`` tokens for a seed — embedded in the manifest for the
    rust parity test."""
    return [int(t) for t in generate(seed, n)]
