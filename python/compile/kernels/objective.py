"""Pallas kernel for the layerwise pruning objective.

Reference semantics (``ref.objective_ref``):

    L(M) = ‖WX − (M⊙W)X‖_F² = Σ_ij [(Z G) ⊙ Z]_ij,   Z = W ⊙ (1 − M)

The kernel fuses the Z·G tile contraction with the Hadamard-and-reduce
epilogue, accumulating the scalar across the whole grid in a single
(1, 1) output block (its index map is constant, so it stays VMEM-resident
for the entire launch — on TPU this is the canonical scalar-reduction
pattern; grid steps execute sequentially per core).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fw_grad import default_blocks


def _objective_kernel(w_ik_ref, m_ik_ref, g_kj_ref, w_ij_ref, m_ij_ref, o_ref, acc_ref, *, nk: int):
    """Grid = (d_out/bm, d_in/bn, d_in/bk).

    acc_ref is a (bm, bn) accumulator output holding the running Z·G tile
    (re-used across k); o_ref is the (1, 1) scalar accumulator.
    """
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (k == 0))
    def _init_scalar():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(k == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    z_ik = w_ik_ref[...] * (1.0 - m_ik_ref[...])
    acc_ref[...] += jnp.dot(z_ik, g_kj_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        z_ij = w_ij_ref[...] * (1.0 - m_ij_ref[...])
        o_ref[...] += jnp.sum(acc_ref[...] * z_ij)


def objective(
    w: jnp.ndarray,
    m: jnp.ndarray,
    g: jnp.ndarray,
    *,
    blocks: Tuple[int, int, int] | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """L(M) = ‖WX − (M⊙W)X‖_F² from precomputed G = XXᵀ; returns (1,1)."""
    d_out, d_in = w.shape
    assert m.shape == (d_out, d_in) and g.shape == (d_in, d_in)
    bm, bn, bk = blocks or default_blocks(d_out, d_in)
    assert d_out % bm == 0 and d_in % bn == 0 and d_in % bk == 0
    nk = d_in // bk
    grid = (d_out // bm, d_in // bn, nk)

    out, _ = pl.pallas_call(
        functools.partial(_objective_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # W (reduction view)
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # M
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # G
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # W (epilogue view)
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # M
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),  # ZG workspace
        ],
        interpret=interpret,
    )(w, m, g, w, m)
    return out
