"""Pallas kernel for streaming gram-matrix accumulation (calibration).

Reference semantics (``ref.gram_acc_ref``):

    G ← G + X Xᵀ

with X a (d_in, B) calibration chunk.  The coordinator streams batches of
activations through this kernel; G's (d_in, d_in) footprint is what makes
SparseFW independent of the calibration sequence length (paper §2.3).

Tiling: grid (d_in/bm, d_in/bn, B/bk); the X·Xᵀ contraction reads X twice
under two index maps (rows i and rows j), accumulating into the
VMEM-resident output tile, with the running G tile added at k == 0.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fw_grad import pick_block


def gram_blocks(d_in: int, batch: int) -> Tuple[int, int, int]:
    bm = pick_block(d_in, 128)
    bn = pick_block(d_in, 128)
    bk = pick_block(batch, 256)
    return bm, bn, bk


def _gram_kernel(g_ref, x_ik_ref, x_jk_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = g_ref[...]

    o_ref[...] += jnp.dot(
        x_ik_ref[...], x_jk_ref[...].T, preferred_element_type=jnp.float32
    )


def gram_acc(
    g: jnp.ndarray,
    x: jnp.ndarray,
    *,
    blocks: Tuple[int, int, int] | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Return G + X Xᵀ (X is (d_in, B))."""
    d_in, batch = x.shape
    assert g.shape == (d_in, d_in)
    bm, bn, bk = blocks or gram_blocks(d_in, batch)
    assert d_in % bm == 0 and d_in % bn == 0 and batch % bk == 0
    nk = batch // bk
    grid = (d_in // bm, d_in // bn, nk)

    return pl.pallas_call(
        functools.partial(_gram_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # running G
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # X rows i
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),  # X rows j
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_in, d_in), jnp.float32),
        interpret=interpret,
    )(g, x, x)
