"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: pytest (+ hypothesis shape sweeps)
asserts kernel == oracle to float tolerance.  They are also what the
kernels' docstrings mean by "the reference semantics".

Notation follows the paper (Section 2.3): W is the layer weight
(d_out × d_in), M the (relaxed) mask, X the calibration input
(d_in × B), G = X Xᵀ the gram matrix and H = W G.
"""

from __future__ import annotations

import jax.numpy as jnp


def fw_grad_ref(w: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """∇L(M) = −2 · W ⊙ (H − (W ⊙ M) G)   (Algorithm 1, line 3)."""
    return -2.0 * w * (h - (w * m) @ g)


def objective_ref(w: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """L(M) = ‖WX − (M⊙W)X‖_F² expressed through G:

    L(M) = Tr(Z G Zᵀ) with Z = W ⊙ (1 − M) = Σ_ij [(Z G) ⊙ Z]_ij.
    """
    z = w * (1.0 - m)
    return jnp.sum((z @ g) * z)


def gram_ref(x: jnp.ndarray) -> jnp.ndarray:
    """G = X Xᵀ for a calibration chunk X (d_in × B)."""
    return x @ x.T


def gram_acc_ref(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Streaming accumulation G ← G + X Xᵀ (batched calibration)."""
    return g + x @ x.T


def pruning_error_ref(w: jnp.ndarray, m: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Direct (X-space) evaluation of the objective, used to validate the
    G-space formulation: ‖WX − (M⊙W)X‖_F²."""
    return jnp.sum((w @ x - (m * w) @ x) ** 2)
