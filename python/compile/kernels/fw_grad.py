"""Pallas kernel for the SparseFW gradient — the per-iteration hot-spot.

Reference semantics (``ref.fw_grad_ref``):

    ∇L(M) = −2 · W ⊙ (H − (W ⊙ M) G)

with W, M, H of shape (d_out, d_in) and G of shape (d_in, d_in).

TPU-oriented design (DESIGN.md §6): the (W⊙M)·G contraction is tiled into
(bm, bk) × (bk, bn) MXU-shaped blocks; the two Hadamard products and the
subtraction are *fused into the epilogue* of the matmul so the W(i,j) and
H(i,j) tiles are streamed exactly once per output tile.  The accumulator
lives in the output block, which is VMEM-resident across the k reduction
steps because its index map is constant in k — the Pallas equivalent of a
threadblock-register accumulator in the paper's CUDA baselines.

The kernel is lowered with ``interpret=True`` everywhere in this repo:
the CPU PJRT plugin cannot execute Mosaic custom-calls, so interpret-mode
lowering (plain HLO ops) is the correctness- and interchange-path; the
MXU/VMEM structure is what a real TPU lowering would use (§Perf records
the per-shape VMEM footprints).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def pick_block(dim: int, target: int) -> int:
    """Largest power-of-two tile <= target that divides ``dim``."""
    b = 1
    while b * 2 <= min(dim, target) and dim % (b * 2) == 0:
        b *= 2
    return b


def default_blocks(d_out: int, d_in: int) -> Tuple[int, int, int]:
    """(bm, bn, bk) aiming at 128-multiples (full MXU tiles) where the
    layer shape allows, under a 16 MiB VMEM budget with double-buffering
    headroom (see ``vmem_bytes``)."""
    bm = pick_block(d_out, 128)
    bn = pick_block(d_in, 128)
    bk = pick_block(d_in, 128)
    return bm, bn, bk


def _fw_grad_kernel(w_ik_ref, m_ik_ref, g_kj_ref, w_ij_ref, h_ij_ref, o_ref, *, nk: int):
    """Grid = (d_out/bm, d_in/bn, d_in/bk); axis 2 is the reduction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction of the masked-weight tile with the gram tile.
    wm = w_ik_ref[...] * m_ik_ref[...]
    o_ref[...] += jnp.dot(wm, g_kj_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = -2.0 * w_ij_ref[...] * (h_ij_ref[...] - o_ref[...])


def fw_grad(
    w: jnp.ndarray,
    m: jnp.ndarray,
    g: jnp.ndarray,
    h: jnp.ndarray,
    *,
    blocks: Tuple[int, int, int] | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Compute ∇L(M) = −2·W⊙(H − (W⊙M)G) with a fused Pallas kernel."""
    d_out, d_in = w.shape
    assert m.shape == (d_out, d_in) and h.shape == (d_out, d_in)
    assert g.shape == (d_in, d_in)
    bm, bn, bk = blocks or default_blocks(d_out, d_in)
    assert d_out % bm == 0 and d_in % bn == 0 and d_in % bk == 0, (
        f"blocks {(bm, bn, bk)} must divide shape {(d_out, d_in)}"
    )
    nk = d_in // bk
    grid = (d_out // bm, d_in // bn, nk)

    return pl.pallas_call(
        functools.partial(_fw_grad_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # W  (reduction view)
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # M
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # G
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # W  (epilogue view)
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # H
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), jnp.float32),
        interpret=interpret,
    )(w, m, g, w, h)


def vmem_bytes(d_out: int, d_in: int, blocks: Tuple[int, int, int] | None = None) -> int:
    """Bytes resident in VMEM per grid step (double-buffered inputs), for
    the §Perf roofline estimate: 2×(W_ik + M_ik + G_kj input tiles)
    + W_ij + H_ij + output accumulator."""
    bm, bn, bk = blocks or default_blocks(d_out, d_in)
    words = 2 * (2 * bm * bk + bk * bn) + 2 * bm * bn + bm * bn
    return 4 * words


def flops(d_out: int, d_in: int) -> int:
    """MXU FLOPs of one gradient evaluation (the matmul dominates)."""
    return 2 * d_out * d_in * d_in
