"""Minimal safetensors-format checkpoint writer/reader.

Format (https://github.com/huggingface/safetensors):
  [8-byte little-endian header length N][N bytes JSON header][raw data]
Header maps tensor name → {"dtype", "shape", "data_offsets": [begin, end]}
with offsets relative to the start of the data section.  Only f32 is
needed here.  The rust counterpart is ``rust/src/model/safetensors.rs``.
"""

from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

_DTYPES = {"F32": np.float32}


def save(path: str, tensors: Dict[str, np.ndarray]) -> None:
    header: Dict[str, dict] = {}
    offset = 0
    blobs = []
    for name in sorted(tensors):
        arr = np.ascontiguousarray(tensors[name], dtype=np.float32)
        blob = arr.tobytes()
        header[name] = {
            "dtype": "F32",
            "shape": list(arr.shape),
            "data_offsets": [offset, offset + len(blob)],
        }
        offset += len(blob)
        blobs.append(blob)
    hjson = json.dumps(header, sort_keys=True).encode("utf-8")
    # pad header to 8-byte alignment (spec recommendation)
    pad = (-len(hjson)) % 8
    hjson += b" " * pad
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hjson)))
        f.write(hjson)
        for blob in blobs:
            f.write(blob)


def load(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        data = f.read()
    out: Dict[str, np.ndarray] = {}
    for name, meta in header.items():
        if name == "__metadata__":
            continue
        dt = _DTYPES[meta["dtype"]]
        b, e = meta["data_offsets"]
        out[name] = np.frombuffer(data[b:e], dtype=dt).reshape(meta["shape"]).copy()
    return out
