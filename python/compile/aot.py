"""AOT driver: python runs ONCE, here, and never again at runtime.

``python -m compile.aot`` produces everything the rust coordinator needs:

  artifacts/
    manifest.json                 — index of all of the below
    train.bin / val.bin / test.bin — synthetic corpus token bins (u8)
    <model>.safetensors           — build-time-pretrained checkpoints
    model_fwd_<model>.hlo.txt     — (tokens, *params) → logits
    fw_grad_<dout>x<din>.hlo.txt  — Algorithm 1 line 3 (Pallas)
    objective_<dout>x<din>.hlo.txt— pruning error L(M) (Pallas)
    gram_<din>x<B>.hlo.txt        — G ← G + XXᵀ chunk (Pallas)
    fw_chunk_<dout>x<din>_c<C>.hlo.txt — fused C-iteration FW (perf path)

Interchange format is HLO **text**: the image's xla_extension 0.5.1
rejects jax≥0.5 serialized HloModuleProtos (64-bit instruction ids); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import checkpoint, configs, data, fw_step, model, train
from .kernels.fw_grad import default_blocks, vmem_bytes

FW_CHUNK_ITERS = 20


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (NOT .serialize())."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_write(fn, example_args, path: str) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------


def gen_corpus(out: str, manifest: Dict, force: bool) -> None:
    sizes = {
        "train": configs.TRAIN_TOKENS,
        "val": configs.VAL_TOKENS,
        "test": configs.TEST_TOKENS,
    }
    entry = {}
    for split, n in sizes.items():
        path = os.path.join(out, f"{split}.bin")
        if force or not os.path.exists(path) or os.path.getsize(path) != n:
            t0 = time.time()
            toks = data.generate(configs.CORPUS_SEEDS[split], n)
            data.write_bin(path, toks)
            print(f"[data] wrote {split}.bin ({n} tokens, {time.time()-t0:.1f}s)")
        entry[split] = f"{split}.bin"
    entry.update(
        vocab=configs.VOCAB_SIZE,
        seq_len=configs.SEQ_LEN,
        seeds=configs.CORPUS_SEEDS,
        sizes=sizes,
    )
    manifest["data"] = entry
    # golden tokens for the rust corpus-parity test
    manifest["golden"] = {
        "corpus": {
            str(seed): data.golden_tokens(seed, 64)
            for seed in (1, 42, configs.CORPUS_SEEDS["train"])
        }
    }


def train_models(out: str, names: List[str], manifest: Dict, force: bool, fast: bool) -> Dict:
    corpus = np.fromfile(os.path.join(out, "train.bin"), dtype=np.uint8)
    test_tokens = np.fromfile(os.path.join(out, "test.bin"), dtype=np.uint8)
    params_by_model = {}
    manifest.setdefault("models", {})
    for name in names:
        cfg = configs.get_config(name)
        if fast:
            cfg = configs.dataclasses.replace(cfg, train_steps=60, warmup_steps=10)
        ckpt_path = os.path.join(out, f"{name}.safetensors")
        meta_path = os.path.join(out, f"{name}.train.json")
        if not force and os.path.exists(ckpt_path) and os.path.exists(meta_path):
            print(f"[train] reusing cached checkpoint {ckpt_path}")
            arrs = checkpoint.load(ckpt_path)
            params = {k: jnp.asarray(v) for k, v in arrs.items()}
            log = json.load(open(meta_path))
        else:
            params, log = train.train(cfg, corpus)
            ppl = train.eval_perplexity(params, cfg, test_tokens)
            log["dense_test_ppl"] = round(ppl, 4)
            checkpoint.save(ckpt_path, {k: np.asarray(v) for k, v in params.items()})
            json.dump(log, open(meta_path, "w"), indent=1)
            print(f"[train] {name}: dense test ppl = {ppl:.3f}")
        params_by_model[name] = params
        manifest["models"][name] = {
            "config": cfg.to_dict(),
            "checkpoint": f"{name}.safetensors",
            "param_order": cfg.param_names(),
            "param_shapes": {k: list(np.asarray(v).shape) for k, v in params.items()},
            "layers": [
                {"name": n, "family": fam, "d_out": do, "d_in": di}
                for (n, fam, do, di) in cfg.layer_shapes()
            ],
            "dense_test_ppl": log.get("dense_test_ppl"),
            "train_log": {k: log[k] for k in ("final_loss", "wall_seconds") if k in log},
        }
    return params_by_model


def lower_model_fwd(out: str, names: List[str], manifest: Dict) -> None:
    for name in names:
        cfg = configs.get_config(name)
        path = os.path.join(out, f"model_fwd_{name}.hlo.txt")
        tok_spec = spec((configs.EVAL_BATCH, cfg.seq_len), jnp.int32)
        param_specs = []
        shapes = manifest["models"][name]["param_shapes"]
        for pname in cfg.param_names():
            param_specs.append(spec(tuple(shapes[pname])))
        n = lower_and_write(model.fwd_for_aot(cfg), [tok_spec] + param_specs, path)
        manifest["models"][name]["fwd_hlo"] = os.path.basename(path)
        manifest["models"][name]["eval_batch"] = configs.EVAL_BATCH
        print(f"[aot] model_fwd_{name}: {n} chars")


def lower_kernels(out: str, names: List[str], manifest: Dict) -> None:
    shapes = []
    dins = set()
    seen = set()
    for name in names:
        cfg = configs.get_config(name)
        for dout, din in cfg.distinct_prune_shapes():
            if (dout, din) not in seen:
                seen.add((dout, din))
                shapes.append((dout, din))
            dins.add(din)

    kman = manifest.setdefault("kernels", {})
    fw, obj, chunk = {}, {}, {}
    for dout, din in shapes:
        key = f"{dout}x{din}"
        w, m, h = spec((dout, din)), spec((dout, din)), spec((dout, din))
        g = spec((din, din))
        p = os.path.join(out, f"fw_grad_{key}.hlo.txt")
        lower_and_write(fw_step.fw_grad_fn, [w, m, g, h], p)
        fw[key] = os.path.basename(p)
        p = os.path.join(out, f"objective_{key}.hlo.txt")
        lower_and_write(fw_step.objective_fn, [w, m, g], p)
        obj[key] = os.path.basename(p)
        p = os.path.join(out, f"fw_chunk_{key}_c{FW_CHUNK_ITERS}.hlo.txt")
        fixed = spec((dout, din))
        k_new = spec((), jnp.float32)
        t0 = spec((), jnp.float32)
        lower_and_write(
            fw_step.make_fw_chunk(FW_CHUNK_ITERS), [w, m, g, h, fixed, k_new, t0], p
        )
        chunk[key] = os.path.basename(p)
        print(f"[aot] kernels {key} done")
    kman["fw_grad"] = fw
    kman["objective"] = obj
    kman["fw_chunk"] = {"iters": FW_CHUNK_ITERS, "paths": chunk}

    grams = {}
    for din in sorted(dins):
        key = f"{din}x{configs.GRAM_CHUNK}"
        p = os.path.join(out, f"gram_{key}.hlo.txt")
        g, x = spec((din, din)), spec((din, configs.GRAM_CHUNK))
        lower_and_write(fw_step.gram_fn, [g, x], p)
        grams[str(din)] = os.path.basename(p)
    kman["gram"] = {"chunk": configs.GRAM_CHUNK, "paths": grams}

    # §Perf metadata: per-shape tile choices + VMEM footprint estimates
    kman["tiling"] = {
        f"{dout}x{din}": {
            "blocks": list(default_blocks(dout, din)),
            "vmem_bytes": vmem_bytes(dout, din),
        }
        for dout, din in shapes
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--models", nargs="*", default=list(configs.MODEL_CONFIGS))
    ap.add_argument("--force", action="store_true", help="retrain + regenerate everything")
    ap.add_argument("--fast", action="store_true", help="tiny training budget (CI smoke)")
    args = ap.parse_args(argv)

    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()
    manifest: Dict = {"version": 1, "fast": bool(args.fast)}

    gen_corpus(out, manifest, args.force)
    train_models(out, args.models, manifest, args.force, args.fast)
    lower_model_fwd(out, args.models, manifest)
    lower_kernels(out, args.models, manifest)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] manifest written; total {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
