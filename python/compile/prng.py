"""SplitMix64 PRNG — bit-identical counterpart of ``rust/src/util/prng.rs``.

The synthetic corpus (data.py) must be reproducible from the rust side for
tests and for regenerating evaluation workloads without python.  Both
implementations are pure 64-bit integer arithmetic, cross-checked by the
golden values embedded in ``artifacts/manifest.json``.
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Sebastiano Vigna's splitmix64; also used to seed Xoshiro on the rust
    side."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_below(self, bound: int) -> int:
        """Unbiased-enough modulo draw in [0, bound); bound must be > 0.

        We deliberately use plain modulo (not rejection sampling) so the
        rust implementation is a line-for-line mirror.
        """
        assert bound > 0
        return self.next_u64() % bound

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of entropy."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def mix64(x: int) -> int:
    """Stateless splitmix-style mixer for derived streams (hash of a key)."""
    z = (x + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return (z ^ (z >> 31)) & MASK64
