"""Model / data / AOT configuration shared between the python compile path
and the rust runtime.

The single source of truth is this module; ``aot.py`` serializes the
resolved configuration into ``artifacts/manifest.json`` which the rust
coordinator reads.  Keep field names in sync with
``rust/src/config/mod.rs``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# ---------------------------------------------------------------------------
# Data / corpus
# ---------------------------------------------------------------------------

VOCAB_SIZE = 256
SEQ_LEN = 128

#: tokens in the training bin (sequences are sampled at random offsets)
TRAIN_TOKENS = 2_000_000
#: tokens in the validation bin
VAL_TOKENS = 64 * SEQ_LEN
#: tokens in the held-out test bin (the "WikiText" stand-in, see DESIGN.md §3)
TEST_TOKENS = 128 * SEQ_LEN

#: corpus generator seeds per split (SplitMix64 streams, see data.py)
CORPUS_SEEDS = {"train": 0x5EED_0001, "val": 0x5EED_0002, "test": 0x5EED_0003}

#: batch size (sequences) baked into the AOT model-forward artifact
EVAL_BATCH = 8

#: calibration gram chunk size (columns of X per gram-kernel launch)
GRAM_CHUNK = 1024


# ---------------------------------------------------------------------------
# Model configurations
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture of one mini-GPT pruning target.

    Linear layer families mirror the paper's pruned matrices: ``attn_qkv``,
    ``attn_out``, ``mlp_up``, ``mlp_down``.  Embeddings and the (tied) LM
    head stay dense, following Sun et al. (2023) / the paper's protocol.
    """

    name: str
    vocab_size: int = VOCAB_SIZE
    seq_len: int = SEQ_LEN
    d_model: int = 64
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 256
    # training hyper-parameters (build-time only)
    train_steps: int = 1200
    batch_size: int = 16
    lr: float = 1e-3
    warmup_steps: int = 100
    weight_decay: float = 0.01
    seed: int = 17

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def layer_shapes(self) -> List[Tuple[str, str, int, int]]:
        """(param_name, family, d_out, d_in) for every pruned linear."""
        out = []
        for i in range(self.n_layers):
            p = f"blocks.{i}."
            out.append((p + "wqkv", "attn_qkv", 3 * self.d_model, self.d_model))
            out.append((p + "wo", "attn_out", self.d_model, self.d_model))
            out.append((p + "wup", "mlp_up", self.d_ff, self.d_model))
            out.append((p + "wdown", "mlp_down", self.d_model, self.d_ff))
        return out

    def distinct_prune_shapes(self) -> List[Tuple[int, int]]:
        seen, out = set(), []
        for _, _, dout, din in self.layer_shapes():
            if (dout, din) not in seen:
                seen.add((dout, din))
                out.append((dout, din))
        return out

    def param_names(self) -> List[str]:
        """Deterministic parameter order used for the flattened AOT
        signature of the model-forward executable (and the safetensors
        checkpoint)."""
        names = ["tok_emb", "pos_emb"]
        for i in range(self.n_layers):
            p = f"blocks.{i}."
            names += [
                p + "ln1_g",
                p + "ln1_b",
                p + "wqkv",
                p + "wo",
                p + "ln2_g",
                p + "ln2_b",
                p + "wup",
                p + "wdown",
            ]
        names += ["lnf_g", "lnf_b"]
        return names

    def n_params(self) -> int:
        d, v, f, L = self.d_model, self.vocab_size, self.d_ff, self.n_layers
        per_block = 4 * d + 3 * d * d + d * d + 2 * d * f
        return v * d + self.seq_len * d + L * per_block + 2 * d

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


MODEL_CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(
        name="tiny",
        d_model=64,
        n_layers=4,
        n_heads=4,
        d_ff=256,
        train_steps=1200,
        batch_size=16,
        seed=17,
    ),
    "small": ModelConfig(
        name="small",
        d_model=128,
        n_layers=4,
        n_heads=4,
        d_ff=512,
        train_steps=1400,
        batch_size=16,
        lr=8e-4,
        seed=23,
    ),
}


def get_config(name: str) -> ModelConfig:
    try:
        return MODEL_CONFIGS[name]
    except KeyError:
        raise KeyError(
            f"unknown model config {name!r}; available: {sorted(MODEL_CONFIGS)}"
        ) from None
