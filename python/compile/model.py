"""Layer-2 JAX model: a mini-GPT pruning target.

Pre-LN transformer with learned positional embeddings, GELU MLP and a
weight-tied LM head.  The four pruned linear families (``attn_qkv``,
``attn_out``, ``mlp_up``, ``mlp_down``) are stored as (d_out, d_in)
matrices applied as ``x @ W.T`` — the same layout the rust coordinator
and the safetensors checkpoints use.

Params are a *flat* dict keyed by the names in
``configs.ModelConfig.param_names()`` so the AOT signature, the
checkpoint and the rust loader all agree on ordering.

The FW hot-spot lives in ``fw_step.py`` (which calls the Pallas kernels);
the model here is the substrate that produces calibration activations and
evaluation logits.  Its forward is lowered to ``model_fwd_<cfg>.hlo.txt``
and executed from rust via PJRT — python never runs at eval time.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig

Params = Dict[str, jnp.ndarray]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """GPT-2-style init: N(0, 0.02) embeddings/projections, residual
    projections scaled by 1/sqrt(2·n_layers), LN at identity."""
    d, v, f, L = cfg.d_model, cfg.vocab_size, cfg.d_ff, cfg.n_layers
    std = 0.02
    resid_std = std / np.sqrt(2.0 * L)
    keys = jax.random.split(key, 2 + 4 * L)
    params: Params = {
        "tok_emb": std * jax.random.normal(keys[0], (v, d)),
        "pos_emb": std * jax.random.normal(keys[1], (cfg.seq_len, d)),
        "lnf_g": jnp.ones((d,)),
        "lnf_b": jnp.zeros((d,)),
    }
    for i in range(L):
        p = f"blocks.{i}."
        k = keys[2 + 4 * i : 6 + 4 * i]
        params[p + "ln1_g"] = jnp.ones((d,))
        params[p + "ln1_b"] = jnp.zeros((d,))
        params[p + "wqkv"] = std * jax.random.normal(k[0], (3 * d, d))
        params[p + "wo"] = resid_std * jax.random.normal(k[1], (d, d))
        params[p + "ln2_g"] = jnp.ones((d,))
        params[p + "ln2_b"] = jnp.zeros((d,))
        params[p + "wup"] = std * jax.random.normal(k[2], (f, d))
        params[p + "wdown"] = resid_std * jax.random.normal(k[3], (d, f))
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (matches the rust implementation)."""
    c = np.sqrt(2.0 / np.pi).astype(np.float32)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _attention(h: jnp.ndarray, wqkv: jnp.ndarray, wo: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, L, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    qkv = h @ wqkv.T  # (B, L, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(hd).astype(np.float32)
    causal = jnp.tril(jnp.ones((L, L), dtype=bool))
    att = jnp.where(causal[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(B, L, d)
    return out @ wo.T


def forward(
    params: Params, tokens: jnp.ndarray, cfg: ModelConfig, collect_inputs: bool = False
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Forward pass.

    Returns ``(logits, layer_inputs)``; ``layer_inputs`` maps pruned-layer
    param names to their linear-layer input activations of shape
    (B, L, d_in) when ``collect_inputs`` — this is the calibration-capture
    path (X matrices for G = XXᵀ).
    """
    B, L = tokens.shape
    captured: Dict[str, jnp.ndarray] = {}
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :L]
    for i in range(cfg.n_layers):
        p = f"blocks.{i}."
        h = _layernorm(x, params[p + "ln1_g"], params[p + "ln1_b"])
        if collect_inputs:
            captured[p + "wqkv"] = h
        nh, hd, d = cfg.n_heads, cfg.head_dim, cfg.d_model
        qkv = h @ params[p + "wqkv"].T
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
        k = k.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
        v = v.reshape(B, L, nh, hd).transpose(0, 2, 1, 3)
        att = (q @ k.transpose(0, 1, 3, 2)) / np.sqrt(hd).astype(np.float32)
        causal = jnp.tril(jnp.ones((L, L), dtype=bool))
        att = jnp.where(causal[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        attn_h = (att @ v).transpose(0, 2, 1, 3).reshape(B, L, d)
        if collect_inputs:
            captured[p + "wo"] = attn_h
        x = x + attn_h @ params[p + "wo"].T
        h2 = _layernorm(x, params[p + "ln2_g"], params[p + "ln2_b"])
        if collect_inputs:
            captured[p + "wup"] = h2
        up = _gelu(h2 @ params[p + "wup"].T)
        if collect_inputs:
            captured[p + "wdown"] = up
        x = x + up @ params[p + "wdown"].T
    x = _layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T  # tied head
    return logits, captured


def loss_fn(params: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Next-token cross-entropy (mean over B×(L−1) positions)."""
    logits, _ = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def flat_params(params: Params, cfg: ModelConfig) -> List[jnp.ndarray]:
    """Params in the canonical AOT/checkpoint order."""
    return [params[n] for n in cfg.param_names()]


def unflatten_params(arrays: List[jnp.ndarray], cfg: ModelConfig) -> Params:
    names = cfg.param_names()
    assert len(arrays) == len(names)
    return dict(zip(names, arrays))


def fwd_for_aot(cfg: ModelConfig):
    """The function lowered to ``model_fwd_<cfg>.hlo.txt``.

    Signature: (tokens int32 (B, L), *params in canonical order) →
    (logits f32 (B, L, V),).  Masks are applied rust-side by multiplying
    them into the weights before upload, so a single artifact serves both
    dense and pruned evaluation.
    """

    def fn(tokens, *arrays):
        params = unflatten_params(list(arrays), cfg)
        logits, _ = forward(params, tokens, cfg)
        return (logits,)

    return fn
