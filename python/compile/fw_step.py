"""Layer-2 FW-step functions — the jax functions that are AOT-lowered and
executed from the rust hot loop.

Each function composes the Layer-1 Pallas kernels; lowering happens in
``aot.py``, once per distinct pruned-layer shape.  Rust drives the FW
iteration (LMO + convex update + α-fixing are coordination, see
DESIGN.md §2), calling:

* ``fw_grad_fn``   — Algorithm 1 line 3 (the FLOP hot-spot),
* ``objective_fn`` — pruning-error evaluation (Fig 2/4 series),
* ``gram_fn``      — streaming calibration G ← G + XXᵀ,
* ``fw_chunk_fn``  — perf variant: C full FW iterations fused into one
  executable (LMO included), eliminating the per-iteration Rust↔PJRT
  round-trip (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fw_grad import fw_grad
from .kernels.gram import gram_acc
from .kernels.objective import objective


def fw_grad_fn(w, m, g, h):
    return (fw_grad(w, m, g, h),)


def objective_fn(w, m, g):
    return (objective(w, m, g),)


def gram_fn(g, x):
    return (gram_acc(g, x),)


BISECT_STEPS = 64


def _lmo_relaxed(neg_needed: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """Dynamic-k LMO over C_k via bisection on the selection threshold.

    Selects (up to) the k most-negative gradient entries and sets them to
    one (paper Eq. 12).  ``k`` is a runtime scalar, so one artifact serves
    every sparsity level / α.

    §Perf note (EXPERIMENTS.md §Perf): XLA-CPU ``sort`` costs ~8 ms for a
    12k-element gradient — 30× the fused gradient matmul — so instead of
    ranking we *bisect* the threshold t, maintaining the invariant
    ``count(flat < lo) ≤ k``: 64 compare+count sweeps (O(n) each, no
    sort).  After convergence ``flat < lo`` selects exactly k entries
    unless exact float ties straddle the boundary, in which case it
    selects fewer — still a feasible vertex of C_k, making this an
    ε-exact LMO (FW convergence tolerates approximate oracles; the
    rounding step restores the exact budget).  The upper bracket starts
    at 0 because the LMO never selects non-negative coefficients.
    """
    flat = neg_needed.reshape(-1)
    kf = k.astype(jnp.float32)

    lo0 = jnp.minimum(jnp.min(flat), 0.0) - 1e-3
    hi0 = jnp.float32(0.0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((flat < mid).astype(jnp.float32))
        ok = cnt <= kf
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid))

    lo, _hi = jax.lax.fori_loop(0, BISECT_STEPS, body, (lo0, hi0))
    chosen = flat < lo
    return chosen.astype(jnp.float32).reshape(neg_needed.shape)


def fw_chunk_fn(w, m, g, h, fixed, k_new, t0, n_iters: int):
    """Run ``n_iters`` FW iterations (Algorithm 2 lines 5–9) in one
    executable.

    Args:
      w, g, h: layer data (W, G=XXᵀ, H=WG).
      m: current relaxed mask over *free* coordinates (fixed coords 0).
      fixed: binary mask M̄ of α-fixed (unprunable) coordinates.
      k_new: f32 scalar — remaining LMO budget k(1−α).
      t0: f32 scalar — global iteration offset (η_t = 2/(t0+t+2)).
      n_iters: static chunk length.

    Returns the updated relaxed mask.  The gradient is evaluated at the
    *total* mask M̄ + M_t and masked to the free coordinates before the
    LMO, exactly as Algorithm 2 line 7.
    """

    def body(t, m):
        grad = fw_grad(w, m + fixed, g, h)
        grad_free = grad * (1.0 - fixed)
        v = _lmo_relaxed(grad_free, k_new)
        eta = 2.0 / (t0 + t.astype(jnp.float32) + 2.0)
        return (1.0 - eta) * m + eta * v

    m_out = jax.lax.fori_loop(0, n_iters, body, m)
    return (m_out,)


def make_fw_chunk(n_iters: int):
    def fn(w, m, g, h, fixed, k_new, t0):
        return fw_chunk_fn(w, m, g, h, fixed, k_new, t0, n_iters)

    return fn
