"""Build-time pretraining of the mini-GPT pruning targets.

This runs exactly once, inside ``make artifacts`` (DESIGN.md §3): the
paper prunes pretrained HuggingFace checkpoints; our stand-ins are
pretrained here on the synthetic corpus so pruning-quality comparisons
have a real signal.  AdamW + linear-warmup/cosine-decay, hand-rolled
(the build image has no optax).
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .configs import ModelConfig
from .model import Params, init_params, loss_fn


def _adamw_update(params, grads, m, v, step, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    def upd(p, g, m_, v_):
        m_new = b1 * m_ + (1 - b1) * g
        v_new = b2 * v_ + (1 - b2) * g * g
        mhat = m_new / (1 - b1**step)
        vhat = v_new / (1 - b2**step)
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return p_new, m_new, v_new

    flat = {k: upd(params[k], grads[k], m[k], v[k]) for k in params}
    return (
        {k: f[0] for k, f in flat.items()},
        {k: f[1] for k, f in flat.items()},
        {k: f[2] for k, f in flat.items()},
    )


def _lr_at(step: int, cfg: ModelConfig) -> float:
    if step <= cfg.warmup_steps:
        return cfg.lr * step / max(1, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / max(1, cfg.train_steps - cfg.warmup_steps)
    return cfg.lr * 0.5 * (1.0 + float(np.cos(np.pi * min(1.0, t))))


def sample_batch(tokens: np.ndarray, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
    offs = rng.integers(0, len(tokens) - seq - 1, size=batch)
    return np.stack([tokens[o : o + seq] for o in offs]).astype(np.int32)


def train(cfg: ModelConfig, corpus: np.ndarray, log_every: int = 100) -> Tuple[Params, Dict]:
    """Train and return (params, training_log)."""
    key = jax.random.PRNGKey(cfg.seed)
    params = init_params(cfg, key)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    rng = np.random.default_rng(cfg.seed)

    # weight decay is skipped on LN params and biases, GPT-style
    decay_mask = {k: float(("_g" not in k) and ("_b" not in k)) for k in params}

    @jax.jit
    def step_fn(params, m, v, batch, lr, step):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(p, batch, cfg))(params)

        def upd(p, g, m_, v_, dk):
            b1, b2, eps = 0.9, 0.999, 1e-8
            m_new = b1 * m_ + (1 - b1) * g
            v_new = b2 * v_ + (1 - b2) * g * g
            mhat = m_new / (1 - b1**step)
            vhat = v_new / (1 - b2**step)
            p_new = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + cfg.weight_decay * dk * p)
            return p_new, m_new, v_new

        new = {k: upd(params[k], grads[k], m[k], v[k], decay_mask[k]) for k in params}
        return (
            {k: n[0] for k, n in new.items()},
            {k: n[1] for k, n in new.items()},
            {k: n[2] for k, n in new.items()},
            loss,
        )

    log = {"steps": [], "loss": [], "lr": []}
    t0 = time.time()
    ema = None
    for step in range(1, cfg.train_steps + 1):
        batch = jnp.asarray(sample_batch(corpus, rng, cfg.batch_size, cfg.seq_len))
        lr = _lr_at(step, cfg)
        params, m, v, loss = step_fn(params, m, v, batch, lr, step)
        lval = float(loss)
        ema = lval if ema is None else 0.95 * ema + 0.05 * lval
        if step % log_every == 0 or step == 1:
            log["steps"].append(step)
            log["loss"].append(round(ema, 4))
            log["lr"].append(round(lr, 6))
            print(
                f"[train:{cfg.name}] step {step:5d}/{cfg.train_steps}"
                f" loss={ema:.4f} lr={lr:.5f} ({time.time() - t0:.0f}s)",
                flush=True,
            )
    log["final_loss"] = round(ema, 4)
    log["wall_seconds"] = round(time.time() - t0, 1)
    return params, log


def eval_perplexity(params: Params, cfg: ModelConfig, tokens: np.ndarray, batch: int = 8) -> float:
    """Build-time perplexity of the dense model (recorded in the manifest
    as a cross-check for the rust evaluator)."""
    seq = cfg.seq_len
    n_seq = len(tokens) // seq
    seqs = tokens[: n_seq * seq].reshape(n_seq, seq).astype(np.int32)
    total, count = 0.0, 0

    @jax.jit
    def nll_fn(params, b):
        return loss_fn(params, b, cfg)

    for i in range(0, n_seq, batch):
        b = jnp.asarray(seqs[i : i + batch])
        total += float(nll_fn(params, b)) * b.shape[0]
        count += b.shape[0]
    return float(np.exp(total / count))
