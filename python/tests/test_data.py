"""Corpus generator tests (python side); the rust mirror is checked by
golden tokens in the manifest + its own suite."""

import numpy as np
import pytest

from compile import data
from compile.prng import MASK64, SplitMix64, mix64


def test_splitmix_reference_values():
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    assert r.next_u64() == 0x06C45D188009454F


def test_splitmix_f64_range():
    r = SplitMix64(1234)
    xs = [r.next_f64() for _ in range(1000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert 0.4 < np.mean(xs) < 0.6


def test_mix64_is_stateless():
    assert mix64(42) == mix64(42)
    assert mix64(42) != mix64(43)
    assert 0 <= mix64(7) <= MASK64


def test_generate_deterministic_prefix():
    a = data.generate(42, 64)
    b = data.generate(42, 256)
    np.testing.assert_array_equal(a, b[:64])
    assert not np.array_equal(data.generate(42, 64), data.generate(43, 64))


def test_token_range():
    toks = data.generate(1, 10_000)
    assert toks.dtype == np.uint8
    assert toks.min() >= 0 and toks.max() <= 255


def test_copy_motifs():
    toks = data.generate(1, 20_000)
    hits = sum(int(toks[i] == toks[i - data.COPY_BACK]) for i in range(data.COPY_BACK, len(toks)))
    assert hits / len(toks) > 0.10


def test_super_token_chain():
    toks = data.generate(2, 50_000)
    total = chained = 0
    for i in range(1, len(toks)):
        if toks[i - 1] >= data.SUPER_MIN_TOKEN:
            total += 1
            chained += int(toks[i] == data.super_successor(int(toks[i - 1])))
    assert total > 50
    assert chained / total > 0.8


def test_golden_tokens_stable():
    # regression pin: the first eight tokens for seed 1 must never change
    # (the rust parity test depends on manifest-embedded goldens)
    assert data.golden_tokens(1, 8) == list(data.generate(1, 8))


@pytest.mark.parametrize("seed", [1, 42, 0x5EED0001])
def test_zipf_cdf_monotone(seed):
    cdf = data.zipf_cdf()
    assert all(cdf[i] < cdf[i + 1] for i in range(len(cdf) - 1))
    # and sampling respects it: token 0 far more common than token 200
    toks = data.generate(seed, 30_000)
    counts = np.bincount(toks, minlength=256)
    assert counts[0] > counts[200]
