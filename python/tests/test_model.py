"""L2 model tests: shapes, causality, loss behaviour, capture, and the
flatten/unflatten contract the AOT signature depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


@pytest.fixture(scope="module")
def cfg():
    return configs.ModelConfig(
        name="t", d_model=32, n_layers=2, n_heads=4, d_ff=64, seq_len=24, vocab_size=64
    )


@pytest.fixture(scope="module")
def params(cfg):
    return model.init_params(cfg, jax.random.PRNGKey(0))


def test_param_names_match_init(cfg, params):
    assert sorted(params.keys()) == sorted(cfg.param_names())
    assert cfg.n_params() == sum(int(np.prod(p.shape)) for p in params.values())


def test_forward_shapes(cfg, params):
    tokens = jnp.zeros((3, cfg.seq_len), dtype=jnp.int32)
    logits, caps = model.forward(params, tokens, cfg, collect_inputs=True)
    assert logits.shape == (3, cfg.seq_len, cfg.vocab_size)
    assert len(caps) == 4 * cfg.n_layers
    assert caps["blocks.0.wqkv"].shape == (3, cfg.seq_len, cfg.d_model)
    assert caps["blocks.0.wdown"].shape == (3, cfg.seq_len, cfg.d_ff)


def test_causality(cfg, params):
    key = jax.random.PRNGKey(1)
    t1 = jax.random.randint(key, (1, cfg.seq_len), 0, cfg.vocab_size)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab_size)
    l1, _ = model.forward(params, t1, cfg)
    l2, _ = model.forward(params, t2, cfg)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], atol=1e-5)
    assert float(jnp.abs(l1[0, -1] - l2[0, -1]).max()) > 1e-6


def test_loss_near_log_vocab_at_init(cfg, params):
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, cfg.seq_len), 0, cfg.vocab_size)
    loss = float(model.loss_fn(params, tokens, cfg))
    assert abs(loss - np.log(cfg.vocab_size)) < 0.5


def test_grad_step_reduces_loss(cfg, params):
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, cfg.seq_len), 0, cfg.vocab_size)
    loss, grads = jax.value_and_grad(lambda p: model.loss_fn(p, tokens, cfg))(params)
    stepped = {k: params[k] - 0.5 * grads[k] for k in params}
    loss2 = float(model.loss_fn(stepped, tokens, cfg))
    assert loss2 < float(loss)


def test_flatten_roundtrip(cfg, params):
    flat = model.flat_params(params, cfg)
    back = model.unflatten_params(flat, cfg)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))


def test_fwd_for_aot_matches_forward(cfg, params):
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, cfg.seq_len), 0, cfg.vocab_size)
    fn = model.fwd_for_aot(cfg)
    (logits_aot,) = fn(tokens, *model.flat_params(params, cfg))
    logits, _ = model.forward(params, tokens, cfg)
    np.testing.assert_allclose(logits_aot, logits, atol=1e-6)


def test_layer_shapes_families(cfg):
    fams = {f for (_, f, _, _) in cfg.layer_shapes()}
    assert fams == {"attn_qkv", "attn_out", "mlp_up", "mlp_down"}
    for name, _f, dout, din in cfg.layer_shapes():
        assert name.startswith("blocks.")
        assert dout > 0 and din > 0
