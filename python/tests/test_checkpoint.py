"""safetensors writer/reader round-trip (python side of the contract
with rust/src/model/safetensors.rs)."""

import struct

import numpy as np
import pytest

from compile import checkpoint


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.safetensors")
    tensors = {
        "a.weight": np.arange(6, dtype=np.float32).reshape(2, 3),
        "b": np.asarray([1.5, -2.5], dtype=np.float32),
    }
    checkpoint.save(path, tensors)
    out = checkpoint.load(path)
    assert set(out) == {"a.weight", "b"}
    np.testing.assert_array_equal(out["a.weight"], tensors["a.weight"])
    np.testing.assert_array_equal(out["b"], tensors["b"])


def test_header_is_8_byte_aligned(tmp_path):
    path = str(tmp_path / "t.safetensors")
    checkpoint.save(path, {"x": np.zeros((3, 3), dtype=np.float32)})
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
    assert hlen % 8 == 0


def test_casts_to_f32(tmp_path):
    path = str(tmp_path / "t.safetensors")
    checkpoint.save(path, {"x": np.asarray([1.0, 2.0], dtype=np.float64)})
    out = checkpoint.load(path)
    assert out["x"].dtype == np.float32


def test_empty_checkpoint(tmp_path):
    path = str(tmp_path / "e.safetensors")
    checkpoint.save(path, {})
    assert checkpoint.load(path) == {}


def test_rejects_wrong_dtype_header(tmp_path):
    path = str(tmp_path / "bad.safetensors")
    header = b'{"x": {"dtype": "I64", "shape": [1], "data_offsets": [0, 8]}}'
    pad = b" " * ((8 - len(header) % 8) % 8)
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(header) + len(pad)))
        f.write(header + pad)
        f.write(b"\0" * 8)
    with pytest.raises(KeyError):
        checkpoint.load(path)
