"""L2 FW-step tests: the fused chunk function must implement Algorithm 2
faithfully — LMO correctness, feasibility, descent, and agreement with a
straightforward python reference loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.fw_step import _lmo_relaxed, fw_chunk_fn
from compile.kernels import ref


def make_layer(seed, dout, din, batch=64):
    w = jax.random.normal(jax.random.PRNGKey(seed), (dout, din), dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (din, batch), dtype=jnp.float32)
    g = x @ x.T
    h = w @ g
    return w, g, h


def test_lmo_selects_most_negative():
    grad = jnp.asarray([[-5.0, 1.0, -1.0], [-3.0, 0.0, 2.0]])
    v = _lmo_relaxed(grad, jnp.asarray(2.0))
    np.testing.assert_array_equal(v, [[1.0, 0.0, 0.0], [1.0, 0.0, 0.0]])


def test_lmo_ignores_nonnegative():
    grad = jnp.asarray([[1.0, 2.0, 0.0, -0.5]])
    v = _lmo_relaxed(grad, jnp.asarray(3.0))
    assert float(v.sum()) == 1.0
    assert float(v[0, 3]) == 1.0


def test_lmo_budget_zero():
    grad = -jnp.ones((2, 3))
    v = _lmo_relaxed(grad, jnp.asarray(0.0))
    assert float(v.sum()) == 0.0


def reference_fw_loop(w, m0, g, h, fixed, k_new, t0, iters):
    """Plain-numpy mirror of the fused chunk."""
    m = np.asarray(m0, dtype=np.float64)
    wn = np.asarray(w, dtype=np.float64)
    gn = np.asarray(g, dtype=np.float64)
    hn = np.asarray(h, dtype=np.float64)
    fx = np.asarray(fixed, dtype=np.float64)
    for t in range(iters):
        grad = -2.0 * wn * (hn - (wn * (m + fx)) @ gn)
        grad = grad * (1.0 - fx)
        flat = grad.reshape(-1)
        order = np.argsort(flat, kind="stable")
        v = np.zeros_like(flat)
        chosen = [i for i in order[:k_new] if flat[i] < 0.0]
        v[chosen] = 1.0
        v = v.reshape(grad.shape)
        eta = 2.0 / (t0 + t + 2.0)
        m = (1.0 - eta) * m + eta * v
    return m


@pytest.mark.parametrize("iters", [1, 5])
def test_chunk_matches_reference_loop(iters):
    dout, din = 8, 12
    w, g, h = make_layer(3, dout, din)
    m0 = jnp.zeros((dout, din))
    fixed = jnp.zeros((dout, din)).at[0, 0].set(1.0)
    k_new = 20
    (m_out,) = fw_chunk_fn(w, m0, g, h, fixed, jnp.asarray(float(k_new)), jnp.asarray(0.0), iters)
    want = reference_fw_loop(w, m0, g, h, fixed, k_new, 0, iters)
    np.testing.assert_allclose(np.asarray(m_out), want, rtol=1e-3, atol=1e-4)


def test_chunk_iterates_stay_feasible():
    dout, din = 6, 10
    w, g, h = make_layer(9, dout, din)
    m0 = jnp.zeros((dout, din))
    fixed = jnp.zeros((dout, din))
    k_new = 12
    (m_out,) = fw_chunk_fn(w, m0, g, h, fixed, jnp.asarray(float(k_new)), jnp.asarray(0.0), 30)
    m_np = np.asarray(m_out)
    assert (m_np >= -1e-6).all() and (m_np <= 1.0 + 1e-6).all()
    assert m_np.sum() <= k_new + 1e-4


def test_chunk_objective_descends():
    dout, din = 12, 16
    w, g, h = make_layer(5, dout, din)
    m0 = jnp.zeros((dout, din))
    fixed = jnp.zeros((dout, din))
    k = dout * din // 2
    start = float(ref.objective_ref(w, m0, g))
    (m_out,) = fw_chunk_fn(w, m0, g, h, fixed, jnp.asarray(float(k)), jnp.asarray(0.0), 50)
    end = float(ref.objective_ref(w, m_out, g))
    assert end < start * 0.8, f"{end} !< {start}"


def test_chunk_respects_fixed_coords():
    dout, din = 6, 8
    w, g, h = make_layer(7, dout, din)
    fixed = jnp.zeros((dout, din)).at[2, 3].set(1.0).at[1, 1].set(1.0)
    m0 = jnp.zeros((dout, din))
    (m_out,) = fw_chunk_fn(w, m0, g, h, fixed, jnp.asarray(10.0), jnp.asarray(0.0), 20)
    m_np = np.asarray(m_out)
    # free-coordinate mask must stay zero at fixed coords
    assert m_np[2, 3] == 0.0 and m_np[1, 1] == 0.0
