"""AOT lowering tests: HLO text generation must work for every artifact
family, and the manifest contract must hold.  These run the actual
lowering (fast) but not training.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, configs, fw_step


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_to_hlo_text_basic():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(spec((4, 4)), spec((4, 4)))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_fw_grad_lowering():
    args = [spec((16, 8)), spec((16, 8)), spec((8, 8)), spec((16, 8))]
    lowered = jax.jit(fw_step.fw_grad_fn).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret-mode pallas must lower to plain HLO: no custom-calls that
    # the CPU PJRT client cannot execute
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_fw_chunk_lowering_contains_loop():
    args = [
        spec((8, 8)),
        spec((8, 8)),
        spec((8, 8)),
        spec((8, 8)),
        spec((8, 8)),
        spec((), jnp.float32),
        spec((), jnp.float32),
    ]
    lowered = jax.jit(fw_step.make_fw_chunk(5)).lower(*args)
    text = aot.to_hlo_text(lowered)
    assert "while" in text.lower()


def test_distinct_prune_shapes_cover_all_layers():
    for cfg in configs.MODEL_CONFIGS.values():
        shapes = set(cfg.distinct_prune_shapes())
        for _, _, dout, din in cfg.layer_shapes():
            assert (dout, din) in shapes


def test_configs_consistency():
    for name, cfg in configs.MODEL_CONFIGS.items():
        assert cfg.name == name
        assert cfg.d_model % cfg.n_heads == 0
        assert len(cfg.param_names()) == 4 + 8 * cfg.n_layers
        assert cfg.vocab_size == configs.VOCAB_SIZE


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_contract():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    for name, entry in m["models"].items():
        cfg = configs.get_config(name)
        assert entry["param_order"] == cfg.param_names()
        for f_ in [entry["checkpoint"], entry["fwd_hlo"]]:
            assert os.path.exists(os.path.join(ARTIFACTS, f_)), f_
        for layer in entry["layers"]:
            key = f"{layer['d_out']}x{layer['d_in']}"
            assert key in m["kernels"]["fw_grad"], key
            assert key in m["kernels"]["objective"], key
            assert key in m["kernels"]["fw_chunk"]["paths"], key
            assert str(layer["d_in"]) in m["kernels"]["gram"]["paths"]
    for group in ["fw_grad", "objective"]:
        for f_ in m["kernels"][group].values():
            assert os.path.exists(os.path.join(ARTIFACTS, f_)), f_
    # golden corpus entries present for the rust parity test
    assert len(m["golden"]["corpus"]) >= 1


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
def test_data_bins_exist_with_declared_sizes():
    with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
        m = json.load(f)
    for split, size in m["data"]["sizes"].items():
        p = os.path.join(ARTIFACTS, m["data"][split])
        assert os.path.getsize(p) == size
