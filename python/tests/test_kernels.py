"""Kernel-vs-oracle correctness — the core L1 signal.

Hypothesis sweeps shapes and magnitudes; every Pallas kernel must match
its pure-jnp oracle within float32 tolerance, including non-default
block configurations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fw_grad import default_blocks, flops, fw_grad, pick_block, vmem_bytes
from compile.kernels.gram import gram_acc, gram_blocks
from compile.kernels.objective import objective

DIMS = st.sampled_from([8, 16, 24, 32, 64, 96, 128])


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def make_layer(seed, dout, din, batch=64):
    w = rand(seed, (dout, din))
    x = rand(seed + 1, (din, batch))
    g = x @ x.T
    h = w @ g
    m = jax.random.uniform(jax.random.PRNGKey(seed + 2), (dout, din), dtype=jnp.float32)
    return w, x, g, h, m


# ---------------------------------------------------------------------------
# fw_grad
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(dout=DIMS, din=DIMS, seed=st.integers(0, 100))
def test_fw_grad_matches_ref(dout, din, seed):
    w, _x, g, h, m = make_layer(seed, dout, din)
    out = fw_grad(w, m, g, h)
    want = ref.fw_grad_ref(w, m, g, h)
    tol = 1e-4 * max(1.0, float(jnp.abs(want).max()))
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=tol)


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (32, 16, 32)])
def test_fw_grad_custom_blocks(blocks):
    w, _x, g, h, m = make_layer(7, 32, 32)
    out = fw_grad(w, m, g, h, blocks=blocks)
    want = ref.fw_grad_ref(w, m, g, h)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-2 * float(jnp.abs(want).max()))


def test_fw_grad_rejects_bad_blocks():
    w, _x, g, h, m = make_layer(3, 24, 24)
    with pytest.raises(AssertionError):
        fw_grad(w, m, g, h, blocks=(7, 8, 8))


def test_fw_grad_zero_at_full_mask():
    w, _x, g, h, _ = make_layer(5, 16, 16)
    out = fw_grad(w, jnp.ones_like(w), g, h)
    assert float(jnp.abs(out).max()) < 1e-2


def test_fw_grad_is_minus_2w_h_at_zero_mask():
    w, _x, g, h, _ = make_layer(6, 16, 24)
    out = fw_grad(w, jnp.zeros_like(w), g, h)
    np.testing.assert_allclose(out, -2.0 * w * h, rtol=1e-4, atol=1e-2 * float(jnp.abs(h).max()))


# ---------------------------------------------------------------------------
# objective
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(dout=DIMS, din=DIMS, seed=st.integers(0, 100))
def test_objective_matches_ref(dout, din, seed):
    w, _x, g, _h, m = make_layer(seed, dout, din)
    out = float(np.asarray(objective(w, m, g)).reshape(()))
    want = float(ref.objective_ref(w, m, g))
    assert out == pytest.approx(want, rel=1e-4, abs=1e-3)


def test_objective_matches_x_space():
    w, x, g, _h, m = make_layer(11, 24, 32, batch=128)
    grams = float(np.asarray(objective(w, m, g)).reshape(()))
    direct = float(ref.pruning_error_ref(w, m, x))
    assert grams == pytest.approx(direct, rel=5e-3)


def test_objective_zero_at_full_mask():
    w, _x, g, _h, _m = make_layer(12, 16, 16)
    out = float(np.asarray(objective(w, jnp.ones_like(w), g)).reshape(()))
    assert abs(out) < 1e-2


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    din=DIMS,
    batch=st.sampled_from([32, 64, 256, 1024]),
    seed=st.integers(0, 100),
)
def test_gram_acc_matches_ref(din, batch, seed):
    x = rand(seed, (din, batch))
    g0 = rand(seed + 3, (din, din))
    out = gram_acc(g0, x)
    want = ref.gram_acc_ref(g0, x)
    np.testing.assert_allclose(out, want, rtol=1e-3, atol=1e-2 * float(jnp.abs(want).max()))


def test_gram_zero_padding_is_identity():
    # padded (zero) columns must not change G — the runtime relies on this
    x = rand(1, (16, 48))
    xp = jnp.concatenate([x, jnp.zeros((16, 16))], axis=1)
    g0 = jnp.zeros((16, 16))
    np.testing.assert_allclose(gram_acc(g0, xp), gram_acc(g0, x), rtol=1e-5, atol=1e-3)


# ---------------------------------------------------------------------------
# tiling metadata
# ---------------------------------------------------------------------------


def test_pick_block_divides():
    for dim in [8, 24, 64, 96, 128, 384, 512]:
        b = pick_block(dim, 128)
        assert dim % b == 0
        assert b <= 128


def test_default_blocks_vmem_budget():
    # every shape in the AOT manifest must fit the 16 MiB VMEM budget
    for dout, din in [(192, 64), (64, 64), (256, 64), (64, 256), (384, 128), (512, 128), (128, 512)]:
        bm, bn, bk = default_blocks(dout, din)
        assert dout % bm == 0 and din % bn == 0 and din % bk == 0
        assert vmem_bytes(dout, din) < 16 * 1024 * 1024
        assert flops(dout, din) == 2 * dout * din * din


def test_gram_blocks_divide():
    bm, bn, bk = gram_blocks(128, 1024)
    assert 128 % bm == 0 and 128 % bn == 0 and 1024 % bk == 0
