"""Make the ``compile`` package importable regardless of pytest's
invocation directory (repo root or python/)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
