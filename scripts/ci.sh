#!/usr/bin/env bash
# Tier-1 verification: build + tests, plus a quickstart smoke run when
# an artifacts workspace exists (skipped gracefully otherwise).
#
#   scripts/ci.sh            # from the repo root (or anywhere)
#
# Referenced from ROADMAP.md's tier-1 line.
set -euo pipefail

REPO="$(cd "$(dirname "$0")/.." && pwd)"
cd "$REPO/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# `make artifacts` (python/compile/aot.py) writes to <repo>/artifacts;
# resolve it absolutely so the cwd (rust/) doesn't matter.
ARTIFACTS="${SPARSEFW_ARTIFACTS:-$REPO/artifacts}"
if [ -d "$ARTIFACTS" ]; then
    echo "== quickstart example ($ARTIFACTS) =="
    SPARSEFW_ARTIFACTS="$ARTIFACTS" cargo run --release --example quickstart
else
    echo "== quickstart example skipped (no artifacts workspace at $ARTIFACTS) =="
fi

echo "ci.sh OK"
