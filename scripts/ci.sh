#!/usr/bin/env bash
# Tier-1 verification: build + tests + a server smoke test over the
# --demo in-memory model, plus a quickstart smoke run when an artifacts
# workspace exists (skipped gracefully otherwise).
#
#   scripts/ci.sh            # from the repo root (or anywhere)
#
# Referenced from ROADMAP.md's tier-1 line.
set -euo pipefail

REPO="$(cd "$(dirname "$0")/.." && pwd)"
cd "$REPO/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q (with debug-invariants asserts) =="
cargo test -q --features debug-invariants

BIN="$REPO/rust/target/release/sparsefw"

echo "== sparsefw analyze --deny-warnings (project lints) =="
"$BIN" analyze --deny-warnings

echo "== sparse inference smoke (prune -> eval --sparse -> generate) =="
INFER_DIR="$(mktemp -d)"
MASKS_FILE="$INFER_DIR/masks.safetensors"
"$BIN" prune --demo --method wanda --pattern per-row:0.5 --samples 8 \
    --out "$MASKS_FILE" >/dev/null 2>&1
[ -s "$MASKS_FILE" ] || { echo "prune --out wrote no masks"; exit 1; }
# eval --sparse exits non-zero if the compiled forward drifts from the
# masked dense model past tolerance — an end-to-end equivalence gate
SPARSE_OUT="$("$BIN" eval --demo --sparse --masks "$MASKS_FILE" 2>&1)" \
    || { echo "eval --sparse failed: $SPARSE_OUT"; exit 1; }
echo "$SPARSE_OUT" | grep -q "logit max" \
    || { echo "eval --sparse printed no logit-equivalence line: $SPARSE_OUT"; exit 1; }
echo "$SPARSE_OUT" | grep -q "ppl masked-dense=" \
    || { echo "eval --sparse printed no perplexity cross-check: $SPARSE_OUT"; exit 1; }
# greedy decode must be deterministic: two identical-seed runs agree
GEN_A="$("$BIN" generate --demo --masks "$MASKS_FILE" --max-new 12 --seed 7 2>&1 \
    | grep '^tokens:')"
GEN_B="$("$BIN" generate --demo --masks "$MASKS_FILE" --max-new 12 --seed 7 2>&1 \
    | grep '^tokens:')"
[ -n "$GEN_A" ] || { echo "generate printed no tokens line"; exit 1; }
[ "$GEN_A" = "$GEN_B" ] \
    || { echo "generate is not deterministic: '$GEN_A' vs '$GEN_B'"; exit 1; }
rm -rf "$INFER_DIR"
echo "   sparse inference smoke OK (equivalence gate + deterministic decode)"

echo "== server smoke test (serve --demo on an ephemeral port) =="
SERVE_LOG="$(mktemp)"
TRACE_NDJSON="$(mktemp)"
"$BIN" serve --demo --addr 127.0.0.1:0 --workers 2 \
    --trace-out "$TRACE_NDJSON" >"$SERVE_LOG" 2>&1 &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT

ADDR=""
for _ in $(seq 1 100); do
    ADDR="$(sed -n 's/^listening on //p' "$SERVE_LOG" | head -n1)"
    [ -n "$ADDR" ] && break
    sleep 0.1
done
if [ -z "$ADDR" ]; then
    echo "server did not come up:"; cat "$SERVE_LOG"; exit 1
fi
echo "   server at $ADDR"

# submit a tiny Wanda job, poll it to Done, and assert non-empty masks
SUBMIT_OUT="$("$BIN" submit --addr "$ADDR" --model demo --method wanda \
    --pattern per-row:0.5 --samples 8 --wait 2>&1)"
echo "$SUBMIT_OUT" | grep -q "state=done" \
    || { echo "job did not finish: $SUBMIT_OUT"; cat "$SERVE_LOG"; exit 1; }
echo "$SUBMIT_OUT" | grep -q "mask_layers=8" \
    || { echo "expected 8 mask layers: $SUBMIT_OUT"; exit 1; }
echo "$SUBMIT_OUT" | grep -Eq "mask_nnz=[1-9]" \
    || { echo "masks are empty: $SUBMIT_OUT"; exit 1; }

# second smoke path: a SparseFW job on the incremental engine
FW_OUT="$("$BIN" submit --addr "$ADDR" --model demo --method sparsefw \
    --fw-engine incremental --iters 40 --alpha 0.9 --pattern per-row:0.5 \
    --samples 8 --wait 2>&1)"
echo "$FW_OUT" | grep -q "state=done" \
    || { echo "incremental FW job did not finish: $FW_OUT"; cat "$SERVE_LOG"; exit 1; }
echo "$FW_OUT" | grep -Eq "mask_nnz=[1-9]" \
    || { echo "incremental FW masks are empty: $FW_OUT"; exit 1; }
echo "   incremental engine smoke OK"

# third smoke path: staged block-propagated calibration end-to-end
PROP_OUT="$("$BIN" submit --addr "$ADDR" --model demo --method wanda \
    --pattern per-row:0.5 --samples 8 --propagate block --wait 2>&1)"
echo "$PROP_OUT" | grep -q "state=done" \
    || { echo "propagated job did not finish: $PROP_OUT"; cat "$SERVE_LOG"; exit 1; }
echo "$PROP_OUT" | grep -Eq "mask_nnz=[1-9]" \
    || { echo "propagated masks are empty: $PROP_OUT"; exit 1; }
echo "   staged --propagate block smoke OK"

# fourth smoke path: the method registry listing, local and via the
# server's GET /methods, must name every built-in
for METHODS_FLAGS in "" "--addr $ADDR"; do
    # shellcheck disable=SC2086
    METHODS_OUT="$("$BIN" methods $METHODS_FLAGS 2>&1)"
    for M in magnitude wanda ria sparsefw sparsegpt; do
        echo "$METHODS_OUT" | grep -q "$M" \
            || { echo "methods listing ($METHODS_FLAGS) missing $M: $METHODS_OUT"; exit 1; }
    done
done
echo "   sparsefw methods smoke OK"

# fifth smoke path: a refined job reports its objective claw-back
REFINE_OUT="$("$BIN" submit --addr "$ADDR" --model demo --method wanda \
    --pattern per-row:0.5 --samples 8 --refine swaps,update --wait 2>&1)"
echo "$REFINE_OUT" | grep -q "state=done" \
    || { echo "refined job did not finish: $REFINE_OUT"; cat "$SERVE_LOG"; exit 1; }
echo "$REFINE_OUT" | grep -q "refine_obj_delta=" \
    || { echo "refined job summary missing refine_obj_delta: $REFINE_OUT"; exit 1; }
echo "   --refine swaps,update smoke OK"

# sixth smoke path: observability — client-supplied corr ID, FW
# convergence certificates via `sparsefw trace`, the server's NDJSON
# span log (--trace-out), and the Prometheus exposition (scraped over
# a raw /dev/tcp socket; the image carries no curl)
OBS_OUT="$("$BIN" submit --addr "$ADDR" --model demo --method sparsefw \
    --fw-engine incremental --iters 40 --alpha 0.9 --pattern per-row:0.5 \
    --samples 8 --trace-every 5 --corr-id ci-obs-smoke --wait 2>&1)"
echo "$OBS_OUT" | grep -q "state=done" \
    || { echo "observability job did not finish: $OBS_OUT"; cat "$SERVE_LOG"; exit 1; }
echo "$OBS_OUT" | grep -q "ci-obs-smoke" \
    || { echo "client corr ID missing from submit output: $OBS_OUT"; exit 1; }
OBS_ID="$(echo "$OBS_OUT" | sed -n 's/^job \([0-9]*\):.*/\1/p' | head -n1)"
TRACE_CMD_OUT="$("$BIN" trace --job "$OBS_ID" --addr "$ADDR" 2>&1)"
echo "$TRACE_CMD_OUT" | grep -qF "[corr ci-obs-smoke]" \
    || { echo "trace endpoint lost the corr ID: $TRACE_CMD_OUT"; exit 1; }
echo "$TRACE_CMD_OUT" | grep -qF "gap[last]" \
    || { echo "no convergence table from sparsefw trace: $TRACE_CMD_OUT"; exit 1; }
[ -s "$TRACE_NDJSON" ] \
    || { echo "--trace-out NDJSON span log is empty"; exit 1; }
head -n1 "$TRACE_NDJSON" | grep -q '"span"' \
    || { echo "--trace-out first line is not a span event: $(head -n1 "$TRACE_NDJSON")"; exit 1; }
PROM="$(exec 3<>"/dev/tcp/${ADDR%:*}/${ADDR##*:}"; \
    printf 'GET /metrics?format=prometheus HTTP/1.1\r\nHost: sparsefw\r\nConnection: close\r\n\r\n' >&3; \
    cat <&3)"
echo "$PROM" | grep -q "^# TYPE sparsefw_jobs_done_total counter" \
    || { echo "prometheus exposition missing jobs_done_total: $PROM"; exit 1; }
echo "$PROM" | grep -q "^sparsefw_phase_fw_seconds_bucket" \
    || { echo "prometheus exposition missing the fw phase histogram: $PROM"; exit 1; }
echo "   observability smoke OK (corr ID + certificates + NDJSON + prometheus)"

# seventh smoke path: served sparse inference — POST /jobs/:id/eval and
# /jobs/:id/generate answer from the worker-compiled model cache (raw
# /dev/tcp again; the image carries no curl)
http_post() { # path body
    exec 3<>"/dev/tcp/${ADDR%:*}/${ADDR##*:}"
    printf 'POST %s HTTP/1.1\r\nHost: sparsefw\r\nContent-Type: application/json\r\nContent-Length: %s\r\nConnection: close\r\n\r\n%s' \
        "$1" "${#2}" "$2" >&3
    cat <&3
    exec 3<&- 3>&-
}
EVAL_RESP="$(http_post "/jobs/$OBS_ID/eval" '{"max_seqs":4}')"
echo "$EVAL_RESP" | grep -q '"ppl"' \
    || { echo "POST /jobs/$OBS_ID/eval returned no ppl: $EVAL_RESP"; cat "$SERVE_LOG"; exit 1; }
echo "$EVAL_RESP" | grep -q '"packed_bytes"' \
    || { echo "eval response missing the format breakdown: $EVAL_RESP"; exit 1; }
GEN_RESP="$(http_post "/jobs/$OBS_ID/generate" \
    '{"prompt":[1,2,3],"max_new":8,"temperature":0.0,"seed":7}')"
echo "$GEN_RESP" | grep -q '"tokens"' \
    || { echo "POST /jobs/$OBS_ID/generate returned no tokens: $GEN_RESP"; cat "$SERVE_LOG"; exit 1; }
PROM2="$(exec 3<>"/dev/tcp/${ADDR%:*}/${ADDR##*:}"; \
    printf 'GET /metrics?format=prometheus HTTP/1.1\r\nHost: sparsefw\r\nConnection: close\r\n\r\n' >&3; \
    cat <&3)"
echo "$PROM2" | grep -Eq "^sparsefw_models_compiled_total [1-9]" \
    || { echo "no models compiled for serving: $PROM2"; exit 1; }
echo "$PROM2" | grep -Eq "^sparsefw_compiled_cache_hits_total [1-9]" \
    || { echo "inference requests did not hit the compiled cache: $PROM2"; exit 1; }
echo "   served inference smoke OK (eval + generate from the compiled cache)"

"$BIN" status --addr "$ADDR"
"$BIN" shutdown --addr "$ADDR"
wait "$SERVE_PID"
trap - EXIT
echo "   server smoke test OK"

# chaos lane: every fault site × {error, panic, delay}, one server each
# (SPARSEFW_FAULTS arms the site's first hit).  Acceptance per cell:
# the job lands as done or as failed-naming-the-injection, the server
# still answers status afterwards, and shutdown is clean — no hangs, no
# lost jobs.  A fault can also legitimately fire during the startup
# journal replay (io.read): then the process must refuse cleanly,
# naming the injection in its log.
echo "== chaos lane: fault-injection sweep (site x {error,panic,delay}) =="
for SITE in io.read io.write.checkpoint gram.compute fw.iter \
            worker.panic net.accept net.mid-response; do
  for KIND in error panic delay; do
    CHAOS_DIR="$(mktemp -d)"
    CHAOS_LOG="$(mktemp)"
    SPARSEFW_FAULTS="$SITE:$KIND" "$BIN" serve --demo --addr 127.0.0.1:0 \
        --workers 1 --journal "$CHAOS_DIR" >"$CHAOS_LOG" 2>&1 &
    CHAOS_PID=$!
    trap 'kill "$CHAOS_PID" 2>/dev/null || true' EXIT
    CADDR=""
    for _ in $(seq 1 100); do
        CADDR="$(sed -n 's/^listening on //p' "$CHAOS_LOG" | head -n1)"
        [ -n "$CADDR" ] && break
        kill -0 "$CHAOS_PID" 2>/dev/null || break
        sleep 0.1
    done
    if [ -z "$CADDR" ]; then
        grep -q "injected" "$CHAOS_LOG" || {
            echo "chaos ($SITE:$KIND): server neither came up nor refused by injection:"
            cat "$CHAOS_LOG"; exit 1; }
        wait "$CHAOS_PID" 2>/dev/null || true
        trap - EXIT
        rm -rf "$CHAOS_DIR"
        echo "   chaos $SITE:$KIND OK (clean startup refusal)"
        continue
    fi
    # the armed site fires exactly once, and the submit connection can
    # be the victim (net.accept): one retry, then the job must land
    CH_OUT="$("$BIN" submit --addr "$CADDR" --model demo --method wanda \
        --pattern per-row:0.5 --samples 8 --propagate block \
        --timeout-secs 120 --wait 2>&1)" \
      || CH_OUT="$CH_OUT
$("$BIN" submit --addr "$CADDR" --model demo --method wanda \
        --pattern per-row:0.5 --samples 8 --propagate block \
        --timeout-secs 120 --wait 2>&1)" \
      || { echo "chaos ($SITE:$KIND): submit failed twice: $CH_OUT"; cat "$CHAOS_LOG"; exit 1; }
    echo "$CH_OUT" | grep -Eq "state=done|state=failed.*injected" \
      || { echo "chaos ($SITE:$KIND): job neither done nor failed-by-injection: $CH_OUT"
           cat "$CHAOS_LOG"; exit 1; }
    "$BIN" status --addr "$CADDR" >/dev/null \
      || { echo "chaos ($SITE:$KIND): server unresponsive after the fault"; cat "$CHAOS_LOG"; exit 1; }
    "$BIN" shutdown --addr "$CADDR" >/dev/null
    wait "$CHAOS_PID"
    trap - EXIT
    rm -rf "$CHAOS_DIR"
    echo "   chaos $SITE:$KIND OK"
  done
done
echo "   chaos lane OK (21/21 cells, zero hangs, zero lost jobs)"

# fleet lane: real coordinator + 2 worker processes behind bearer auth,
# SIGKILL one worker mid-shard; the job must requeue the lost blocks on
# the survivor, finish, and match the single-node mask_digest bit for
# bit.  Each worker arms a one-shot 3s fw.iter delay (the fault
# registry; a delay changes no results) so the kill reliably lands
# while the shard is genuinely mid-flight.
echo "== fleet smoke: coordinator + 2 workers, SIGKILL one mid-shard =="
FLEET_JOB_FLAGS="--model demo --method wanda --pattern per-row:0.5 \
    --samples 8 --propagate block"

# single-node reference digest for the identical spec
REF_LOG="$(mktemp)"
"$BIN" serve --demo --addr 127.0.0.1:0 --workers 1 >"$REF_LOG" 2>&1 &
REF_PID=$!
trap 'kill "$REF_PID" 2>/dev/null || true' EXIT
RADDR=""
for _ in $(seq 1 100); do
    RADDR="$(sed -n 's/^listening on //p' "$REF_LOG" | head -n1)"
    [ -n "$RADDR" ] && break
    sleep 0.1
done
[ -n "$RADDR" ] || { echo "reference server did not come up:"; cat "$REF_LOG"; exit 1; }
# shellcheck disable=SC2086
REF_OUT="$("$BIN" submit --addr "$RADDR" $FLEET_JOB_FLAGS --wait 2>&1)"
REF_DIGEST="$(echo "$REF_OUT" | sed -n 's/.*mask_digest=\([0-9a-f]*\).*/\1/p' | head -n1)"
[ -n "$REF_DIGEST" ] \
    || { echo "no single-node mask_digest: $REF_OUT"; cat "$REF_LOG"; exit 1; }
"$BIN" shutdown --addr "$RADDR" >/dev/null
wait "$REF_PID"
trap - EXIT
echo "   single-node reference digest $REF_DIGEST"

# coordinator (short heartbeat window so the reap lands in test time)
# + two fleet workers, all speaking the same bearer token
FTOKEN="ci-fleet-secret"
CO_LOG="$(mktemp)"; W1_LOG="$(mktemp)"; W2_LOG="$(mktemp)"
W1_PID=""; W2_PID=""
"$BIN" serve --demo --coordinator --addr 127.0.0.1:0 \
    --fleet-timeout-secs 2 --auth-token "$FTOKEN" >"$CO_LOG" 2>&1 &
CO_PID=$!
trap 'kill -9 "$CO_PID" $W1_PID $W2_PID 2>/dev/null || true' EXIT
FADDR=""
for _ in $(seq 1 100); do
    FADDR="$(sed -n 's/^listening on //p' "$CO_LOG" | head -n1)"
    [ -n "$FADDR" ] && break
    sleep 0.1
done
[ -n "$FADDR" ] || { echo "coordinator did not come up:"; cat "$CO_LOG"; exit 1; }
SPARSEFW_FAULTS='fw.iter:delay:1:3000' "$BIN" serve --worker \
    --coordinator-addr "$FADDR" --demo --label w1 \
    --auth-token "$FTOKEN" >"$W1_LOG" 2>&1 &
W1_PID=$!
SPARSEFW_FAULTS='fw.iter:delay:1:3000' "$BIN" serve --worker \
    --coordinator-addr "$FADDR" --demo --label w2 \
    --auth-token "$FTOKEN" >"$W2_LOG" 2>&1 &
W2_PID=$!
for _ in $(seq 1 100); do
    grep -q "registered with coordinator" "$W1_LOG" \
        && grep -q "registered with coordinator" "$W2_LOG" && break
    sleep 0.1
done
grep -q "registered with coordinator" "$W1_LOG" \
    || { echo "worker w1 never registered:"; cat "$W1_LOG" "$CO_LOG"; exit 1; }
grep -q "registered with coordinator" "$W2_LOG" \
    || { echo "worker w2 never registered:"; cat "$W2_LOG" "$CO_LOG"; exit 1; }

# auth: an un-tokened submit to the coordinator must bounce with a 401
# shellcheck disable=SC2086
if NOAUTH_OUT="$("$BIN" submit --addr "$FADDR" $FLEET_JOB_FLAGS 2>&1)"; then
    echo "un-tokened submit was accepted: $NOAUTH_OUT"; exit 1
fi
echo "$NOAUTH_OUT" | grep -q "401" \
    || { echo "expected a 401 without the token: $NOAUTH_OUT"; exit 1; }

# submit in the background, then SIGKILL the first worker to lease a
# shard while that shard is still running
FLEET_OUT="$(mktemp)"
# shellcheck disable=SC2086
"$BIN" submit --addr "$FADDR" --token "$FTOKEN" $FLEET_JOB_FLAGS \
    --timeout-secs 300 --wait >"$FLEET_OUT" 2>&1 &
SUB_PID=$!
VICTIM=""; SURVIVOR=""
for _ in $(seq 1 600); do
    if grep -q "leased job" "$W1_LOG"; then VICTIM=$W1_PID; SURVIVOR=$W2_PID; break; fi
    if grep -q "leased job" "$W2_LOG"; then VICTIM=$W2_PID; SURVIVOR=$W1_PID; break; fi
    sleep 0.05
done
[ -n "$VICTIM" ] \
    || { echo "no worker leased a shard:"; cat "$CO_LOG" "$W1_LOG" "$W2_LOG"; exit 1; }
kill -9 "$VICTIM"
echo "   SIGKILLed worker pid $VICTIM mid-shard"

wait "$SUB_PID" || true
grep -q "state=done" "$FLEET_OUT" \
    || { echo "fleet job did not finish after the kill:"; cat "$FLEET_OUT" "$CO_LOG"; exit 1; }
FLEET_DIGEST="$(sed -n 's/.*mask_digest=\([0-9a-f]*\).*/\1/p' "$FLEET_OUT" | head -n1)"
[ "$FLEET_DIGEST" = "$REF_DIGEST" ] \
    || { echo "fleet digest $FLEET_DIGEST != single-node $REF_DIGEST"
         cat "$FLEET_OUT" "$CO_LOG"; exit 1; }
grep -q "requeued shard" "$CO_LOG" \
    || { echo "killed worker's shard was never requeued:"; cat "$CO_LOG"; exit 1; }
FPROM="$(exec 3<>"/dev/tcp/${FADDR%:*}/${FADDR##*:}"; \
    printf 'GET /metrics?format=prometheus HTTP/1.1\r\nHost: sparsefw\r\nConnection: close\r\n\r\n' >&3; \
    cat <&3)"
echo "$FPROM" | grep -Eq "^sparsefw_fleet_shards_dispatched_total [1-9]" \
    || { echo "fleet exposition missing shard dispatches: $FPROM"; exit 1; }
echo "$FPROM" | grep -Eq "^sparsefw_fleet_shards_requeued_total [1-9]" \
    || { echo "fleet exposition missing the requeue count: $FPROM"; exit 1; }

# clean shutdown: survivor first (it polls the coordinator), then the
# coordinator itself over the authed client
kill "$SURVIVOR" 2>/dev/null || true
"$BIN" shutdown --addr "$FADDR" --token "$FTOKEN" >/dev/null
wait "$CO_PID"
trap - EXIT
echo "   fleet smoke OK (kill-one-worker requeue, digest $FLEET_DIGEST)"

echo "== server queue micro-bench (BENCH_server.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_server.json" cargo bench --bench server_queue
echo "   wrote $REPO/BENCH_server.json"

echo "== FW hot-loop bench: dense vs incremental engine (BENCH_fw.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_fw.json" cargo bench --bench fw_hot_loop
echo "   wrote $REPO/BENCH_fw.json"

echo "== staged vs one-shot calibration bench (BENCH_calib.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_calib.json" cargo bench --bench calib_staged
echo "   wrote $REPO/BENCH_calib.json"

echo "== telemetry overhead bench: spans off/on the FW layer (BENCH_trace.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_trace.json" cargo bench --bench trace_overhead
echo "   wrote $REPO/BENCH_trace.json"

echo "== sparse inference bench: dense vs CSR vs n:m (BENCH_infer.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_infer.json" cargo bench --bench sparse_infer
echo "   wrote $REPO/BENCH_infer.json"

# method-registry-driven end-to-end timings: iterates the registry, so
# newly registered methods are benched automatically (prints a note and
# exits cleanly without an artifacts workspace)
echo "== table1 methods bench over the registry (BENCH_methods.json) =="
SPARSEFW_BENCH_JSON="$REPO/BENCH_methods.json" cargo bench --bench table1_methods

# `make artifacts` (python/compile/aot.py) writes to <repo>/artifacts;
# resolve it absolutely so the cwd (rust/) doesn't matter.
ARTIFACTS="${SPARSEFW_ARTIFACTS:-$REPO/artifacts}"
if [ -d "$ARTIFACTS" ]; then
    # first pass runs the default incremental engine; the second pins
    # the dense engine so both hot loops stay smoke-tested end-to-end
    echo "== quickstart example ($ARTIFACTS, --fw-engine incremental default) =="
    SPARSEFW_ARTIFACTS="$ARTIFACTS" cargo run --release --example quickstart
    echo "== quickstart example, --fw-engine dense smoke path =="
    SPARSEFW_ARTIFACTS="$ARTIFACTS" SPARSEFW_FW_ENGINE=dense \
        cargo run --release --example quickstart
else
    echo "== quickstart example skipped (no artifacts workspace at $ARTIFACTS) =="
fi

echo "ci.sh OK"
