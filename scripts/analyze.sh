#!/usr/bin/env bash
# Static analysis + sanitizer lanes, one entry point:
#
#   scripts/analyze.sh       # from the repo root (or anywhere)
#
#   1. `sparsefw analyze --deny-warnings` — the project-invariant lints
#      (lock ordering, panic paths, registry/codec consistency) over
#      rust/src.  Always runs; any finding fails the script.
#   2. ThreadSanitizer lane — the threaded server/pool/queue tests with
#      `-Z sanitizer=thread`.  Needs a nightly toolchain with the
#      rust-src component (TSan rebuilds std); skipped with a named
#      reason otherwise.
#   3. Miri lane — the util/tensor unit tests under Miri's UB checker.
#      Needs the nightly miri component; skipped with a named reason
#      otherwise.
#
# The skips are deliberate: the lanes are best-effort hardening wherever
# the toolchain allows, while `scripts/ci.sh` (tier 1, which runs lane 1
# too) stays runnable on a stock stable toolchain.
set -euo pipefail

REPO="$(cd "$(dirname "$0")/.." && pwd)"
cd "$REPO/rust"

echo "== sparsefw analyze --deny-warnings (project lints) =="
cargo build --release --quiet
"$REPO/rust/target/release/sparsefw" analyze --deny-warnings

have_nightly() {
    command -v rustup >/dev/null 2>&1 \
        && rustup toolchain list 2>/dev/null | grep -q '^nightly'
}

nightly_component() {
    rustup component list --toolchain nightly 2>/dev/null \
        | grep -q "^$1.*(installed)"
}

echo "== ThreadSanitizer lane (server / pool / queue tests) =="
if ! have_nightly; then
    echo "   SKIPPED: no nightly toolchain (TSan needs -Z sanitizer=thread)"
elif ! nightly_component "rust-src"; then
    echo "   SKIPPED: nightly rust-src component missing (TSan rebuilds std via -Z build-std)"
else
    HOST="$(rustc -vV | sed -n 's/^host: //p')"
    RUSTFLAGS="-Z sanitizer=thread" RUSTDOCFLAGS="-Z sanitizer=thread" \
        cargo +nightly test -Z build-std --target "$HOST" \
        --lib -- server:: util::pool:: util::sync::
    RUSTFLAGS="-Z sanitizer=thread" RUSTDOCFLAGS="-Z sanitizer=thread" \
        cargo +nightly test -Z build-std --target "$HOST" \
        --test server_api
    echo "   TSan lane OK"
fi

echo "== Miri lane (util / tensor unit tests) =="
if ! have_nightly; then
    echo "   SKIPPED: no nightly toolchain (Miri is nightly-only)"
elif ! nightly_component "miri"; then
    echo "   SKIPPED: nightly miri component missing (rustup +nightly component add miri)"
else
    # the server tests do real socket I/O, which Miri does not model;
    # scope Miri to the pure-compute core
    cargo +nightly miri test --lib -- util::json:: util::prng:: tensor::
    echo "   Miri lane OK"
fi

echo "analyze.sh OK"
