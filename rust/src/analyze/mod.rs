//! `sparsefw analyze` — a project-invariant static-analysis pass.
//!
//! The server/coordinator stack is built entirely on std
//! `Mutex`/`Condvar`/`thread::spawn`; the invariants that keep it safe
//! (lock ordering, panic-free request paths, registry/codec
//! consistency) were convention until this module.  `analyze` tokenizes
//! the crate's own sources with the hand-rolled lexer in
//! [`lexer`] (same no-dependency discipline as [`crate::util::json`])
//! and enforces three lint families, each reporting `file:line`
//! diagnostics:
//!
//! | lint | family | what it flags |
//! |------|--------|---------------|
//! | `lock-order` | concurrency | two locks acquired in inconsistent order across the codebase (incl. re-entrant self-cycles) |
//! | `lock-across-blocking` | concurrency | a lock guard held across blocking I/O, `Condvar::wait` on a different lock, or a progress-callback invocation |
//! | `panic-path` | panic paths | `unwrap()` / `expect()` / `panic!`-family macros in request-serving code |
//! | `unchecked-index` | panic paths | `x[i]` indexing in request-serving code |
//! | `registry-coverage` | consistency | a registered method missing from the registry test, the `table1_methods` bench, or USAGE |
//! | `metrics-coverage` | consistency | a metric in [`crate::server::METRIC_CATALOG`] missing from the USAGE metric catalog |
//! | `route-coverage` | consistency | a route in the server's API dispatch (`server/api.rs`) missing from the USAGE endpoint table |
//! | `codec-fields` | consistency | a `to_json`/`from_json` pair whose key sets differ |
//! | `unbounded-retry` | robustness | a `loop`/`while` retry loop with neither an attempt cap nor a deadline |
//! | `stale-allow` | meta | an `// analyze: allow(..)` annotation that no longer suppresses anything |
//!
//! False positives are silenced in place:
//!
//! ```text
//! // analyze: allow(lock-across-blocking, "stderr lock makes the write atomic")
//! ```
//!
//! on the offending line or the line directly above it.  Every allow
//! must keep earning its place — one that stops matching a finding is
//! itself reported as `stale-allow`, so suppressions can't outlive the
//! code they excused.
//!
//! Adding a lint: implement `fn check(file: &SourceFile, out: &mut
//! Vec<Finding>)` in a submodule, give the lint a kebab-case name, call
//! it from [`analyze_tree`], and add a violating + allow-annotated
//! fixture pair under `rust/tests/analyze_fixtures/`.

pub mod consistency;
pub mod lexer;
pub mod locks;
pub mod panics;
pub mod retries;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use lexer::{lex, Lexed};

/// One diagnostic.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Path relative to the analysis root (slash-separated).
    pub file: String,
    pub line: u32,
    /// Kebab-case lint name (`lock-order`, `panic-path`, …).
    pub lint: String,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: warning[{}]: {}",
            self.file, self.line, self.lint, self.message
        )
    }
}

/// A parsed `// analyze: allow(<lint>, "<reason>")` annotation.
#[derive(Debug, Clone)]
pub struct Allow {
    pub line: u32,
    pub lint: String,
    #[allow(dead_code)]
    pub reason: String,
}

/// One lexed source file, ready for the lint passes.
pub struct SourceFile {
    /// Path relative to the analysis root (slash-separated).
    pub rel: String,
    pub lexed: Lexed,
    pub allows: Vec<Allow>,
    /// Token-index ranges (inclusive) of `#[cfg(test)]` / `#[test]`
    /// code, which every lint skips.
    pub test_ranges: Vec<(usize, usize)>,
}

impl SourceFile {
    pub fn parse(rel: &str, src: &str) -> Self {
        let lexed = lex(src);
        let allows = parse_allows(&lexed);
        let test_ranges = lexer::test_ranges(&lexed.tokens);
        SourceFile { rel: rel.to_string(), lexed, allows, test_ranges }
    }

    /// True when token index `i` falls inside test-only code.
    pub fn in_test(&self, i: usize) -> bool {
        self.test_ranges.iter().any(|&(s, e)| i >= s && i <= e)
    }

    /// True when a marker comment `// analyze: request-path` appears in
    /// the file (fixtures use it to opt into the panic-path lints
    /// without living under `server/`).  The marker must start the
    /// comment — doc comments merely *mentioning* it (like this one)
    /// begin with `//!`/`///` and don't count.
    pub fn has_request_path_marker(&self) -> bool {
        self.lexed.comments.iter().any(|(_, c)| {
            c.trim_start_matches('/')
                .trim()
                .starts_with("analyze: request-path")
        })
    }
}

fn parse_allows(lexed: &Lexed) -> Vec<Allow> {
    let mut out = Vec::new();
    for (line, text) in &lexed.comments {
        let Some(rest) = text
            .trim_start_matches('/')
            .trim()
            .strip_prefix("analyze: allow(")
        else {
            continue;
        };
        let Some(body) = rest.split(')').next() else { continue };
        let mut parts = body.splitn(2, ',');
        let lint = parts.next().unwrap_or("").trim().to_string();
        let reason = parts
            .next()
            .unwrap_or("")
            .trim()
            .trim_matches('"')
            .to_string();
        if !lint.is_empty() {
            out.push(Allow { line: *line, lint, reason });
        }
    }
    out
}

/// What to analyze and how.
pub struct AnalyzeConfig {
    /// Root of the source tree (`rust/src` in the repo).
    pub src_root: PathBuf,
    /// Relative path prefixes (slash-separated) whose files are
    /// request-serving: the panic-path lints apply there.
    pub panic_paths: Vec<String>,
    /// Run the registry-coverage lint (needs the process's registry and
    /// the sibling `tests/` + `benches/` dirs; fixture runs disable it).
    pub check_registry: bool,
}

impl AnalyzeConfig {
    pub fn new(src_root: impl Into<PathBuf>) -> Self {
        AnalyzeConfig {
            src_root: src_root.into(),
            panic_paths: vec!["server/".to_string()],
            check_registry: true,
        }
    }
}

/// Run every lint over the tree at `cfg.src_root`; returns findings
/// sorted by file, line, lint.  Allow annotations are applied here, and
/// stale allows are converted into `stale-allow` findings.
pub fn analyze_tree(cfg: &AnalyzeConfig) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(&cfg.src_root, &cfg.src_root, &mut files)?;
    files.sort();

    let mut sources = Vec::new();
    for rel in &files {
        let path = cfg.src_root.join(rel);
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        sources.push(SourceFile::parse(rel, &src));
    }

    let mut findings = Vec::new();

    // concurrency lints see the whole tree at once (the lock graph is
    // cross-file); panic lints are per-file
    locks::check(&sources, &mut findings);
    for sf in &sources {
        let applies = cfg
            .panic_paths
            .iter()
            .any(|p| sf.rel.starts_with(p.as_str()))
            || sf.has_request_path_marker();
        if applies {
            panics::check(sf, &mut findings);
        }
        consistency::check_codecs(sf, &mut findings);
        retries::check(sf, &mut findings);
    }
    if cfg.check_registry {
        consistency::check_registry(&cfg.src_root, &mut findings);
        consistency::check_metrics_usage(&cfg.src_root, &mut findings);
        consistency::check_routes_usage(&cfg.src_root, &mut findings);
    }

    let findings = apply_allows(&sources, findings);
    Ok(findings)
}

/// Suppress findings covered by an allow on the same or preceding
/// line; report allows that suppressed nothing.
fn apply_allows(sources: &[SourceFile], findings: Vec<Finding>) -> Vec<Finding> {
    let mut used: Vec<Vec<bool>> = sources
        .iter()
        .map(|sf| vec![false; sf.allows.len()])
        .collect();
    let mut out = Vec::new();
    'finding: for f in findings {
        for (si, sf) in sources.iter().enumerate() {
            if sf.rel != f.file {
                continue;
            }
            for (ai, a) in sf.allows.iter().enumerate() {
                if a.lint == f.lint && (a.line == f.line || a.line + 1 == f.line) {
                    used[si][ai] = true;
                    continue 'finding;
                }
            }
        }
        out.push(f);
    }
    for (si, sf) in sources.iter().enumerate() {
        for (ai, a) in sf.allows.iter().enumerate() {
            if !used[si][ai] {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: a.line,
                    lint: "stale-allow".to_string(),
                    message: format!(
                        "allow({}) no longer matches any finding; remove it",
                        a.lint
                    ),
                });
            }
        }
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint.as_str())
            .cmp(&(b.file.as_str(), b.line, b.lint.as_str()))
    });
    out
}

fn collect_rs_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("reading dir {}", dir.display()))?
    {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}
