//! Minimal Rust tokenizer for the static-analysis pass.
//!
//! Same discipline as [`crate::util::json`]: the offline registry has
//! no `syn`/`proc-macro2`, so this module lexes just enough of the Rust
//! grammar for token-level lints — identifiers, punctuation, string /
//! char / numeric literals, lifetimes — with line numbers, and collects
//! comments separately (the `// analyze: allow(..)` escape hatch lives
//! in comment text).  It is a *lexer*, not a parser: lints that need
//! structure (function bodies, impl blocks, `#[cfg(test)]` regions)
//! recover it from brace matching over the token stream.
//!
//! Deliberately not handled: macro expansion (tokens inside macro
//! invocations are lexed like any other code), shebangs, and the
//! `c"…"` literal family newer than this crate's edition.

/// One lexed token (comments and whitespace are stripped; see
/// [`Lexed::comments`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    /// String literal (cooked content, escapes left as written).
    Str(String),
    /// Char literal (content irrelevant to every lint).
    Char,
    /// Numeric literal (value irrelevant to every lint).
    Num,
    /// Lifetime (`'a`), distinguished from char literals.
    Life,
    /// One punctuation byte (`.`, `(`, `{`, `!`, …).  Multi-byte
    /// operators arrive as consecutive tokens (`:` `:` for `::`).
    P(char),
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(self, Tok::Ident(i) if i == s)
    }

    pub fn is_p(&self, c: char) -> bool {
        matches!(self, Tok::P(p) if *p == c)
    }

    pub fn ident(&self) -> Option<&str> {
        match self {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn str_lit(&self) -> Option<&str> {
        match self {
            Tok::Str(s) => Some(s),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    /// 1-based source line.
    pub line: u32,
}

/// Lexer output: the token stream plus every comment with its line
/// (attribute annotations like `// analyze: allow(..)` are comments).
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub comments: Vec<(u32, String)>,
}

/// Tokenize `src`.  Never fails: unrecognized bytes are skipped (the
/// analyzer lints real source that already compiled, so error recovery
/// beats error reporting here).
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;

    macro_rules! push {
        ($tok:expr) => {
            out.tokens.push(Token { tok: $tok, line })
        };
    }

    while i < b.len() {
        let c = b[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_ascii_whitespace() => i += 1,
            // line comment (also doc comments ///, //!)
            b'/' if b.get(i + 1) == Some(&b'/') => {
                let start = i;
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
                let text = String::from_utf8_lossy(&b[start..i]).into_owned();
                out.comments.push((line, text));
            }
            // block comment, nested per the Rust grammar
            b'/' if b.get(i + 1) == Some(&b'*') => {
                let start = i;
                let start_line = line;
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == b'\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == b'/' && b.get(i + 1) == Some(&b'*') {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && b.get(i + 1) == Some(&b'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                let text = String::from_utf8_lossy(&b[start..i.min(b.len())]).into_owned();
                out.comments.push((start_line, text));
            }
            // raw strings r"…", r#"…"#, and byte-raw br#"…"#
            b'r' | b'b' if raw_str_start(b, i).is_some() => {
                let (content_at, hashes) = match raw_str_start(b, i) {
                    Some(x) => x,
                    None => unreachable!(),
                };
                i = content_at;
                let start = i;
                let close: Vec<u8> = std::iter::once(b'"')
                    .chain(std::iter::repeat(b'#').take(hashes))
                    .collect();
                while i < b.len() && !b[i..].starts_with(&close) {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
                let text = String::from_utf8_lossy(&b[start..i.min(b.len())]).into_owned();
                push!(Tok::Str(text));
                i = (i + close.len()).min(b.len());
            }
            // byte string b"…"
            b'b' if b.get(i + 1) == Some(&b'"') => {
                i += 1; // fall into the cooked-string scanner below
                let (s, ni, nl) = cooked_string(b, i, line);
                push!(Tok::Str(s));
                i = ni;
                line = nl;
            }
            b'"' => {
                let (s, ni, nl) = cooked_string(b, i, line);
                push!(Tok::Str(s));
                i = ni;
                line = nl;
            }
            // lifetime vs char literal: 'a followed by non-' is a
            // lifetime; anything else quote-delimited is a char
            b'\'' => {
                let is_life = matches!(b.get(i + 1), Some(c) if is_ident_byte(*c))
                    && b.get(i + 2) != Some(&b'\'');
                if is_life {
                    i += 1;
                    while i < b.len() && is_ident_byte(b[i]) {
                        i += 1;
                    }
                    push!(Tok::Life);
                } else {
                    // char literal: skip escapes, find the closing quote
                    i += 1;
                    if b.get(i) == Some(&b'\\') {
                        i += 2;
                    } else {
                        i += 1;
                    }
                    while i < b.len() && b[i] != b'\'' {
                        i += 1;
                    }
                    i += 1;
                    push!(Tok::Char);
                }
            }
            c if c.is_ascii_digit() => {
                while i < b.len() && (is_ident_byte(b[i]) || b[i] == b'.') {
                    // `0..n` range: the dots are punctuation, not a float
                    if b[i] == b'.' && b.get(i + 1) == Some(&b'.') {
                        break;
                    }
                    i += 1;
                }
                push!(Tok::Num);
            }
            c if is_ident_start(c) => {
                let start = i;
                while i < b.len() && is_ident_byte(b[i]) {
                    i += 1;
                }
                let s = String::from_utf8_lossy(&b[start..i]).into_owned();
                push!(Tok::Ident(s));
            }
            c if c.is_ascii() => {
                push!(Tok::P(c as char));
                i += 1;
            }
            // multi-byte UTF-8 outside strings/comments (e.g. in an
            // ident we don't support): skip the sequence
            _ => {
                i += 1;
                while i < b.len() && (b[i] & 0xC0) == 0x80 {
                    i += 1;
                }
            }
        }
    }
    out
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic()
}

fn is_ident_byte(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// If `b[i..]` starts a raw (or byte-raw) string, return
/// `(content_start, hash_count)`.
fn raw_str_start(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Scan a cooked string starting at the opening quote; returns
/// `(content, next_index, next_line)`.
fn cooked_string(b: &[u8], open: usize, mut line: u32) -> (String, usize, u32) {
    let mut i = open + 1;
    let start = i;
    while i < b.len() {
        match b[i] {
            b'"' => break,
            b'\\' => i = (i + 2).min(b.len()),
            b'\n' => {
                line += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    let s = String::from_utf8_lossy(&b[start..i.min(b.len())]).into_owned();
    ((s), (i + 1).min(b.len()), line)
}

// ---------------------------------------------------------------------------
// Structure recovery over the token stream
// ---------------------------------------------------------------------------

/// Index of the `}` matching the `{` at `open` (or the last token if
/// the stream is truncated).
pub fn matching_brace(tokens: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (j, t) in tokens.iter().enumerate().skip(open) {
        match t.tok {
            Tok::P('{') => depth += 1,
            Tok::P('}') => {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
            _ => {}
        }
    }
    tokens.len().saturating_sub(1)
}

/// Token-index ranges (inclusive) of test-only code: `#[cfg(test)]`
/// mod bodies and `#[test]` functions.  Lints skip findings inside.
pub fn test_ranges(tokens: &[Token]) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        if tokens[i].tok.is_p('#') && tokens.get(i + 1).is_some_and(|t| t.tok.is_p('[')) {
            // collect the attribute tokens up to the matching ']'
            let mut j = i + 2;
            let mut depth = 1;
            let mut attr = Vec::new();
            while j < tokens.len() && depth > 0 {
                match &tokens[j].tok {
                    Tok::P('[') => depth += 1,
                    Tok::P(']') => depth -= 1,
                    t => attr.push(t.clone()),
                }
                j += 1;
            }
            let is_cfg_test = attr.first().is_some_and(|t| t.is_ident("cfg"))
                && attr.iter().any(|t| t.is_ident("test"));
            let is_test_attr = attr.len() == 1 && attr[0].is_ident("test");
            if is_cfg_test || is_test_attr {
                // find the next `{` (the mod/fn body) and span it
                let mut k = j;
                while k < tokens.len() && !tokens[k].tok.is_p('{') {
                    // a cfg(test) on a non-block item (e.g. `use`) ends
                    // at `;` — nothing to span
                    if tokens[k].tok.is_p(';') {
                        break;
                    }
                    k += 1;
                }
                if k < tokens.len() && tokens[k].tok.is_p('{') {
                    let end = matching_brace(tokens, k);
                    out.push((i, end));
                    i = end + 1;
                    continue;
                }
            }
            i = j;
            continue;
        }
        i += 1;
    }
    out
}

/// One `fn` body found in the stream, with its enclosing impl type (the
/// last path segment of `impl … [for] Type`), if any.
#[derive(Debug)]
pub struct FnSpan {
    pub name: String,
    pub impl_type: Option<String>,
    /// Token index of the body's `{`.
    pub body_open: usize,
    /// Token index of the body's `}`.
    pub body_close: usize,
}

/// Locate every function body and its enclosing `impl` type.
pub fn fn_spans(tokens: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    // (impl_type, close_index) stack entries
    let mut impls: Vec<(String, usize)> = Vec::new();
    let mut i = 0;
    while i < tokens.len() {
        impls.retain(|&(_, close)| i <= close);
        match &tokens[i].tok {
            Tok::Ident(kw) if kw == "impl" => {
                if let Some((ty, open)) = impl_header(tokens, i) {
                    let close = matching_brace(tokens, open);
                    impls.push((ty, close));
                    i = open + 1;
                    continue;
                }
            }
            Tok::Ident(kw) if kw == "fn" => {
                let name = tokens
                    .get(i + 1)
                    .and_then(|t| t.tok.ident().map(String::from))
                    .unwrap_or_default();
                // body starts at the first `{` before any `;` (a trait
                // method declaration has no body)
                let mut j = i + 2;
                while j < tokens.len()
                    && !tokens[j].tok.is_p('{')
                    && !tokens[j].tok.is_p(';')
                {
                    j += 1;
                }
                if j < tokens.len() && tokens[j].tok.is_p('{') {
                    let close = matching_brace(tokens, j);
                    out.push(FnSpan {
                        name,
                        impl_type: impls.last().map(|(t, _)| t.clone()),
                        body_open: j,
                        body_close: close,
                    });
                    // walk *into* the body: nested fns are rare and
                    // their sites then attribute to the outer fn, which
                    // is fine for diagnostics
                    i = j + 1;
                    continue;
                }
                i = j + 1;
                continue;
            }
            _ => {}
        }
        i += 1;
    }
    out
}

/// Parse an `impl` header starting at token `at`; returns the impl'd
/// type's last path segment and the index of the body's `{`.
fn impl_header(tokens: &[Token], at: usize) -> Option<(String, usize)> {
    let mut i = at + 1;
    // skip generic params `<…>`
    i = skip_generics(tokens, i);
    let first = read_path_segment(tokens, &mut i)?;
    // `impl Trait for Type` — the type is what we scope by
    let mut ty = first;
    loop {
        match tokens.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(kw)) if kw == "for" => {
                i += 1;
                i = skip_generics(tokens, i);
                ty = read_path_segment(tokens, &mut i)?;
            }
            Some(Tok::P('{')) => return Some((ty, i)),
            Some(Tok::P(';')) | None => return None,
            _ => i += 1,
        }
    }
}

/// Read `a::b::C<…>` at `*i`, returning the last segment (`C`).
fn read_path_segment(tokens: &[Token], i: &mut usize) -> Option<String> {
    let mut last = None;
    loop {
        match tokens.get(*i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => {
                last = Some(s.clone());
                *i += 1;
            }
            Some(Tok::P(':')) => *i += 1,
            Some(Tok::P('<')) => {
                *i = skip_generics(tokens, *i);
                break;
            }
            Some(Tok::P('&')) | Some(Tok::Life) => *i += 1,
            _ => break,
        }
    }
    last
}

/// If `tokens[i]` is `<`, skip the balanced `<…>` group.
fn skip_generics(tokens: &[Token], i: usize) -> usize {
    if !tokens.get(i).is_some_and(|t| t.tok.is_p('<')) {
        return i;
    }
    let mut depth = 0i32;
    let mut j = i;
    while j < tokens.len() {
        match tokens[j].tok {
            Tok::P('<') => depth += 1,
            Tok::P('>') => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .iter()
            .filter_map(|t| t.tok.ident().map(String::from))
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let l = lex("let x = a.lock();\nlet y = 2; // hi\n/* multi\nline */ z");
        assert_eq!(
            idents("let x = a.lock();"),
            vec!["let", "x", "a", "lock"]
        );
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0], (2, "// hi".to_string()));
        assert_eq!(l.comments[1].0, 3);
        // `z` sits on line 4 (the block comment spans 3–4)
        let z = l.tokens.iter().find(|t| t.tok.is_ident("z")).unwrap();
        assert_eq!(z.line, 4);
    }

    #[test]
    fn strings_chars_lifetimes() {
        let l = lex(r##"f("a \" b", 'x', '\n', r#"raw " here"# , b"bytes"); <'a, T>"##);
        let strs: Vec<&str> = l.tokens.iter().filter_map(|t| t.tok.str_lit()).collect();
        assert_eq!(strs, vec![r#"a \" b"#, r#"raw " here"#, "bytes"]);
        assert_eq!(
            l.tokens.iter().filter(|t| t.tok == Tok::Char).count(),
            2
        );
        assert_eq!(l.tokens.iter().filter(|t| t.tok == Tok::Life).count(), 1);
    }

    #[test]
    fn range_is_not_a_float() {
        let l = lex("for i in 0..n {}");
        assert!(l.tokens.iter().any(|t| t.tok == Tok::Num));
        assert_eq!(
            l.tokens.iter().filter(|t| t.tok.is_p('.')).count(),
            2,
            "range dots survive as punctuation"
        );
    }

    #[test]
    fn test_ranges_cover_cfg_test_mod_and_test_fn() {
        let src = "fn live() { a.unwrap(); }\n\
                   #[cfg(test)]\nmod tests { fn t() { b.unwrap(); } }\n\
                   #[test]\nfn unit() { c.unwrap(); }";
        let l = lex(src);
        let ranges = test_ranges(&l.tokens);
        assert_eq!(ranges.len(), 2);
        let in_test = |name: &str| {
            let idx = l
                .tokens
                .iter()
                .position(|t| t.tok.is_ident(name))
                .unwrap();
            ranges.iter().any(|&(s, e)| idx >= s && idx <= e)
        };
        assert!(!in_test("a"));
        assert!(in_test("b"));
        assert!(in_test("c"));
    }

    #[test]
    fn fn_spans_see_impl_types() {
        let src = "impl Foo { fn a(&self) {} }\n\
                   impl<T: Clone> Bar<T> for Baz<'_, T> { fn b() { { } } }\n\
                   fn free() {}";
        let l = lex(src);
        let spans = fn_spans(&l.tokens);
        let by_name: Vec<(String, Option<String>)> = spans
            .iter()
            .map(|s| (s.name.clone(), s.impl_type.clone()))
            .collect();
        assert_eq!(
            by_name,
            vec![
                ("a".into(), Some("Foo".into())),
                ("b".into(), Some("Baz".into())),
                ("free".into(), None),
            ]
        );
    }
}
