//! Cross-surface consistency lints.
//!
//! `codec-fields`: a `to_json`/`from_json` pair (same `impl` block, or
//! same-file `<prefix>_to_json`/`<prefix>_from_json` free functions)
//! must cover the same key set — a field written but never read back
//! (or vice versa) silently corrupts round-trips.  Keys are extracted
//! token-wise: `("key", value)` tuples on the writer side, `get("key")`
//! / `at(&["key", ..])` on the reader side; only snake_case literals
//! count (format strings and labels don't look like keys).
//!
//! `registry-coverage`: every method in
//! [`crate::pruner::MethodRegistry::global`] must appear in
//! `tests/method_registry.rs` (as a quoted literal), in the
//! `table1_methods` bench (quoted literal, or the bench iterates
//! `MethodRegistry::global()` and covers everything by construction),
//! and in the USAGE text in `src/main.rs`.  These findings point at
//! the surface that's missing the method and cannot be `allow`ed —
//! coverage gaps get fixed, not excused.
//!
//! `metrics-coverage`: every metric in
//! [`crate::server::METRIC_CATALOG`] (the list `GET
//! /metrics?format=prometheus` renders) must be documented in the
//! USAGE metric catalog in `src/main.rs` — operators discover metrics
//! from the USAGE table, so an undocumented metric is invisible and a
//! renamed one leaves the docs lying.  Like `registry-coverage`, these
//! findings cannot be `allow`ed.
//!
//! `route-coverage`: every route the server's request dispatch matches
//! (`src/server/api.rs`, the `route()` match arms of the shape
//! `("METHOD", ["seg", id, …])`) must appear in the USAGE endpoint
//! table in `src/main.rs`, rendered as `/seg/:id/…`.  A route shipped
//! without docs is an API nobody can discover; a renamed one leaves
//! the table lying.  Guarded arms and `..` rest-patterns (the 405/404
//! fallbacks) are skipped.

use std::collections::BTreeMap;
use std::path::Path;

use super::lexer::{fn_spans, Tok, Token};
use super::{Finding, SourceFile};

/// A key literal with the line it appears on.
type Keys = Vec<(String, u32)>;

pub fn check_codecs(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.lexed.tokens;
    // pair id -> (to_json keys, from_json keys)
    let mut pairs: BTreeMap<String, (Option<Keys>, Option<Keys>)> = BTreeMap::new();
    for span in fn_spans(toks) {
        if sf.in_test(span.body_open) {
            continue;
        }
        let (is_to, pair_id) = match codec_role(&span.name) {
            Some(x) => x,
            None => continue,
        };
        // scope free-fn prefixes by file, impl methods by type
        let scope = span
            .impl_type
            .clone()
            .unwrap_or_else(|| format!("{}::", sf.rel));
        let id = format!("{scope}{pair_id}");
        let entry = pairs.entry(id).or_default();
        let body = &toks[span.body_open..=span.body_close.min(toks.len() - 1)];
        if is_to {
            entry.0 = Some(writer_keys(body));
        } else {
            entry.1 = Some(reader_keys(body));
        }
    }
    for (_, (w, r)) in pairs {
        let (Some(w), Some(r)) = (w, r) else { continue };
        // compare only when both sides actually extract keys (a codec
        // that delegates wholesale has nothing token-visible to check)
        if w.is_empty() || r.is_empty() {
            continue;
        }
        for (k, line) in &w {
            if !r.iter().any(|(rk, _)| rk == k) {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: *line,
                    lint: "codec-fields".into(),
                    message: format!(
                        "to_json writes key `{k}` but the paired from_json never \
                         reads it"
                    ),
                });
            }
        }
        for (k, line) in &r {
            if !w.iter().any(|(wk, _)| wk == k) {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: *line,
                    lint: "codec-fields".into(),
                    message: format!(
                        "from_json reads key `{k}` but the paired to_json never \
                         writes it"
                    ),
                });
            }
        }
    }
}

/// Classify a fn name as a codec half: returns (is_to_json, pair id).
fn codec_role(name: &str) -> Option<(bool, String)> {
    if name == "to_json" {
        return Some((true, "json".into()));
    }
    if name == "from_json" {
        return Some((false, "json".into()));
    }
    if let Some(p) = name.strip_suffix("_to_json") {
        return Some((true, format!("{p}_json")));
    }
    if let Some(p) = name.strip_suffix("_from_json") {
        return Some((false, format!("{p}_json")));
    }
    None
}

fn is_keyish(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// `("key", value)` tuples: a snake_case string literal preceded by
/// `(` and followed by `,`.
fn writer_keys(body: &[Token]) -> Keys {
    let mut out = Keys::new();
    for (i, t) in body.iter().enumerate() {
        let Some(s) = t.tok.str_lit() else { continue };
        let tupled = i > 0
            && body[i - 1].tok.is_p('(')
            && body.get(i + 1).is_some_and(|t| t.tok.is_p(','));
        if tupled && is_keyish(s) && !out.iter().any(|(k, _)| k == s) {
            out.push((s.to_string(), t.line));
        }
    }
    out
}

/// Literals inside `get("…")` and `at(&["…", …])` calls.
fn reader_keys(body: &[Token]) -> Keys {
    let mut out = Keys::new();
    let mut push = |s: &str, line: u32| {
        if is_keyish(s) && !out.iter().any(|(k, _)| k == s) {
            out.push((s.to_string(), line));
        }
    };
    let mut i = 0;
    while i < body.len() {
        match body[i].tok.ident() {
            Some("get") if body.get(i + 1).is_some_and(|t| t.tok.is_p('(')) => {
                if let Some(t) = body.get(i + 2) {
                    if let Some(s) = t.tok.str_lit() {
                        push(s, t.line);
                    }
                }
                i += 3;
            }
            Some("at") if body.get(i + 1).is_some_and(|t| t.tok.is_p('(')) => {
                // collect every literal up to the matching `)`
                let mut depth = 0usize;
                let mut j = i + 1;
                while j < body.len() {
                    match &body[j].tok {
                        Tok::P('(') => depth += 1,
                        Tok::P(')') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        Tok::Str(s) => push(s, body[j].line),
                        _ => {}
                    }
                    j += 1;
                }
                i = j;
            }
            _ => i += 1,
        }
    }
    out
}

/// Surfaces every registered method must appear on.
pub fn check_registry(src_root: &Path, out: &mut Vec<Finding>) {
    let names = crate::pruner::MethodRegistry::global().names();
    let root = src_root.parent().unwrap_or(src_root);
    let surfaces: &[(&str, &Path, bool)] = &[
        // (label, path, iteration-marker satisfies)
        ("tests/method_registry.rs", Path::new("tests/method_registry.rs"), false),
        ("benches/table1_methods.rs", Path::new("benches/table1_methods.rs"), true),
        ("src/main.rs (USAGE)", Path::new("src/main.rs"), false),
    ];
    for (label, rel, marker_ok) in surfaces {
        let path = root.join(rel);
        let Ok(text) = std::fs::read_to_string(&path) else {
            out.push(Finding {
                file: label.to_string(),
                line: 0,
                lint: "registry-coverage".into(),
                message: format!("surface file missing or unreadable: {}", path.display()),
            });
            continue;
        };
        if *marker_ok && text.contains("MethodRegistry::global()") {
            // the bench iterates the registry: every method is covered
            // by construction
            continue;
        }
        for name in &names {
            let quoted = format!("\"{name}\"");
            let covered = if label.ends_with("(USAGE)") {
                text.contains(name.as_str())
            } else {
                text.contains(&quoted)
            };
            if !covered {
                out.push(Finding {
                    file: label.to_string(),
                    line: 0,
                    lint: "registry-coverage".into(),
                    message: format!(
                        "registered method `{name}` does not appear in {label}"
                    ),
                });
            }
        }
    }
}

/// Every metric in [`crate::server::METRIC_CATALOG`] must appear in
/// `src/main.rs` (the USAGE metric catalog) — the Prometheus surface
/// and the user-facing docs must not drift.
pub fn check_metrics_usage(src_root: &Path, out: &mut Vec<Finding>) {
    let label = "src/main.rs (USAGE)";
    let root = src_root.parent().unwrap_or(src_root);
    let path = root.join("src/main.rs");
    let Ok(text) = std::fs::read_to_string(&path) else {
        out.push(Finding {
            file: label.to_string(),
            line: 0,
            lint: "metrics-coverage".into(),
            message: format!("surface file missing or unreadable: {}", path.display()),
        });
        return;
    };
    for &(name, kind, _) in crate::server::METRIC_CATALOG {
        if !text.contains(name) {
            out.push(Finding {
                file: label.to_string(),
                line: 0,
                lint: "metrics-coverage".into(),
                message: format!(
                    "{kind} metric `{name}` (server METRIC_CATALOG) is not \
                     documented in the USAGE metric catalog"
                ),
            });
        }
    }
}

/// Extract the documentable routes from request-dispatch source text:
/// every single-line match arm of the shape `("METHOD", ["seg", id])`
/// becomes `(METHOD, /seg/:id)` — string-literal segments stay
/// literal, bare identifiers render as `:name` placeholders.  Guarded
/// arms (` if `) and `..` rest-patterns (the 405/404 fallbacks) are
/// not routes and are skipped.
pub fn routes_in(text: &str) -> Vec<(String, String)> {
    const METHODS: &[&str] = &["GET", "POST", "PUT", "PATCH", "DELETE"];
    let mut out = Vec::new();
    for line in text.lines() {
        let Some(open) = line.find("(\"") else { continue };
        let rest = &line[open + 2..];
        let Some(method) = METHODS
            .iter()
            .find(|m| rest.strip_prefix(**m).is_some_and(|r| r.starts_with("\", [")))
        else {
            continue;
        };
        let after = &rest[method.len() + 4..]; // past `", [`
        let Some(end) = after.find(']') else { continue };
        let list = &after[..end];
        if after[end..].contains(" if ") || list.contains("..") {
            continue;
        }
        let mut segs = Vec::new();
        let mut well_formed = true;
        for seg in list.split(',') {
            let seg = seg.trim();
            if seg.is_empty() {
                continue;
            }
            if let Some(lit) = seg.strip_prefix('"').and_then(|s| s.strip_suffix('"')) {
                segs.push(lit.to_string());
            } else if seg.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                segs.push(format!(":{seg}"));
            } else {
                well_formed = false;
            }
        }
        if well_formed && !segs.is_empty() {
            out.push((method.to_string(), format!("/{}", segs.join("/"))));
        }
    }
    out
}

/// Every route the server's API dispatch matches
/// (`src/server/api.rs`) must appear — as its `/seg/:id` path — in the
/// USAGE endpoint table in `src/main.rs`.  Like the other coverage
/// lints, these findings cannot be `allow`ed.
pub fn check_routes_usage(src_root: &Path, out: &mut Vec<Finding>) {
    let label = "src/main.rs (USAGE)";
    let root = src_root.parent().unwrap_or(src_root);
    let api_path = root.join("src/server/api.rs");
    let Ok(api_text) = std::fs::read_to_string(&api_path) else {
        out.push(Finding {
            file: "src/server/api.rs".to_string(),
            line: 0,
            lint: "route-coverage".into(),
            message: format!("surface file missing or unreadable: {}", api_path.display()),
        });
        return;
    };
    let usage_path = root.join("src/main.rs");
    let Ok(usage_text) = std::fs::read_to_string(&usage_path) else {
        out.push(Finding {
            file: label.to_string(),
            line: 0,
            lint: "route-coverage".into(),
            message: format!("surface file missing or unreadable: {}", usage_path.display()),
        });
        return;
    };
    for (method, path) in routes_in(&api_text) {
        // the USAGE table lines METHOD and path up in columns, so the
        // path string alone is the stable token to require
        if !usage_text.contains(&path) {
            out.push(Finding {
                file: label.to_string(),
                line: 0,
                lint: "route-coverage".into(),
                message: format!(
                    "route `{method} {path}` (server api.rs dispatch) is not \
                     documented in the USAGE endpoint table"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_parser_extracts_paths() {
        let src = r#"
            match (req.method.as_str(), segs.as_slice()) {
                ("GET", ["healthz"]) => healthz(state),
                ("POST", ["jobs"]) => submit(req),
                ("GET", ["jobs", id]) => status(id),
                ("POST", ["jobs", id, "eval"]) => eval_job(req, state, id),
                ("GET", [a, id, c]) if a == "jobs" && c == "events" => stream(id),
                (_, ["jobs", ..]) | (_, ["healthz"]) => not_allowed(),
            }
        "#;
        let routes = routes_in(src);
        assert!(routes.contains(&("GET".to_string(), "/healthz".to_string())));
        assert!(routes.contains(&("POST".to_string(), "/jobs".to_string())));
        assert!(routes.contains(&("GET".to_string(), "/jobs/:id".to_string())));
        assert!(routes.contains(&("POST".to_string(), "/jobs/:id/eval".to_string())));
        // guarded arms and `..` rest-pattern fallbacks are not routes
        assert_eq!(routes.len(), 4);
    }

    #[test]
    fn live_dispatch_routes_parse() {
        // the real dispatch must yield the full route set (guard rail:
        // if route() is refactored into a shape routes_in can't read,
        // the route-coverage lint would silently stop checking)
        let text = std::fs::read_to_string("src/server/api.rs").unwrap();
        let routes = routes_in(&text);
        for expect in ["/jobs", "/jobs/:id", "/jobs/:id/eval", "/jobs/:id/generate", "/metrics"] {
            assert!(
                routes.iter().any(|(_, p)| p == expect),
                "route {expect} not parsed from api.rs; got {routes:?}"
            );
        }
    }
}
