//! Cross-surface consistency lints.
//!
//! `codec-fields`: a `to_json`/`from_json` pair (same `impl` block, or
//! same-file `<prefix>_to_json`/`<prefix>_from_json` free functions)
//! must cover the same key set — a field written but never read back
//! (or vice versa) silently corrupts round-trips.  Keys are extracted
//! token-wise: `("key", value)` tuples on the writer side, `get("key")`
//! / `at(&["key", ..])` on the reader side; only snake_case literals
//! count (format strings and labels don't look like keys).
//!
//! `registry-coverage`: every method in
//! [`crate::pruner::MethodRegistry::global`] must appear in
//! `tests/method_registry.rs` (as a quoted literal), in the
//! `table1_methods` bench (quoted literal, or the bench iterates
//! `MethodRegistry::global()` and covers everything by construction),
//! and in the USAGE text in `src/main.rs`.  These findings point at
//! the surface that's missing the method and cannot be `allow`ed —
//! coverage gaps get fixed, not excused.
//!
//! `metrics-coverage`: every metric in
//! [`crate::server::METRIC_CATALOG`] (the list `GET
//! /metrics?format=prometheus` renders) must be documented in the
//! USAGE metric catalog in `src/main.rs` — operators discover metrics
//! from the USAGE table, so an undocumented metric is invisible and a
//! renamed one leaves the docs lying.  Like `registry-coverage`, these
//! findings cannot be `allow`ed.

use std::collections::BTreeMap;
use std::path::Path;

use super::lexer::{fn_spans, Tok, Token};
use super::{Finding, SourceFile};

/// A key literal with the line it appears on.
type Keys = Vec<(String, u32)>;

pub fn check_codecs(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.lexed.tokens;
    // pair id -> (to_json keys, from_json keys)
    let mut pairs: BTreeMap<String, (Option<Keys>, Option<Keys>)> = BTreeMap::new();
    for span in fn_spans(toks) {
        if sf.in_test(span.body_open) {
            continue;
        }
        let (is_to, pair_id) = match codec_role(&span.name) {
            Some(x) => x,
            None => continue,
        };
        // scope free-fn prefixes by file, impl methods by type
        let scope = span
            .impl_type
            .clone()
            .unwrap_or_else(|| format!("{}::", sf.rel));
        let id = format!("{scope}{pair_id}");
        let entry = pairs.entry(id).or_default();
        let body = &toks[span.body_open..=span.body_close.min(toks.len() - 1)];
        if is_to {
            entry.0 = Some(writer_keys(body));
        } else {
            entry.1 = Some(reader_keys(body));
        }
    }
    for (_, (w, r)) in pairs {
        let (Some(w), Some(r)) = (w, r) else { continue };
        // compare only when both sides actually extract keys (a codec
        // that delegates wholesale has nothing token-visible to check)
        if w.is_empty() || r.is_empty() {
            continue;
        }
        for (k, line) in &w {
            if !r.iter().any(|(rk, _)| rk == k) {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: *line,
                    lint: "codec-fields".into(),
                    message: format!(
                        "to_json writes key `{k}` but the paired from_json never \
                         reads it"
                    ),
                });
            }
        }
        for (k, line) in &r {
            if !w.iter().any(|(wk, _)| wk == k) {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: *line,
                    lint: "codec-fields".into(),
                    message: format!(
                        "from_json reads key `{k}` but the paired to_json never \
                         writes it"
                    ),
                });
            }
        }
    }
}

/// Classify a fn name as a codec half: returns (is_to_json, pair id).
fn codec_role(name: &str) -> Option<(bool, String)> {
    if name == "to_json" {
        return Some((true, "json".into()));
    }
    if name == "from_json" {
        return Some((false, "json".into()));
    }
    if let Some(p) = name.strip_suffix("_to_json") {
        return Some((true, format!("{p}_json")));
    }
    if let Some(p) = name.strip_suffix("_from_json") {
        return Some((false, format!("{p}_json")));
    }
    None
}

fn is_keyish(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_lowercase())
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// `("key", value)` tuples: a snake_case string literal preceded by
/// `(` and followed by `,`.
fn writer_keys(body: &[Token]) -> Keys {
    let mut out = Keys::new();
    for (i, t) in body.iter().enumerate() {
        let Some(s) = t.tok.str_lit() else { continue };
        let tupled = i > 0
            && body[i - 1].tok.is_p('(')
            && body.get(i + 1).is_some_and(|t| t.tok.is_p(','));
        if tupled && is_keyish(s) && !out.iter().any(|(k, _)| k == s) {
            out.push((s.to_string(), t.line));
        }
    }
    out
}

/// Literals inside `get("…")` and `at(&["…", …])` calls.
fn reader_keys(body: &[Token]) -> Keys {
    let mut out = Keys::new();
    let mut push = |s: &str, line: u32| {
        if is_keyish(s) && !out.iter().any(|(k, _)| k == s) {
            out.push((s.to_string(), line));
        }
    };
    let mut i = 0;
    while i < body.len() {
        match body[i].tok.ident() {
            Some("get") if body.get(i + 1).is_some_and(|t| t.tok.is_p('(')) => {
                if let Some(t) = body.get(i + 2) {
                    if let Some(s) = t.tok.str_lit() {
                        push(s, t.line);
                    }
                }
                i += 3;
            }
            Some("at") if body.get(i + 1).is_some_and(|t| t.tok.is_p('(')) => {
                // collect every literal up to the matching `)`
                let mut depth = 0usize;
                let mut j = i + 1;
                while j < body.len() {
                    match &body[j].tok {
                        Tok::P('(') => depth += 1,
                        Tok::P(')') => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        Tok::Str(s) => push(s, body[j].line),
                        _ => {}
                    }
                    j += 1;
                }
                i = j;
            }
            _ => i += 1,
        }
    }
    out
}

/// Surfaces every registered method must appear on.
pub fn check_registry(src_root: &Path, out: &mut Vec<Finding>) {
    let names = crate::pruner::MethodRegistry::global().names();
    let root = src_root.parent().unwrap_or(src_root);
    let surfaces: &[(&str, &Path, bool)] = &[
        // (label, path, iteration-marker satisfies)
        ("tests/method_registry.rs", Path::new("tests/method_registry.rs"), false),
        ("benches/table1_methods.rs", Path::new("benches/table1_methods.rs"), true),
        ("src/main.rs (USAGE)", Path::new("src/main.rs"), false),
    ];
    for (label, rel, marker_ok) in surfaces {
        let path = root.join(rel);
        let Ok(text) = std::fs::read_to_string(&path) else {
            out.push(Finding {
                file: label.to_string(),
                line: 0,
                lint: "registry-coverage".into(),
                message: format!("surface file missing or unreadable: {}", path.display()),
            });
            continue;
        };
        if *marker_ok && text.contains("MethodRegistry::global()") {
            // the bench iterates the registry: every method is covered
            // by construction
            continue;
        }
        for name in &names {
            let quoted = format!("\"{name}\"");
            let covered = if label.ends_with("(USAGE)") {
                text.contains(name.as_str())
            } else {
                text.contains(&quoted)
            };
            if !covered {
                out.push(Finding {
                    file: label.to_string(),
                    line: 0,
                    lint: "registry-coverage".into(),
                    message: format!(
                        "registered method `{name}` does not appear in {label}"
                    ),
                });
            }
        }
    }
}

/// Every metric in [`crate::server::METRIC_CATALOG`] must appear in
/// `src/main.rs` (the USAGE metric catalog) — the Prometheus surface
/// and the user-facing docs must not drift.
pub fn check_metrics_usage(src_root: &Path, out: &mut Vec<Finding>) {
    let label = "src/main.rs (USAGE)";
    let root = src_root.parent().unwrap_or(src_root);
    let path = root.join("src/main.rs");
    let Ok(text) = std::fs::read_to_string(&path) else {
        out.push(Finding {
            file: label.to_string(),
            line: 0,
            lint: "metrics-coverage".into(),
            message: format!("surface file missing or unreadable: {}", path.display()),
        });
        return;
    };
    for &(name, kind, _) in crate::server::METRIC_CATALOG {
        if !text.contains(name) {
            out.push(Finding {
                file: label.to_string(),
                line: 0,
                lint: "metrics-coverage".into(),
                message: format!(
                    "{kind} metric `{name}` (server METRIC_CATALOG) is not \
                     documented in the USAGE metric catalog"
                ),
            });
        }
    }
}
