//! Panic-path lints: request-serving code must degrade, not abort.
//!
//! Applies to files under a configured prefix (`server/` by default)
//! or carrying a `// analyze: request-path` marker comment (how the
//! fixtures opt in).  A panic on a connection thread unwinds into
//! `catch_unwind`-free scaffolding, poisons every `Mutex` the frame
//! holds, and turns one bad request into a wedged server — so the
//! request path bans the whole `unwrap`/`expect`/`panic!` family plus
//! unchecked `x[i]` indexing, each individually justifiable with
//! `// analyze: allow(panic-path, "...")` /
//! `// analyze: allow(unchecked-index, "...")`.

use super::lexer::{Tok, Token};
use super::{Finding, SourceFile};

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

/// Keywords that can directly precede an array *literal* (`for x in
/// [..]`) — a `[` after one of these is not an indexing expression.
const KEYWORDS_BEFORE_LITERAL: &[&str] = &[
    "in", "return", "break", "mut", "ref", "move", "as", "else", "match", "if",
];

pub fn check(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.lexed.tokens;
    for (i, t) in toks.iter().enumerate() {
        if sf.in_test(i) {
            continue;
        }
        match &t.tok {
            Tok::P('.') => {
                if let Some(Tok::Ident(m)) = toks.get(i + 1).map(|t| &t.tok) {
                    let called = toks.get(i + 2).is_some_and(|t| t.tok.is_p('('));
                    if called && (m == "unwrap" || m == "expect") {
                        out.push(Finding {
                            file: sf.rel.clone(),
                            line: toks[i + 1].line,
                            lint: "panic-path".into(),
                            message: format!(
                                ".{m}() in request-serving code (return an error or \
                                 recover instead)"
                            ),
                        });
                    }
                }
            }
            Tok::Ident(m)
                if PANIC_MACROS.contains(&m.as_str())
                    && toks.get(i + 1).is_some_and(|t| t.tok.is_p('!')) =>
            {
                out.push(Finding {
                    file: sf.rel.clone(),
                    line: t.line,
                    lint: "panic-path".into(),
                    message: format!("{m}! in request-serving code"),
                });
            }
            // `x[i]`: `[` whose previous token ends an expression.
            // `&[u8]` (type), `[0u8; n]` (literal), and `#[attr]` all
            // have non-expression predecessors and don't match.
            Tok::P('[') if i > 0 => {
                let kw_before = toks[i - 1]
                    .tok
                    .ident()
                    .is_some_and(|s| KEYWORDS_BEFORE_LITERAL.contains(&s));
                let indexes_expr = matches!(
                    toks[i - 1].tok,
                    Tok::Ident(_) | Tok::P(']') | Tok::P(')')
                ) && !kw_before
                    && !is_type_position(toks, i - 1);
                if indexes_expr {
                    out.push(Finding {
                        file: sf.rel.clone(),
                        line: t.line,
                        lint: "unchecked-index".into(),
                        message: "unchecked indexing in request-serving code (use \
                                  .get()/.get_mut() or slice patterns)"
                            .into(),
                    });
                }
            }
            _ => {}
        }
    }
}

/// Heuristic: the identifier before `[` sits in type position when the
/// token before *it* is `:` or `<` (e.g. `Vec<[f32; 4]>`, `x: [u8; 2]`).
fn is_type_position(toks: &[Token], ident_at: usize) -> bool {
    ident_at > 0 && matches!(toks[ident_at - 1].tok, Tok::P(':') | Tok::P('<'))
}
