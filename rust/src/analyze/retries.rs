//! `unbounded-retry` lint: retry loops must carry an explicit bound.
//!
//! A retry loop that can spin forever turns a persistent fault into a
//! hung worker — exactly the failure mode the fault-injection harness
//! ([`crate::util::fault`]) exists to surface.  The fix is always the
//! same: cap the attempts or check a deadline (or both, as
//! [`crate::util::retry::RetryPolicy::run`] does), so a fault that
//! never clears becomes a reported error instead of a silent hang.
//!
//! Heuristic: a `loop` or `while` whose header/body mentions retry
//! vocabulary (`retry`, `reconnect`, `backoff`, …) but no bound
//! vocabulary (`deadline`, `timeout`, `max_attempts`, `remaining`, …)
//! is flagged.  `for` loops are inherently bounded and exempt, as is
//! test code.  False positives are silenced with
//! `// analyze: allow(unbounded-retry, "why this loop terminates")`.

use super::lexer::Token;
use super::{Finding, SourceFile};

/// Identifier substrings (lowercased) that mark a loop as retry-shaped.
const RETRY_WORDS: &[&str] = &["retry", "retries", "retrying", "reconnect", "backoff"];

/// Identifier substrings (lowercased) that count as a termination
/// bound: an attempt cap, a deadline/timeout check, or a shrinking
/// budget.  Matching any one of these classifies the loop as bounded —
/// the lint checks that a bound is *consulted*, not that the arithmetic
/// is right (that is what `util::retry`'s unit tests are for).
const BOUND_WORDS: &[&str] = &[
    "deadline",
    "timeout",
    "expired",
    "remaining",
    "max_attempt",
    "max_retries",
    "budget",
    "give_up",
];

pub fn check(sf: &SourceFile, out: &mut Vec<Finding>) {
    let toks = &sf.lexed.tokens;
    for i in 0..toks.len() {
        let kw = match toks[i].tok.ident() {
            Some(k @ ("loop" | "while")) => k,
            _ => continue,
        };
        // `loop`/`while` are keywords, so every hit is a real loop
        // header (they can't be variable or field names).
        if sf.in_test(i) {
            continue;
        }
        let Some(end) = body_end(toks, i) else {
            continue;
        };
        let mut retryish = false;
        let mut bounded = false;
        // scan header + body: for `while`, the condition sits between
        // the keyword and the `{`, so starting at the keyword covers it
        for t in &toks[i..=end] {
            if let Some(id) = t.tok.ident() {
                let low = id.to_ascii_lowercase();
                if !retryish && RETRY_WORDS.iter().any(|w| low.contains(w)) {
                    retryish = true;
                }
                if !bounded && BOUND_WORDS.iter().any(|w| low.contains(w)) {
                    bounded = true;
                }
                if retryish && bounded {
                    break;
                }
            }
        }
        if retryish && !bounded {
            out.push(Finding {
                file: sf.rel.clone(),
                line: toks[i].line,
                lint: "unbounded-retry".to_string(),
                message: format!(
                    "`{kw}` retry loop with neither an attempt cap nor a deadline; \
                     a fault that never clears spins it forever (use \
                     util::retry::RetryPolicy::run, or check a Deadline in the loop)"
                ),
            });
        }
    }
}

/// Index of the `}` closing the loop body whose `loop`/`while` keyword
/// is at `kw`.  Finds the first `{` after the keyword and matches
/// braces from there; `None` when the source is truncated mid-block
/// (the lexer recovers from anything, so be permissive here too).
fn body_end(toks: &[Token], kw: usize) -> Option<usize> {
    let open = (kw + 1..toks.len()).find(|&j| toks[j].tok.is_p('{'))?;
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.tok.is_p('{') {
            depth += 1;
        } else if t.tok.is_p('}') {
            depth -= 1;
            if depth == 0 {
                return Some(j);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn findings(src: &str) -> Vec<Finding> {
        let sf = SourceFile::parse("x.rs", src);
        let mut out = Vec::new();
        check(&sf, &mut out);
        out
    }

    #[test]
    fn flags_a_retry_loop_without_a_bound() {
        let src = r#"
            fn f() {
                loop {
                    match connect() {
                        Ok(c) => return c,
                        Err(_) => retry_backoff(),
                    }
                }
            }
        "#;
        let out = findings(src);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].lint, "unbounded-retry");
        assert_eq!(out[0].line, 3);
    }

    #[test]
    fn deadline_or_attempt_cap_classifies_as_bounded() {
        let src = r#"
            fn f() {
                loop {
                    deadline.check("connect")?;
                    if connect_with_retry().is_ok() { return; }
                }
                while attempt < max_attempts {
                    reconnect();
                }
            }
        "#;
        assert!(findings(src).is_empty());
    }

    #[test]
    fn loops_without_retry_vocabulary_are_ignored() {
        let src = r#"
            fn f() {
                loop {
                    let job = queue.pop();
                    process(job);
                }
            }
        "#;
        assert!(findings(src).is_empty());
    }

    #[test]
    fn for_loops_and_test_code_are_exempt() {
        let src = r#"
            fn f() {
                for _ in 0.. {
                    retry();
                }
            }
            #[cfg(test)]
            mod tests {
                fn t() {
                    loop { reconnect(); }
                }
            }
        "#;
        assert!(findings(src).is_empty());
    }

    #[test]
    fn retry_word_in_while_condition_counts() {
        let src = r#"
            fn f() {
                while should_retry() {
                    poke();
                }
            }
        "#;
        let out = findings(src);
        assert_eq!(out.len(), 1, "{out:?}");
    }
}
