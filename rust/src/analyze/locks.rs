//! Concurrency lints: the lock-acquisition graph and
//! guard-held-across-blocking detection.
//!
//! Lock identity is the final field/variable identifier of the
//! receiver chain, prefixed with the `impl` type for direct `self.x`
//! accesses (`JobQueue.inner`, `rx`, `metrics`).  No type inference is
//! attempted: two unrelated locks that share a field name merge, which
//! errs on the side of reporting — exactly what the
//! `// analyze: allow(..)` escape hatch is for.
//!
//! Tracked acquisitions: `.lock()`, no-arg `.read()`/`.write()`
//! (RwLock), and the crate's poison-recovering
//! [`crate::util::sync::lock_recover`].  Guard lifetimes follow the
//! two shapes that actually occur in straight-line Rust:
//! let-bound guards (die at `drop(g)`, scope exit, or a Condvar wait
//! that consumes them) and statement temporaries (die at the `;`
//! closing their statement).

use super::lexer::{fn_spans, Tok, Token};
use super::{Finding, SourceFile};

/// Methods that block the calling thread.  `read`/`write` only count
/// when called with arguments (no-arg forms are RwLock acquisitions).
const BLOCKING: &[&str] = &[
    "read",
    "read_exact",
    "read_to_end",
    "read_to_string",
    "write",
    "write_all",
    "write_fmt",
    "flush",
    "accept",
    "connect",
    "recv",
    "recv_timeout",
    "sleep",
    "join",
];

/// Identifiers treated as progress callbacks when invoked.
const CALLBACKS: &[&str] = &["progress", "on_progress", "callback", "cb"];

#[derive(Debug, Clone)]
struct Guard {
    lock: String,
    /// Binding name for let-bound guards; `None` for temporaries.
    var: Option<String>,
    /// Brace depth at acquisition.
    depth: usize,
    line: u32,
}

#[derive(Debug)]
struct Edge {
    from: String,
    to: String,
    file: String,
    line: u32,
}

/// Run the concurrency lints over the whole tree (the lock graph is
/// cross-file).
pub fn check(sources: &[SourceFile], out: &mut Vec<Finding>) {
    let mut edges: Vec<Edge> = Vec::new();
    for sf in sources {
        scan_file(sf, &mut edges, out);
    }
    report_cycles(&edges, out);
}

fn scan_file(sf: &SourceFile, edges: &mut Vec<Edge>, out: &mut Vec<Finding>) {
    let toks = &sf.lexed.tokens;
    for span in fn_spans(toks) {
        if sf.in_test(span.body_open) {
            continue;
        }
        scan_body(sf, toks, &span, edges, out);
    }
}

fn scan_body(
    sf: &SourceFile,
    toks: &[Token],
    span: &super::lexer::FnSpan,
    edges: &mut Vec<Edge>,
    out: &mut Vec<Finding>,
) {
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    // active `let` binding: (first bound ident, token index after `=`)
    let mut let_bind: Option<(String, usize)> = None;
    let mut i = span.body_open;
    while i <= span.body_close && i < toks.len() {
        let t = &toks[i];
        match &t.tok {
            Tok::P('{') => depth += 1,
            Tok::P('}') => {
                depth = depth.saturating_sub(1);
                guards.retain(|g| g.depth <= depth);
            }
            Tok::P(';') => {
                guards.retain(|g| g.var.is_some() || g.depth < depth);
                let_bind = None;
            }
            Tok::Ident(kw) if kw == "let" => {
                // capture the first bound ident (handles `mut` and the
                // first element of tuple patterns) and where `=` is
                let mut j = i + 1;
                let mut var = None;
                while j < toks.len() && !toks[j].tok.is_p('=') && !toks[j].tok.is_p(';') {
                    if var.is_none() {
                        if let Tok::Ident(name) = &toks[j].tok {
                            if name != "mut" {
                                var = Some(name.clone());
                            }
                        }
                    }
                    j += 1;
                }
                if let (Some(v), true) = (var, toks.get(j).is_some_and(|t| t.tok.is_p('='))) {
                    let_bind = Some((v, j + 1));
                }
            }
            // drop(g) releases a let-bound guard early
            Tok::Ident(kw) if kw == "drop" && toks.get(i + 1).is_some_and(|t| t.tok.is_p('(')) => {
                if let Some(Tok::Ident(arg)) = toks.get(i + 2).map(|t| &t.tok) {
                    let arg = arg.clone();
                    guards.retain(|g| g.var.as_deref() != Some(arg.as_str()));
                }
            }
            // Condvar wait: `.wait(g)` / `.wait_timeout(g, ..)` or the
            // poison-recovering `wait_recover(&cv, g)` /
            // `wait_timeout_recover(&cv, g, ..)` free functions.  The
            // guard passed survives (it is returned re-locked); any
            // *other* held lock is a deadlock-shaped finding.
            Tok::Ident(kw)
                if (kw == "wait"
                    || kw == "wait_timeout"
                    || kw == "wait_recover"
                    || kw == "wait_timeout_recover")
                    && toks.get(i + 1).is_some_and(|t| t.tok.is_p('(')) =>
            {
                let arg_idents = call_arg_idents(toks, i + 1);
                let consumed: Vec<String> = guards
                    .iter()
                    .filter(|g| {
                        g.var
                            .as_ref()
                            .is_some_and(|v| arg_idents.iter().any(|a| a == v))
                    })
                    .map(|g| g.lock.clone())
                    .collect();
                if !consumed.is_empty() {
                    for g in guards.iter().filter(|g| !consumed.contains(&g.lock)) {
                        out.push(Finding {
                            file: sf.rel.clone(),
                            line: t.line,
                            lint: "lock-across-blocking".into(),
                            message: format!(
                                "Condvar wait consumes lock `{}` while also holding `{}` \
                                 (acquired line {})",
                                consumed.join(", "),
                                g.lock,
                                g.line
                            ),
                        });
                    }
                }
            }
            // lock_recover(&self.x): acquisition via the helper
            Tok::Ident(kw)
                if kw == "lock_recover" && toks.get(i + 1).is_some_and(|t| t.tok.is_p('(')) =>
            {
                let name = arg_chain_name(sf, span, toks, i + 1);
                let after = matching_paren(toks, i + 1) + 1;
                acquire(
                    sf, span, toks, &mut guards, edges, name, i, after, t.line, depth,
                    &let_bind,
                );
            }
            // `.lock()` and no-arg `.read()`/`.write()` acquisitions
            Tok::P('.') => {
                if let Some(Tok::Ident(m)) = toks.get(i + 1).map(|t| &t.tok) {
                    let called = toks.get(i + 2).is_some_and(|t| t.tok.is_p('('));
                    let no_args =
                        called && toks.get(i + 3).is_some_and(|t| t.tok.is_p(')'));
                    if (m == "lock" || m == "read" || m == "write") && no_args {
                        let name = receiver_chain_name(sf, span, toks, i);
                        acquire(
                            sf, span, toks, &mut guards, edges, name, i, i + 4, t.line,
                            depth, &let_bind,
                        );
                        i += 2; // skip past `name (` so `(` isn't rescanned
                        continue;
                    }
                    // `read`/`write` only block when called with a
                    // buffer; everything else in BLOCKING blocks at any
                    // arity (`.recv()`, `.flush()`, `.join()`, …)
                    let blocks = called
                        && BLOCKING.contains(&m.as_str())
                        && !((m == "read" || m == "write") && no_args);
                    if blocks {
                        blocking_hit(sf, out, &guards, toks[i + 1].line, &format!(".{m}()"));
                    }
                    if called && CALLBACKS.contains(&m.as_str()) {
                        blocking_hit(
                            sf,
                            out,
                            &guards,
                            toks[i + 1].line,
                            &format!("progress callback `{m}`"),
                        );
                    }
                }
            }
            // path calls (`thread::sleep(..)`) and `write!`/`writeln!`
            Tok::Ident(name) => {
                let called = toks.get(i + 1).is_some_and(|t| t.tok.is_p('('));
                let is_macro = toks.get(i + 1).is_some_and(|t| t.tok.is_p('!'));
                let path_call = i > 0 && toks[i - 1].tok.is_p(':');
                if called && path_call && BLOCKING.contains(&name.as_str()) {
                    blocking_hit(sf, out, &guards, t.line, &format!("{name}()"));
                } else if is_macro && (name == "write" || name == "writeln") {
                    blocking_hit(sf, out, &guards, t.line, &format!("{name}! "));
                } else if called && !path_call && CALLBACKS.contains(&name.as_str()) {
                    blocking_hit(
                        sf,
                        out,
                        &guards,
                        t.line,
                        &format!("progress callback `{name}`"),
                    );
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// Record an acquisition: edges from every held lock, then push the
/// new guard.  The guard is let-bound only when the `let` initializer
/// *is* the lock expression — the receiver chain starts right after
/// `let … =` and nothing but `.unwrap()`/`.expect(..)`/
/// `.unwrap_or_else(..)` stands between the call and the closing `;`.
/// `let n = m.lock().unwrap().len();` therefore stays a statement
/// temporary (the guard dies at the `;`), matching real Rust drops.
#[allow(clippy::too_many_arguments)]
fn acquire(
    sf: &SourceFile,
    span: &super::lexer::FnSpan,
    toks: &[Token],
    guards: &mut Vec<Guard>,
    edges: &mut Vec<Edge>,
    name: String,
    at: usize,
    after: usize,
    line: u32,
    depth: usize,
    let_bind: &Option<(String, usize)>,
) {
    let _ = span;
    for g in guards.iter() {
        edges.push(Edge {
            from: g.lock.clone(),
            to: name.clone(),
            file: sf.rel.clone(),
            line,
        });
    }
    let chain_start = chain_start_index(toks, at);
    let var = match let_bind {
        Some((v, eq_next)) if chain_start == *eq_next && tail_is_binding(toks, after) => {
            Some(v.clone())
        }
        _ => None,
    };
    guards.push(Guard { lock: name, var, depth, line });
}

/// True when the tokens from `j` to the statement's `;` only re-wrap
/// the guard (`.unwrap()`, `.expect(..)`, `.unwrap_or_else(..)`), so
/// the `let` binding really holds the guard itself.
fn tail_is_binding(toks: &[Token], mut j: usize) -> bool {
    const WRAPPERS: &[&str] = &["unwrap", "expect", "unwrap_or_else"];
    loop {
        match toks.get(j).map(|t| &t.tok) {
            Some(Tok::P(';')) => return true,
            Some(Tok::P('.')) => {
                let wraps = toks
                    .get(j + 1)
                    .and_then(|t| t.tok.ident())
                    .is_some_and(|m| WRAPPERS.contains(&m));
                if !(wraps && toks.get(j + 2).is_some_and(|t| t.tok.is_p('('))) {
                    return false;
                }
                j = matching_paren(toks, j + 2) + 1;
            }
            _ => return false,
        }
    }
}

/// Index of the `)` matching the `(` at `open` (or the last token if
/// unbalanced — malformed input must not panic the analyzer).
fn matching_paren(toks: &[Token], open: usize) -> usize {
    let mut depth = 0usize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.tok.is_p('(') {
            depth += 1;
        } else if t.tok.is_p(')') {
            depth -= 1;
            if depth == 0 {
                return k;
            }
        }
    }
    toks.len().saturating_sub(1)
}

fn blocking_hit(
    sf: &SourceFile,
    out: &mut Vec<Finding>,
    guards: &[Guard],
    line: u32,
    what: &str,
) {
    for g in guards {
        out.push(Finding {
            file: sf.rel.clone(),
            line,
            lint: "lock-across-blocking".into(),
            message: format!(
                "{} while holding lock `{}` (acquired line {})",
                what.trim_end(),
                g.lock,
                g.line
            ),
        });
    }
}

/// Walk the receiver chain backwards from the `.` of `.lock()` and
/// name the lock.  `self.x` → `Type.x` (when the impl type is known);
/// otherwise the last identifier alone.
fn receiver_chain_name(
    sf: &SourceFile,
    span: &super::lexer::FnSpan,
    toks: &[Token],
    dot: usize,
) -> String {
    let start = chain_start_index(toks, dot);
    let idents: Vec<&str> = toks[start..dot]
        .iter()
        .filter_map(|t| t.tok.ident())
        .collect();
    name_from_chain(sf, span, &idents)
}

/// Name the lock from the argument of `lock_recover(&self.x)`.
fn arg_chain_name(
    sf: &SourceFile,
    span: &super::lexer::FnSpan,
    toks: &[Token],
    open: usize,
) -> String {
    let mut idents = Vec::new();
    let mut depth = 0usize;
    for t in toks.iter().skip(open) {
        match &t.tok {
            Tok::P('(') => depth += 1,
            Tok::P(')') => {
                if depth <= 1 {
                    break;
                }
                depth -= 1;
            }
            Tok::P(',') if depth == 1 => break,
            Tok::Ident(s) => idents.push(s.as_str()),
            _ => {}
        }
    }
    name_from_chain(sf, span, &idents)
}

fn name_from_chain(
    _sf: &SourceFile,
    span: &super::lexer::FnSpan,
    idents: &[&str],
) -> String {
    let last = idents.last().copied().unwrap_or("<unknown>");
    if idents.first() == Some(&"self") && idents.len() == 2 {
        if let Some(ty) = &span.impl_type {
            return format!("{ty}.{last}");
        }
    }
    last.to_string()
}

/// Top-level identifiers appearing in a call's argument list (for
/// matching Condvar-wait arguments against held guard variables).
fn call_arg_idents(toks: &[Token], open: usize) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    for t in toks.iter().skip(open) {
        match &t.tok {
            Tok::P('(') => depth += 1,
            Tok::P(')') => {
                if depth <= 1 {
                    break;
                }
                depth -= 1;
            }
            Tok::Ident(s) if depth == 1 => out.push(s.clone()),
            _ => {}
        }
    }
    out
}

/// Token index where the receiver chain feeding `toks[dot]` begins
/// (walks back over `ident`, `.`, `::`, `self`, balanced `[..]` and
/// `(..)` groups, and `&`).
fn chain_start_index(toks: &[Token], dot: usize) -> usize {
    let mut i = dot;
    while i > 0 {
        let prev = &toks[i - 1].tok;
        match prev {
            Tok::Ident(_) | Tok::P('.') | Tok::P(':') => i -= 1,
            Tok::P(']') | Tok::P(')') => {
                let (open, close) = if prev.is_p(']') { ('[', ']') } else { ('(', ')') };
                let mut depth = 0usize;
                let mut j = i - 1;
                loop {
                    if toks[j].tok.is_p(close) {
                        depth += 1;
                    } else if toks[j].tok.is_p(open) {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    if j == 0 {
                        break;
                    }
                    j -= 1;
                }
                i = j;
            }
            Tok::P('&') => i -= 1,
            _ => break,
        }
    }
    i
}

/// Find strongly connected components of the lock graph and report
/// every edge inside a cyclic SCC (incl. self-loops: re-acquiring a
/// non-reentrant `std::Mutex` deadlocks).
fn report_cycles(edges: &[Edge], out: &mut Vec<Finding>) {
    use std::collections::{BTreeMap, BTreeSet};
    let mut nodes: BTreeSet<&str> = BTreeSet::new();
    let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
    for e in edges {
        nodes.insert(&e.from);
        nodes.insert(&e.to);
        adj.entry(&e.from).or_default().insert(&e.to);
    }

    // iterative Tarjan
    let idx_of: BTreeMap<&str, usize> =
        nodes.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let names: Vec<&str> = nodes.iter().copied().collect();
    let n = names.len();
    let succ: Vec<Vec<usize>> = names
        .iter()
        .map(|&u| {
            adj.get(u)
                .map(|s| s.iter().map(|v| idx_of[v]).collect())
                .unwrap_or_default()
        })
        .collect();
    let mut index = vec![usize::MAX; n];
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack = Vec::new();
    let mut comp = vec![usize::MAX; n];
    let mut next_index = 0usize;
    let mut next_comp = 0usize;
    for root in 0..n {
        if index[root] != usize::MAX {
            continue;
        }
        // (node, next-successor-position)
        let mut work = vec![(root, 0usize)];
        while let Some(&mut (v, ref mut pi)) = work.last_mut() {
            if *pi == 0 {
                index[v] = next_index;
                low[v] = next_index;
                next_index += 1;
                stack.push(v);
                on_stack[v] = true;
            }
            if let Some(&w) = succ[v].get(*pi) {
                *pi += 1;
                if index[w] == usize::MAX {
                    work.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            } else {
                if low[v] == index[v] {
                    loop {
                        let Some(w) = stack.pop() else { break };
                        on_stack[w] = false;
                        comp[w] = next_comp;
                        if w == v {
                            break;
                        }
                    }
                    next_comp += 1;
                }
                work.pop();
                if let Some(&(u, _)) = work.last() {
                    low[u] = low[u].min(low[v]);
                }
            }
        }
    }

    // SCC sizes (to tell cyclic multi-node SCCs from singletons)
    let mut size = vec![0usize; next_comp];
    for &c in &comp {
        size[c] += 1;
    }
    for e in edges {
        let (fi, ti) = (idx_of[e.from.as_str()], idx_of[e.to.as_str()]);
        if e.from == e.to {
            out.push(Finding {
                file: e.file.clone(),
                line: e.line,
                lint: "lock-order".into(),
                message: format!(
                    "lock `{}` acquired while already held (std::Mutex is not \
                     reentrant; this deadlocks)",
                    e.to
                ),
            });
        } else if comp[fi] == comp[ti] && size[comp[fi]] > 1 {
            out.push(Finding {
                file: e.file.clone(),
                line: e.line,
                lint: "lock-order".into(),
                message: format!(
                    "lock-order inversion: `{}` acquired while holding `{}`, but \
                     another site orders them the other way (cycle in the \
                     lock-acquisition graph)",
                    e.to, e.from
                ),
            });
        }
    }
    // dedupe identical (file, line, message) repeats from loops
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.lint.as_str(), a.message.as_str())
            .cmp(&(b.file.as_str(), b.line, b.lint.as_str(), b.message.as_str()))
    });
    out.dedup();
}
