//! Figure regenerators: Fig 2 (per-layer error reduction), Fig 3
//! (perplexity vs iterations / vs samples), Fig 4 (continuous vs
//! thresholded error + threshold residual).  Every pruning run is a
//! [`JobSpec`](crate::coordinator::JobSpec) through the shared session,
//! so sweeping a grid never recollects calibration grams.

use anyhow::{Context, Result};

use crate::pruner::{Method, SparseFwConfig, SparsityPattern, Warmstart};
use crate::util::json::Json;

use super::{print_table, ReportCtx};

/// Fig 2: relative reduction in pruning error vs the Wanda warmstart,
/// per layer, grouped by matrix family (60% sparsity in the paper).
pub fn fig2(ctx: &mut ReportCtx) -> Result<Json> {
    let pattern = SparsityPattern::PerRow { sparsity: 0.6 };
    let model_name = ctx.models[0].clone();

    let method = Method::sparsefw(SparseFwConfig {
        iters: ctx.iters,
        warmstart: Warmstart::Wanda,
        ..Default::default()
    });
    let mut spec = ctx.spec(&model_name, method, pattern.clone());
    spec.eval = None; // fig 2 only needs the per-layer errors
    let res = ctx.run(&spec)?;

    let layers = ctx.session.model(&model_name)?.cfg.layers();
    let mut rows = Vec::new();
    let mut out = Vec::new();
    for l in &layers {
        let warm = res.prune.warm_objs[&l.name];
        let fin = res.prune.layer_objs[&l.name];
        let red = if warm > 0.0 { (warm - fin) / warm } else { 0.0 };
        let block: String = l
            .name
            .split('.')
            .nth(1)
            .unwrap_or("?")
            .to_string();
        rows.push(vec![
            block.clone(),
            l.family.clone(),
            format!("{:.4e}", warm),
            format!("{:.4e}", fin),
            format!("{:.1}%", red * 100.0),
        ]);
        out.push(Json::obj(vec![
            ("layer", l.name.as_str().into()),
            ("block", block.parse::<usize>().unwrap_or(0).into()),
            ("family", l.family.as_str().into()),
            ("warm_err", warm.into()),
            ("final_err", fin.into()),
            ("rel_reduction", red.into()),
        ]));
    }

    println!(
        "\nFig 2 — per-layer pruning-error reduction vs Wanda warmstart ({model_name}, {}, {} iters)",
        pattern.label(),
        ctx.iters
    );
    print_table(&["block", "family", "warm err", "sparsefw err", "reduction"], &rows);
    println!(
        "mean relative reduction: {:.1}%",
        res.mean_rel_reduction().unwrap_or(0.0) * 100.0
    );

    let report = Json::obj(vec![
        ("figure", "fig2".into()),
        ("model", model_name.as_str().into()),
        ("pattern", pattern.label().into()),
        ("iters", ctx.iters.into()),
        ("mean_rel_reduction", res.mean_rel_reduction().unwrap_or(0.0).into()),
        ("layers", Json::Arr(out)),
    ]);
    ctx.write_json("fig2", &report)?;
    Ok(report)
}

/// Fig 3 left: perplexity vs number of FW iterations (2:4 pattern).
pub fn fig3_iters(ctx: &mut ReportCtx, iter_grid: &[usize]) -> Result<Json> {
    let pattern = SparsityPattern::NM { keep: 2, block: 4 };
    let model_name = ctx.models[0].clone();

    let mut rows = Vec::new();
    let mut out = Vec::new();
    for &iters in iter_grid {
        let method = Method::sparsefw(SparseFwConfig {
            iters,
            warmstart: Warmstart::Wanda,
            ..Default::default()
        });
        let spec = ctx.spec(&model_name, method, pattern.clone());
        let res = ctx.run(&spec)?;
        let ppl = res.eval.as_ref().context("fig3 point missing eval")?.ppl;
        crate::info!("fig3-iters: T={iters} -> ppl {ppl:.3}");
        rows.push(vec![iters.to_string(), format!("{ppl:.3}")]);
        out.push(Json::obj(vec![("iters", iters.into()), ("ppl", ppl.into())]));
    }

    println!(
        "\nFig 3 (left) — perplexity vs SparseFW iterations ({model_name}, {}, {} samples)",
        pattern.label(),
        ctx.calib_samples
    );
    print_table(&["iters", "ppl"], &rows);

    let report = Json::obj(vec![
        ("figure", "fig3_iters".into()),
        ("model", model_name.as_str().into()),
        ("series", Json::Arr(out)),
    ]);
    ctx.write_json("fig3_iters", &report)?;
    Ok(report)
}

/// Fig 3 right: perplexity vs number of calibration samples for both
/// SparseFW and the Wanda baseline (the paper's sample-efficiency
/// contrast).  Both methods share the memoized calibration per sample
/// count — one gram collection per grid point, not two.
pub fn fig3_samples(ctx: &mut ReportCtx, sample_grid: &[usize]) -> Result<Json> {
    let pattern = SparsityPattern::NM { keep: 2, block: 4 };
    let model_name = ctx.models[0].clone();

    let mut rows = Vec::new();
    let mut out = Vec::new();
    for &samples in sample_grid {
        let fw_method = Method::sparsefw(SparseFwConfig {
            iters: ctx.iters,
            warmstart: Warmstart::Wanda,
            ..Default::default()
        });
        let mut fw_spec = ctx.spec(&model_name, fw_method, pattern.clone());
        fw_spec.calib_samples = samples;
        let mut wanda_spec = ctx.spec(&model_name, Method::wanda(), pattern.clone());
        wanda_spec.calib_samples = samples;

        let fw_ppl = ctx.run(&fw_spec)?.eval.context("fig3 fw missing eval")?.ppl;
        let wanda_ppl = ctx.run(&wanda_spec)?.eval.context("fig3 wanda missing eval")?.ppl;
        crate::info!("fig3-samples: N={samples} -> sparsefw {fw_ppl:.3}, wanda {wanda_ppl:.3}");
        rows.push(vec![
            samples.to_string(),
            format!("{fw_ppl:.3}"),
            format!("{wanda_ppl:.3}"),
        ]);
        out.push(Json::obj(vec![
            ("samples", samples.into()),
            ("sparsefw_ppl", fw_ppl.into()),
            ("wanda_ppl", wanda_ppl.into()),
        ]));
    }

    println!(
        "\nFig 3 (right) — perplexity vs calibration samples ({model_name}, {}, {} iters)",
        pattern.label(),
        ctx.iters
    );
    print_table(&["samples", "sparsefw", "wanda"], &rows);

    let report = Json::obj(vec![
        ("figure", "fig3_samples".into()),
        ("model", model_name.as_str().into()),
        ("series", Json::Arr(out)),
    ]);
    ctx.write_json("fig3_samples", &report)?;
    Ok(report)
}

/// Fig 4: per-matrix relative error reduction of the continuous vs the
/// thresholded iterate over FW iterations (left), and the mean ℓ₁
/// threshold residual (right).  α = 0 and unstructured C_k, matching
/// the paper's "optimized towards 60% unstructured" setting.
pub fn fig4(ctx: &mut ReportCtx) -> Result<Json> {
    let pattern = SparsityPattern::Unstructured { sparsity: 0.6 };
    let model_name = ctx.models[0].clone();

    let trace_every = (ctx.iters / 25).max(1);
    let method = Method::sparsefw(SparseFwConfig {
        iters: ctx.iters,
        alpha: 0.0,
        warmstart: Warmstart::Wanda,
        trace_every,
        use_chunk: false,
        keep_best: false, // raw Algorithm 1 behaviour for the trace
        line_search: false,
        ..Default::default()
    });
    let mut spec = ctx.spec(&model_name, method, pattern.clone());
    spec.eval = None; // fig 4 reads the optimization traces only
    let res = ctx.run(&spec)?;
    let traces = &res.prune.traces;
    let warm_objs = &res.prune.warm_objs;

    // median across matrices at each trace point
    let names: Vec<&String> = traces.keys().collect();
    anyhow::ensure!(!names.is_empty(), "no traces recorded");
    let t_axis = traces[names[0]].iters.clone();
    let mut rows = Vec::new();
    let mut series = Vec::new();
    for (ti, &t) in t_axis.iter().enumerate() {
        let mut cont_red = Vec::new();
        let mut thr_red = Vec::new();
        let mut resid = Vec::new();
        for name in &names {
            let tr = &traces[*name];
            let warm = warm_objs[*name];
            if warm <= 0.0 || ti >= tr.iters.len() {
                continue;
            }
            cont_red.push((warm - tr.continuous_obj[ti]) / warm);
            thr_red.push((warm - tr.thresholded_obj[ti]) / warm);
            resid.push(tr.residual[ti]);
        }
        let med = |v: &mut Vec<f64>| -> f64 {
            if v.is_empty() {
                return 0.0;
            }
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let (c, th, r) = (med(&mut cont_red), med(&mut thr_red), med(&mut resid));
        rows.push(vec![
            t.to_string(),
            format!("{:.1}%", c * 100.0),
            format!("{:.1}%", th * 100.0),
            format!("{:.4}", r),
        ]);
        series.push(Json::obj(vec![
            ("iter", t.into()),
            ("continuous_reduction_median", c.into()),
            ("thresholded_reduction_median", th.into()),
            ("residual_median", r.into()),
        ]));
    }

    println!(
        "\nFig 4 — median across {} matrices ({model_name}, {}, α=0)",
        names.len(),
        pattern.label()
    );
    print_table(
        &["iter", "continuous red.", "thresholded red.", "ℓ₁ residual"],
        &rows,
    );

    let report = Json::obj(vec![
        ("figure", "fig4".into()),
        ("model", model_name.as_str().into()),
        ("pattern", pattern.label().into()),
        ("series_median", Json::Arr(series)),
    ]);
    ctx.write_json("fig4", &report)?;
    Ok(report)
}
