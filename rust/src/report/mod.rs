//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 experiment index):
//!
//! * [`tables::table1`] — perplexity + zero-shot accuracy grid.
//! * [`tables::table2`] — α-ratio ablation.
//! * [`figs::fig2`]     — per-layer relative error reduction by family.
//! * [`figs::fig3`]     — perplexity vs iterations / vs samples.
//! * [`figs::fig4`]     — continuous vs thresholded error + residual.
//!
//! Each regenerator prints the paper-style rows/series to stdout and
//! writes machine-readable JSON under `reports/`.  Every cell is one
//! declarative [`JobSpec`] executed through the shared
//! [`PruneSession`], so models are loaded once and calibrations are
//! memoized by `(model, samples, seed)` across the whole sweep.

pub mod figs;
pub mod tables;

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::config::{Backend, Workspace};
use crate::coordinator::{Allocation, EvalSpec, JobResult, JobSpec, PruneSession};
use crate::pruner::{Method, SparsityPattern};
use crate::util::json::{self, Json};

/// Shared context: the executing session plus report-size knobs.
pub struct ReportCtx {
    pub session: PruneSession,
    pub models: Vec<String>,
    /// Calibration samples (paper: 256; we default lower for wall-time).
    pub calib_samples: usize,
    pub calib_seed: u64,
    /// SparseFW iterations (paper: 2000).
    pub iters: usize,
    /// Perplexity eval sequences (paper: 100 validation sequences).
    pub eval_seqs: usize,
    /// Items per zero-shot task.
    pub zs_items: usize,
    pub out_dir: PathBuf,
}

impl ReportCtx {
    pub fn new(ws: Workspace, models: Vec<String>) -> Result<Self> {
        let models = if models.is_empty() {
            ws.manifest.model_names()
        } else {
            models
        };
        Ok(Self {
            session: PruneSession::new(ws),
            models,
            calib_samples: 128,
            calib_seed: 7,
            iters: 400,
            eval_seqs: 64,
            zs_items: 60,
            out_dir: PathBuf::from("reports"),
        })
    }

    /// Shrink every knob for smoke-tests (`--fast`).
    pub fn fast(&mut self) {
        self.calib_samples = 16;
        self.iters = 40;
        self.eval_seqs = 16;
        self.zs_items = 12;
    }

    /// The [`JobSpec`] for one report cell (native backend, ctx-level
    /// calibration knobs, eval enabled).
    pub fn spec(&self, model: &str, method: Method, pattern: SparsityPattern) -> JobSpec {
        JobSpec {
            model: model.to_string(),
            method,
            allocation: Allocation::Uniform(pattern),
            backend: Backend::Native,
            calib_samples: self.calib_samples,
            calib_seed: self.calib_seed,
            // report tables reproduce the paper's protocol: one-shot
            // dense calibration
            calib_policy: crate::calib::CalibPolicy::Dense,
            trace_every: 0,
            refine: Vec::new(),
            eval: Some(EvalSpec { seqs: self.eval_seqs, zs_items: self.zs_items }),
        }
    }

    /// Execute one cell through the shared session.
    pub fn run(&mut self, spec: &JobSpec) -> Result<JobResult> {
        self.session.execute(spec)
    }

    /// Write a report JSON under `reports/`.
    pub fn write_json(&self, name: &str, v: &Json) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {:?}", self.out_dir))?;
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, json::to_string_pretty(v))?;
        crate::info!("wrote {path:?}");
        Ok(path)
    }
}

/// Fixed-width table printing helper.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i == 0 {
                s.push_str(&format!("{:<w$}", c, w = widths[i]));
            } else {
                s.push_str(&format!("  {:>w$}", c, w = widths[i]));
            }
        }
        s
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", line(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    for row in rows {
        println!("{}", line(row));
    }
}
