//! Regeneration of every table and figure in the paper's evaluation
//! (DESIGN.md §5 experiment index):
//!
//! * [`tables::table1`] — perplexity + zero-shot accuracy grid.
//! * [`tables::table2`] — α-ratio ablation.
//! * [`figs::fig2`]     — per-layer relative error reduction by family.
//! * [`figs::fig3`]     — perplexity vs iterations / vs samples.
//! * [`figs::fig4`]     — continuous vs thresholded error + residual.
//!
//! Each regenerator prints the paper-style rows/series to stdout and
//! writes machine-readable JSON under `reports/`.

pub mod figs;
pub mod tables;

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::calib::Calibration;
use crate::config::Workspace;
use crate::data::TokenBin;
use crate::eval::{perplexity_native, zero_shot};
use crate::model::Gpt;
use crate::util::json::{self, Json};

/// Shared context: workspace, loaded models, calibration cache, eval
/// data, and report-size knobs.
pub struct ReportCtx {
    pub ws: Workspace,
    pub models: Vec<String>,
    pub test: TokenBin,
    pub train: TokenBin,
    /// Calibration samples (paper: 256; we default lower for wall-time).
    pub calib_samples: usize,
    pub calib_seed: u64,
    /// SparseFW iterations (paper: 2000).
    pub iters: usize,
    /// Perplexity eval sequences (paper: 100 validation sequences).
    pub eval_seqs: usize,
    /// Items per zero-shot task.
    pub zs_items: usize,
    pub out_dir: PathBuf,

    pub(crate) loaded: BTreeMap<String, Gpt>,
    pub(crate) calib_cache: BTreeMap<(String, usize, u64), Calibration>,
}

impl ReportCtx {
    pub fn new(ws: Workspace, models: Vec<String>) -> Result<Self> {
        let models = if models.is_empty() {
            ws.manifest.model_names()
        } else {
            models
        };
        let test = ws.test_bin()?;
        let train = ws.train_bin()?;
        Ok(Self {
            ws,
            models,
            test,
            train,
            calib_samples: 128,
            calib_seed: 7,
            iters: 400,
            eval_seqs: 64,
            zs_items: 60,
            out_dir: PathBuf::from("reports"),
            loaded: BTreeMap::new(),
            calib_cache: BTreeMap::new(),
        })
    }

    /// Shrink every knob for smoke-tests (`--fast`).
    pub fn fast(&mut self) {
        self.calib_samples = 16;
        self.iters = 40;
        self.eval_seqs = 16;
        self.zs_items = 12;
    }

    pub fn model(&mut self, name: &str) -> Result<&Gpt> {
        if !self.loaded.contains_key(name) {
            let m = self.ws.load_model(name)?;
            crate::info!(
                "loaded model {name}: {} params, dense ppl (build-time) = {:?}",
                m.n_params(),
                self.ws.manifest.dense_test_ppl(name)
            );
            self.loaded.insert(name.to_string(), m);
        }
        Ok(&self.loaded[name])
    }

    pub fn calibration(&mut self, name: &str) -> Result<&Calibration> {
        self.calibration_with(name, self.calib_samples, self.calib_seed)
    }

    pub fn calibration_with(
        &mut self,
        name: &str,
        samples: usize,
        seed: u64,
    ) -> Result<&Calibration> {
        let key = (name.to_string(), samples, seed);
        if !self.calib_cache.contains_key(&key) {
            self.model(name)?; // ensure loaded
            let model = &self.loaded[name];
            let t0 = std::time::Instant::now();
            let calib = Calibration::collect(model, &self.train, samples, seed)?;
            crate::info!(
                "calibrated {name} with {samples} samples in {:.1}s",
                t0.elapsed().as_secs_f64()
            );
            self.calib_cache.insert(key.clone(), calib);
        }
        Ok(&self.calib_cache[&key])
    }

    /// Perplexity + mean zero-shot accuracy of a (masked) model.
    pub fn evaluate(&self, model: &Gpt) -> Result<(f64, f64)> {
        let ppl = perplexity_native(model, &self.test, self.eval_seqs)?;
        let zs = zero_shot(model, 0xE7A1, self.zs_items)?;
        Ok((ppl, zs.mean()))
    }

    /// Write a report JSON under `reports/`.
    pub fn write_json(&self, name: &str, v: &Json) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.out_dir)
            .with_context(|| format!("creating {:?}", self.out_dir))?;
        let path = self.out_dir.join(format!("{name}.json"));
        std::fs::write(&path, json::to_string_pretty(v))?;
        crate::info!("wrote {path:?}");
        Ok(path)
    }
}

/// Fixed-width table printing helper.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i == 0 {
                s.push_str(&format!("{:<w$}", c, w = widths[i]));
            } else {
                s.push_str(&format!("  {:>w$}", c, w = widths[i]));
            }
        }
        s
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", line(&hdr));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    for row in rows {
        println!("{}", line(row));
    }
}
