//! Table 1 (method × sparsity grid) and Table 2 (α ablation) — each
//! cell is one [`JobSpec`](crate::coordinator::JobSpec) executed
//! through the shared session (calibration collected once per model).

use anyhow::{Context, Result};

use crate::pruner::{Method, SparseFwConfig, SparsityPattern, Warmstart};
use crate::util::json::Json;

use super::{print_table, ReportCtx};

/// The paper's sparsity regimes.  Protocol note (DESIGN.md §5): the
/// baselines use the per-row budget (Wanda's native protocol, Sun et
/// al. 2023); SparseFW relaxes over the same per-row polytope so keep
/// budgets match exactly across methods.
pub fn sparsity_grid() -> Vec<SparsityPattern> {
    vec![
        SparsityPattern::PerRow { sparsity: 0.5 },
        SparsityPattern::PerRow { sparsity: 0.6 },
        SparsityPattern::NM { keep: 2, block: 4 },
    ]
}

fn table1_methods(iters: usize) -> Vec<Method> {
    vec![
        Method::wanda(),
        Method::ria(),
        Method::sparsefw(SparseFwConfig {
            iters,
            warmstart: Warmstart::Wanda,
            ..Default::default()
        }),
        Method::sparsefw(SparseFwConfig {
            iters,
            warmstart: Warmstart::Ria,
            ..Default::default()
        }),
    ]
}

/// Table 1: perplexity (↓) and zero-shot accuracy (↑) for every model ×
/// sparsity × method.
pub fn table1(ctx: &mut ReportCtx) -> Result<Json> {
    let methods = table1_methods(ctx.iters);
    let mut rows_ppl: Vec<Vec<String>> = Vec::new();
    let mut rows_acc: Vec<Vec<String>> = Vec::new();
    let mut out = Vec::new();

    for pattern in sparsity_grid() {
        for method in &methods {
            let mut row_p = vec![method.label(), pattern.label()];
            let mut row_a = vec![method.label(), pattern.label()];
            for model_name in ctx.models.clone() {
                let spec = ctx.spec(&model_name, method.clone(), pattern.clone());
                let res = ctx.run(&spec)?;
                let ev = res.eval.as_ref().context("table1 cell missing eval")?;
                let (ppl, acc) = (ev.ppl, ev.zero_shot.mean());
                crate::info!(
                    "table1: {model_name} {} {} -> ppl {ppl:.2} acc {:.1}% ({:.1}s prune)",
                    method.label(),
                    pattern.label(),
                    acc * 100.0,
                    res.wall_seconds(),
                );
                row_p.push(format!("{ppl:.2}"));
                row_a.push(format!("{:.2}", acc * 100.0));
                out.push(Json::obj(vec![
                    ("model", model_name.as_str().into()),
                    ("method", method.label().into()),
                    ("pattern", pattern.label().into()),
                    ("ppl", ppl.into()),
                    ("zero_shot_acc", acc.into()),
                    ("mean_rel_reduction", res.mean_rel_reduction().unwrap_or(0.0).into()),
                    ("prune_seconds", res.wall_seconds().into()),
                ]));
            }
            rows_ppl.push(row_p);
            rows_acc.push(row_a);
        }
    }

    let mut headers = vec!["method", "sparsity"];
    let model_names: Vec<&str> = ctx.models.iter().map(|s| s.as_str()).collect();
    headers.extend(model_names);

    println!("\nTable 1 — WikiText-proxy perplexity (lower is better)");
    print_table(&headers, &rows_ppl);
    println!("\nTable 1 — zero-shot accuracy % (higher is better)");
    print_table(&headers, &rows_acc);

    let report = Json::obj(vec![
        ("table", "table1".into()),
        ("iters", ctx.iters.into()),
        ("calib_samples", ctx.calib_samples.into()),
        ("rows", Json::Arr(out)),
    ]);
    ctx.write_json("table1", &report)?;
    Ok(report)
}

/// Table 2: the α (fraction of fixed high-saliency weights) ablation at
/// 60% per-row and 2:4 sparsity, Wanda warmstart.
pub fn table2(ctx: &mut ReportCtx) -> Result<Json> {
    let alphas = [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0];
    let patterns = [
        SparsityPattern::NM { keep: 2, block: 4 },
        SparsityPattern::PerRow { sparsity: 0.6 },
    ];
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut out = Vec::new();

    for pattern in &patterns {
        for model_name in ctx.models.clone() {
            let mut row = vec![model_name.clone(), pattern.label()];
            for &alpha in &alphas {
                let method = Method::sparsefw(SparseFwConfig {
                    iters: ctx.iters,
                    alpha,
                    warmstart: Warmstart::Wanda,
                    // raw Algorithm 2: the ablation's point is that small
                    // α *degrades* quality despite lower local error —
                    // the keep_best guard would mask exactly that.
                    keep_best: false,
                    ..Default::default()
                });
                let spec = ctx.spec(&model_name, method, pattern.clone());
                let res = ctx.run(&spec)?;
                let ppl = res.eval.as_ref().context("table2 cell missing eval")?.ppl;
                crate::info!(
                    "table2: {model_name} {} alpha={alpha} -> ppl {ppl:.2}",
                    pattern.label()
                );
                row.push(format!("{ppl:.2}"));
                out.push(Json::obj(vec![
                    ("model", model_name.as_str().into()),
                    ("pattern", pattern.label().into()),
                    ("alpha", alpha.into()),
                    ("ppl", ppl.into()),
                ]));
            }
            rows.push(row);
        }
    }

    let mut headers = vec!["model", "sparsity"];
    let alpha_labels: Vec<String> = alphas
        .iter()
        .map(|a| {
            if *a == 1.0 {
                "1.0 (=Wanda)".to_string()
            } else {
                format!("{a}")
            }
        })
        .collect();
    let alpha_refs: Vec<&str> = alpha_labels.iter().map(|s| s.as_str()).collect();
    headers.extend(alpha_refs);

    println!("\nTable 2 — perplexity by α (fraction of fixed high-saliency weights)");
    print_table(&headers, &rows);

    let report = Json::obj(vec![
        ("table", "table2".into()),
        ("iters", ctx.iters.into()),
        ("rows", Json::Arr(out)),
    ]);
    ctx.write_json("table2", &report)?;
    Ok(report)
}
