//! Streaming block-sequential calibration state.
//!
//! The one-shot [`super::Calibration`] forwards the *dense* model once
//! and holds all `4·n_layers` gram matrices simultaneously — O(model)
//! calibration memory, and grams that ignore the error already
//! introduced by pruning earlier layers.  [`CalibState`] is the staged
//! alternative: it keeps only the per-sequence hidden states (the
//! residual stream entering the current block) and materializes **one
//! block's grams at a time**, computed from the *pruned-so-far* model,
//! so compounding error is priced into every layer's objective and peak
//! gram memory is O(block) instead of O(model).
//!
//! Protocol, per block `b` (driven by `coordinator::run_blocks`):
//!
//! 1. [`CalibState::block_grams`] (or four [`CalibState::layer_gram`]
//!    calls for the strictly-sequential granularity) — compute grams
//!    from the current hiddens with the working model's weights.
//! 2. Prune the block's layers; write masks into the working model.
//! 3. [`CalibState::advance`] — re-forward the hiddens through the now-
//!    *masked* block, yielding the inputs block `b+1` actually sees.
//!
//! Checked-out grams live in a [`GramSet`] guard that counts live sets
//! and bytes; tests assert the staged driver never holds more than one
//! block's grams at a time.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::model::forward::{
    attention, forward_block, forward_embed, gelu, layernorm, BlockNames, Captures,
};
use crate::model::Gpt;
use crate::tensor::{matmul_a_bt, matmul_at_b, Mat};
use crate::util::pool::parallel_map;

// ---------------------------------------------------------------------------
// CalibPolicy
// ---------------------------------------------------------------------------

/// How calibration grams are computed for a pruning run
/// (`--propagate off|block|layer`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibPolicy {
    /// One-shot grams over the dense model (`--propagate off`) — the
    /// original pipeline, bit-identical to the pre-staged behaviour.
    Dense,
    /// Staged (`--propagate block`): per block, grams come from the
    /// pruned-so-far hiddens; the block's four layers keep their
    /// intra-block parallelism, then hiddens re-forward through the
    /// masked block.
    PropagateBlock,
    /// Strictly sequential (`--propagate layer`): like `block`, but the
    /// `wo` / `wdown` grams are recomputed *after* `wqkv` / `wup` are
    /// pruned, so even intra-block compounding is priced in.
    PropagateLayer,
}

impl CalibPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "off" | "dense" => CalibPolicy::Dense,
            "block" => CalibPolicy::PropagateBlock,
            "layer" => CalibPolicy::PropagateLayer,
            other => bail!("unknown propagation granularity {other:?} (off|block|layer)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            CalibPolicy::Dense => "off",
            CalibPolicy::PropagateBlock => "block",
            CalibPolicy::PropagateLayer => "layer",
        }
    }

    /// True for the staged (block-sequential) policies.
    pub fn is_propagated(&self) -> bool {
        !matches!(self, CalibPolicy::Dense)
    }
}

// ---------------------------------------------------------------------------
// EmbedPrefix
// ---------------------------------------------------------------------------

/// The token-sample/embed prefix of a staged calibration: per-sequence
/// embedded hidden states, before any block has run.
///
/// This is the only method-independent part of a propagated calibration
/// (everything after it depends on the masks chosen so far), hence the
/// only part [`crate::coordinator::PruneSession`] memoizes.
#[derive(Clone)]
pub struct EmbedPrefix {
    pub(crate) hiddens: Vec<Mat>,
    pub(crate) seq_len: usize,
}

impl EmbedPrefix {
    /// Reassemble a prefix from raw parts — the fleet hand-off path:
    /// a worker receives its predecessor's exit hiddens over the wire
    /// (`server::fleet::wire`) and resumes staged calibration from
    /// them, without ever materializing the upstream blocks' grams.
    pub(crate) fn from_parts(hiddens: Vec<Mat>, seq_len: usize) -> Self {
        Self { hiddens, seq_len }
    }

    /// The per-sequence hidden states (read-only; serialization only).
    pub(crate) fn hiddens(&self) -> &[Mat] {
        &self.hiddens
    }

    /// Bit-exact digest of the carried hiddens — identical to
    /// [`CalibState::digest`] over the same activations, so a wire
    /// hand-off can be verified before a shard trusts it.
    pub fn digest(&self) -> u64 {
        digest_hiddens(&self.hiddens)
    }

    /// Embed `seqs` (parallel over sequences).  All sequences must have
    /// the same length.
    pub fn new(model: &Gpt, seqs: &[Vec<u8>]) -> Result<Self> {
        let seq_len = super::validate_seq_lens(seqs)?;
        ensure!(
            seq_len <= model.cfg.seq_len,
            "calibration sequences longer than model seq_len ({seq_len} > {})",
            model.cfg.seq_len
        );
        let hiddens = parallel_map(seqs.len(), |i| forward_embed(model, &seqs[i]));
        Ok(Self { hiddens, seq_len })
    }

    pub fn n_samples(&self) -> usize {
        self.hiddens.len()
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }
}

/// The shared digest behind [`CalibState::digest`] and
/// [`EmbedPrefix::digest`]: dims + every f32 bit pattern,
/// [`crate::util::prng::mix64`]-folded.
fn digest_hiddens(hiddens: &[Mat]) -> u64 {
    use crate::util::prng::mix64;
    let mut h = mix64(0x63616c6962 ^ hiddens.len() as u64);
    for m in hiddens {
        h = mix64(h ^ m.rows as u64);
        h = mix64(h ^ m.cols as u64);
        for x in &m.data {
            h = mix64(h ^ u64::from(x.to_bits()));
        }
    }
    h
}

// ---------------------------------------------------------------------------
// Live-gram accounting
// ---------------------------------------------------------------------------

/// Shared counters behind the O(block) memory claim: how many gram sets
/// (and bytes) are checked out of a [`CalibState`] right now, and the
/// high-water marks.
#[derive(Default)]
struct LiveStats {
    live_sets: AtomicUsize,
    peak_sets: AtomicUsize,
    live_bytes: AtomicUsize,
    peak_bytes: AtomicUsize,
}

/// One checked-out set of gram matrices — a whole block's four
/// ([`CalibState::block_grams`]) or a single layer's
/// ([`CalibState::layer_gram`]).  Holding a set counts toward the
/// owning state's live statistics; dropping it releases the count, so
/// `peak_live_sets() == 1` after a run proves the driver streamed one
/// set at a time.
pub struct GramSet {
    /// Block the grams belong to.
    pub block: usize,
    grams: BTreeMap<String, Mat>,
    bytes: usize,
    stats: Arc<LiveStats>,
}

impl GramSet {
    fn checkout(block: usize, grams: BTreeMap<String, Mat>, stats: Arc<LiveStats>) -> Self {
        let bytes: usize = grams.values().map(|g| g.numel() * 4).sum();
        let live = stats.live_sets.fetch_add(1, Ordering::Relaxed) + 1;
        stats.peak_sets.fetch_max(live, Ordering::Relaxed);
        let live_b = stats.live_bytes.fetch_add(bytes, Ordering::Relaxed) + bytes;
        stats.peak_bytes.fetch_max(live_b, Ordering::Relaxed);
        Self { block, grams, bytes, stats }
    }

    /// Gram lookup with a named-layer error (no panicking `[]` on the
    /// staged path).
    pub fn gram(&self, layer: &str) -> Result<&Mat> {
        self.grams.get(layer).ok_or_else(|| {
            anyhow::anyhow!(
                "no gram for layer {layer} in staged block {} (have: {})",
                self.block,
                self.grams.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// f32 payload bytes of the checked-out grams.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.grams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grams.is_empty()
    }
}

impl Drop for GramSet {
    fn drop(&mut self) {
        self.stats.live_sets.fetch_sub(1, Ordering::Relaxed);
        self.stats.live_bytes.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// CalibState
// ---------------------------------------------------------------------------

/// One of a block's four pruned linears, in model order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockSlot {
    Wqkv,
    Wo,
    Wup,
    Wdown,
}

impl BlockSlot {
    /// Model-order slots, matching `GptConfig::layers()` within a block.
    pub const ALL: [BlockSlot; 4] = [BlockSlot::Wqkv, BlockSlot::Wo, BlockSlot::Wup, BlockSlot::Wdown];
}

/// Intra-block activations stashed between [`CalibState::layer_gram`]
/// calls so the strictly-sequential granularity never recomputes a
/// stage (one activation set per stage is live at a time).
struct Stash {
    block: usize,
    /// Last slot whose gram was produced.
    slot: BlockSlot,
    /// ln1 outputs (inputs to `wqkv`/attention), then ln2 outputs after
    /// the `Wup` step (inputs to `wup`).
    pre: Vec<Mat>,
    /// Attention outputs (inputs to `wo`).
    attn: Vec<Mat>,
    /// GELU'd MLP activations (inputs to `wdown`).
    up: Vec<Mat>,
}

/// Streaming calibration state: per-sequence residual streams advanced
/// block by block, yielding one block's grams on demand (parallel over
/// sequences).  See the module docs for the drive protocol.
pub struct CalibState {
    hiddens: Vec<Mat>,
    names: Vec<BlockNames>,
    n_heads: usize,
    seq_len: usize,
    stash: Option<Stash>,
    stats: Arc<LiveStats>,
}

impl CalibState {
    /// Validate + embed `seqs` and take them as the initial hiddens.
    pub fn new(model: &Gpt, seqs: &[Vec<u8>]) -> Result<Self> {
        Self::from_prefix(model, EmbedPrefix::new(model, seqs)?)
    }

    /// Resume from a (possibly memoized) embed prefix.
    pub fn from_prefix(model: &Gpt, prefix: EmbedPrefix) -> Result<Self> {
        ensure!(!prefix.hiddens.is_empty(), "empty embed prefix");
        ensure!(
            prefix.hiddens[0].cols == model.cfg.d_model,
            "embed prefix width {} != model d_model {}",
            prefix.hiddens[0].cols,
            model.cfg.d_model
        );
        Ok(Self {
            hiddens: prefix.hiddens,
            names: BlockNames::for_model(&model.cfg),
            n_heads: model.cfg.n_heads,
            seq_len: prefix.seq_len,
            stash: None,
            stats: Arc::new(LiveStats::default()),
        })
    }

    pub fn n_samples(&self) -> usize {
        self.hiddens.len()
    }

    pub fn seq_len(&self) -> usize {
        self.seq_len
    }

    /// Bit-exact digest of the current residual streams (dims + every
    /// f32 bit pattern, [`crate::util::prng::mix64`]-folded).  Stored
    /// in per-block checkpoints as the propagated-activation identity:
    /// on resume the rebuilt state must reproduce the digest recorded
    /// when a block's grams were computed before the block's
    /// checkpointed outputs are trusted.
    pub fn digest(&self) -> u64 {
        digest_hiddens(&self.hiddens)
    }

    /// Surrender the residual streams as an [`EmbedPrefix`] — the exit
    /// hand-off a fleet worker ships to its successor's shard.  Only
    /// meaningful after the last `advance` of a shard (the hiddens then
    /// are exactly what the next block would see).
    pub fn into_prefix(self) -> EmbedPrefix {
        EmbedPrefix { hiddens: self.hiddens, seq_len: self.seq_len }
    }

    /// Max gram sets simultaneously checked out so far.
    pub fn peak_live_sets(&self) -> usize {
        self.stats.peak_sets.load(Ordering::Relaxed)
    }

    /// Max bytes of gram matrices simultaneously checked out so far.
    pub fn peak_gram_bytes(&self) -> usize {
        self.stats.peak_bytes.load(Ordering::Relaxed)
    }

    /// Σ Xᵀ X over per-sequence activation matrices, reduced in
    /// sequence order (bit-identical to `Calibration::from_sequences`'s
    /// accumulation for the same activations).
    fn gram_of(xs: &[Mat]) -> Mat {
        let partials = parallel_map(xs.len(), |i| matmul_at_b(&xs[i], &xs[i]));
        let mut it = partials.into_iter();
        let mut acc = it.next().expect("at least one sequence");
        for g in it {
            acc.add_inplace(&g);
        }
        acc
    }

    fn block_name(&self, bi: usize) -> Result<&BlockNames> {
        self.names
            .get(bi)
            .ok_or_else(|| anyhow::anyhow!("block {bi} out of range ({} blocks)", self.names.len()))
    }

    /// All four grams of block `bi`, computed from the current hiddens
    /// with `model`'s current (possibly already-masked) weights.
    /// Parallel over sequences; one forward through the block.
    pub fn block_grams(&mut self, model: &Gpt, bi: usize) -> Result<GramSet> {
        ensure!(
            self.stash.is_none(),
            "block_grams called mid layer-gram sequence (finish the block with advance first)"
        );
        let names = self.block_name(bi)?.clone();
        let partials: Vec<BTreeMap<String, Mat>> = parallel_map(self.hiddens.len(), |i| {
            let mut x = self.hiddens[i].clone();
            let mut caps = Captures::new();
            forward_block(model, &names, &mut x, Some(&mut caps));
            caps.into_iter()
                .map(|(k, v)| (k, matmul_at_b(&v, &v)))
                .collect()
        });
        let mut grams: BTreeMap<String, Mat> = BTreeMap::new();
        for p in partials {
            for (name, g) in p {
                match grams.get_mut(&name) {
                    Some(acc) => acc.add_inplace(&g),
                    None => {
                        grams.insert(name, g);
                    }
                }
            }
        }
        Ok(GramSet::checkout(bi, grams, self.stats.clone()))
    }

    /// One gram at a time for the strictly-sequential granularity.
    /// Must be called in [`BlockSlot::ALL`] order within a block; each
    /// call uses `model`'s *current* weights, so a layer pruned between
    /// calls feeds the next gram its masked activations.
    pub fn layer_gram(&mut self, model: &Gpt, bi: usize, slot: BlockSlot) -> Result<GramSet> {
        let names = self.block_name(bi)?.clone();
        let n = self.hiddens.len();
        let expect_slot = |stash: &Option<Stash>, want: BlockSlot| -> Result<()> {
            match stash {
                Some(s) if s.block == bi && s.slot == want => Ok(()),
                _ => bail!(
                    "layer_gram({slot:?}) called out of order for block {bi} \
                     (slots must follow BlockSlot::ALL)"
                ),
            }
        };
        let (name, xs) = match slot {
            BlockSlot::Wqkv => {
                ensure!(
                    self.stash.is_none(),
                    "layer_gram(Wqkv) with a pending stash (finish the previous block first)"
                );
                let pre = parallel_map(n, |i| {
                    layernorm(&self.hiddens[i], model.mat(&names.ln1_g), model.mat(&names.ln1_b))
                });
                let g = Self::gram_of(&pre);
                self.stash = Some(Stash {
                    block: bi,
                    slot: BlockSlot::Wqkv,
                    pre,
                    attn: Vec::new(),
                    up: Vec::new(),
                });
                (names.wqkv.clone(), g)
            }
            BlockSlot::Wo => {
                expect_slot(&self.stash, BlockSlot::Wqkv)?;
                let stash = self.stash.as_mut().unwrap();
                let attn = {
                    let pre = &stash.pre;
                    let n_heads = self.n_heads;
                    parallel_map(n, |i| attention(&pre[i], model.mat(&names.wqkv), n_heads))
                };
                let g = Self::gram_of(&attn);
                stash.pre = Vec::new(); // ln1 outputs no longer needed
                stash.attn = attn;
                stash.slot = BlockSlot::Wo;
                (names.wo.clone(), g)
            }
            BlockSlot::Wup => {
                expect_slot(&self.stash, BlockSlot::Wo)?;
                let stash = self.stash.as_mut().unwrap();
                // residual after attention: x ← x + attn · woᵀ
                let x2 = {
                    let hiddens = &self.hiddens;
                    let attn = &stash.attn;
                    parallel_map(n, |i| {
                        let mut x = hiddens[i].clone();
                        x.add_inplace(&matmul_a_bt(&attn[i], model.mat(&names.wo)));
                        x
                    })
                };
                self.hiddens = x2;
                let pre = {
                    let hiddens = &self.hiddens;
                    parallel_map(n, |i| {
                        layernorm(&hiddens[i], model.mat(&names.ln2_g), model.mat(&names.ln2_b))
                    })
                };
                let g = Self::gram_of(&pre);
                let stash = self.stash.as_mut().unwrap();
                stash.attn = Vec::new();
                stash.pre = pre;
                stash.slot = BlockSlot::Wup;
                (names.wup.clone(), g)
            }
            BlockSlot::Wdown => {
                expect_slot(&self.stash, BlockSlot::Wup)?;
                let stash = self.stash.as_mut().unwrap();
                let up = {
                    let pre = &stash.pre;
                    parallel_map(n, |i| {
                        let mut u = matmul_a_bt(&pre[i], model.mat(&names.wup));
                        for v in &mut u.data {
                            *v = gelu(*v);
                        }
                        u
                    })
                };
                let g = Self::gram_of(&up);
                stash.pre = Vec::new();
                stash.up = up;
                stash.slot = BlockSlot::Wdown;
                (names.wdown.clone(), g)
            }
        };
        let mut grams = BTreeMap::new();
        grams.insert(name, xs);
        Ok(GramSet::checkout(bi, grams, self.stats.clone()))
    }

    /// Re-forward the hiddens through block `bi` with `model`'s current
    /// (masked) weights, producing the inputs block `bi+1` sees.  After
    /// a full [`CalibState::layer_gram`] sequence only the final MLP
    /// residual remains to apply; otherwise the block is recomputed.
    pub fn advance(&mut self, model: &Gpt, bi: usize) -> Result<()> {
        let names = self.block_name(bi)?.clone();
        let n = self.hiddens.len();
        if let Some(stash) = &self.stash {
            // validate before consuming: a misuse error must leave the
            // stash intact, not silently fall back to the full-block
            // path over half-advanced hiddens
            ensure!(
                stash.block == bi,
                "advance({bi}) with a stash for block {}",
                stash.block
            );
            ensure!(
                stash.slot == BlockSlot::Wdown,
                "advance({bi}) mid layer-gram sequence (last slot {:?})",
                stash.slot
            );
            let stash = self.stash.take().expect("checked above");
            // hiddens already hold the post-attention residual; finish
            // with x ← x + up · wdownᵀ
            let next = {
                let hiddens = &self.hiddens;
                let up = &stash.up;
                parallel_map(n, |i| {
                    let mut x = hiddens[i].clone();
                    x.add_inplace(&matmul_a_bt(&up[i], model.mat(&names.wdown)));
                    x
                })
            };
            self.hiddens = next;
            return Ok(());
        }
        let next = {
            let hiddens = &self.hiddens;
            parallel_map(n, |i| {
                let mut x = hiddens[i].clone();
                forward_block(model, &names, &mut x, None);
                x
            })
        };
        self.hiddens = next;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::Calibration;
    use crate::data::TokenBin;
    use crate::model::testutil::{random_model, tiny_cfg};

    fn setup() -> (Gpt, Vec<Vec<u8>>) {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 11);
        let bin = TokenBin::from_tokens(crate::data::corpus::generate(5, 4096));
        let seqs = bin.sample(cfg.seq_len, 5, 3);
        (model, seqs)
    }

    #[test]
    fn streaming_matches_one_shot_on_dense_model() {
        // with no masks applied between blocks, the streamed grams must
        // equal the one-shot dense calibration bit-for-bit
        let (model, seqs) = setup();
        let oneshot = Calibration::from_sequences(&model, &seqs).unwrap();
        let mut state = CalibState::new(&model, &seqs).unwrap();
        for bi in 0..model.cfg.n_layers {
            let gs = state.block_grams(&model, bi).unwrap();
            for l in &model.cfg.layers()[4 * bi..4 * bi + 4] {
                assert_eq!(
                    gs.gram(&l.name).unwrap().data,
                    oneshot.gram(&l.name).data,
                    "{}",
                    l.name
                );
            }
            drop(gs);
            state.advance(&model, bi).unwrap();
        }
        assert_eq!(state.peak_live_sets(), 1);
    }

    #[test]
    fn layer_grams_match_block_grams_on_dense_model() {
        // without intervening pruning, the strictly-sequential path must
        // produce the same grams as the whole-block path
        let (model, seqs) = setup();
        let mut a = CalibState::new(&model, &seqs).unwrap();
        let mut b = CalibState::new(&model, &seqs).unwrap();
        for bi in 0..model.cfg.n_layers {
            let block = a.block_grams(&model, bi).unwrap();
            for (slot, l) in BlockSlot::ALL.iter().zip(&model.cfg.layers()[4 * bi..]) {
                let single = b.layer_gram(&model, bi, *slot).unwrap();
                assert_eq!(
                    single.gram(&l.name).unwrap().data,
                    block.gram(&l.name).unwrap().data,
                    "{}",
                    l.name
                );
            }
            drop(block);
            a.advance(&model, bi).unwrap();
            b.advance(&model, bi).unwrap();
            for (x, y) in a.hiddens.iter().zip(&b.hiddens) {
                assert_eq!(x.data, y.data);
            }
        }
    }

    #[test]
    fn layer_gram_enforces_slot_order() {
        let (model, seqs) = setup();
        let mut state = CalibState::new(&model, &seqs).unwrap();
        assert!(state.layer_gram(&model, 0, BlockSlot::Wo).is_err());
        let _g = state.layer_gram(&model, 0, BlockSlot::Wqkv).unwrap();
        drop(_g);
        assert!(state.layer_gram(&model, 0, BlockSlot::Wdown).is_err());
        // and block_grams refuses to run mid-sequence
        assert!(state.block_grams(&model, 0).is_err());
    }

    #[test]
    fn gram_set_tracks_live_bytes_and_sets() {
        let (model, seqs) = setup();
        let mut state = CalibState::new(&model, &seqs).unwrap();
        let d = model.cfg.d_model;
        let ff = model.cfg.d_ff;
        let gs = state.block_grams(&model, 0).unwrap();
        assert_eq!(gs.len(), 4);
        // qkv/wo/wup grams are d×d, the wdown gram is d_ff×d_ff
        assert_eq!(gs.bytes(), (d * d * 3 + ff * ff) * 4);
        assert_eq!(state.peak_live_sets(), 1);
        assert_eq!(state.peak_gram_bytes(), gs.bytes());
        drop(gs);
        state.advance(&model, 0).unwrap();
        // a second checkout does not raise the peak beyond one set
        let gs = state.block_grams(&model, 1).unwrap();
        assert_eq!(state.peak_live_sets(), 1);
        drop(gs);
    }

    #[test]
    fn missing_layer_gram_is_a_named_error() {
        let (model, seqs) = setup();
        let mut state = CalibState::new(&model, &seqs).unwrap();
        let gs = state.block_grams(&model, 0).unwrap();
        let err = gs.gram("blocks.9.wqkv").unwrap_err().to_string();
        assert!(err.contains("blocks.9.wqkv"), "{err}");
        assert!(err.contains("block 0"), "{err}");
    }

    #[test]
    fn embed_prefix_rejects_mixed_lengths() {
        let (model, mut seqs) = setup();
        seqs[1].pop();
        let err = EmbedPrefix::new(&model, &seqs).unwrap_err().to_string();
        assert!(err.contains("mixed-length"), "{err}");
    }

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(CalibPolicy::parse("off").unwrap(), CalibPolicy::Dense);
        assert_eq!(CalibPolicy::parse("block").unwrap(), CalibPolicy::PropagateBlock);
        assert_eq!(CalibPolicy::parse("layer").unwrap(), CalibPolicy::PropagateLayer);
        assert!(CalibPolicy::parse("sideways").is_err());
        assert_eq!(CalibPolicy::PropagateLayer.label(), "layer");
        assert!(!CalibPolicy::Dense.is_propagated());
        assert!(CalibPolicy::PropagateBlock.is_propagated());
    }

    #[test]
    fn advance_with_masked_block_changes_downstream_grams() {
        let (model, seqs) = setup();
        // dense reference
        let mut dense = CalibState::new(&model, &seqs).unwrap();
        let _ = dense.block_grams(&model, 0).unwrap();
        dense.advance(&model, 0).unwrap();
        let dense_g1 = dense.block_grams(&model, 1).unwrap();

        // mask block 0's wup entirely and propagate through it
        let mut masks = BTreeMap::new();
        masks.insert(
            "blocks.0.wup".to_string(),
            Mat::zeros(model.cfg.d_ff, model.cfg.d_model),
        );
        let masked = model.apply_masks(&masks).unwrap();
        let mut staged = CalibState::new(&model, &seqs).unwrap();
        let _ = staged.block_grams(&masked, 0).unwrap();
        staged.advance(&masked, 0).unwrap();
        let staged_g1 = staged.block_grams(&masked, 1).unwrap();

        let name = "blocks.1.wqkv";
        let a = dense_g1.gram(name).unwrap();
        let b = staged_g1.gram(name).unwrap();
        assert!(a.max_abs_diff(b) > 1e-6, "propagation must shift the gram");
    }
}
