//! Calibration: collect per-layer gram matrices `G = XXᵀ` from forward
//! passes over calibration sequences.
//!
//! This is the paper's §2.3 memory trick: the FW objective and gradient
//! depend on X only through `G` (d_in × d_in) and `H = WG`, so the
//! calibration footprint is independent of the number of samples N and
//! sequence length L.  Batches are streamed: each captured activation
//! block (L × d_in) is folded into G and dropped.
//!
//! Two accumulation backends: native (`matmul_at_b`) and the AOT Pallas
//! `gram` kernel via PJRT (cross-checked in integration tests).
//!
//! [`Calibration`] is the *one-shot dense* path ([`CalibPolicy::Dense`],
//! `--propagate off`): one forward pass over the dense model, all
//! `4·n_layers` grams held at once.  The staged block-sequential
//! alternative lives in [`state`]: a [`CalibState`] streams one block's
//! grams at a time from the pruned-so-far hidden states, bounding peak
//! calibration memory at O(block) and pricing compounding error into
//! every layer's objective (see `coordinator::run_blocks`).

pub mod state;

pub use state::{BlockSlot, CalibPolicy, CalibState, EmbedPrefix, GramSet};

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::data::TokenBin;
use crate::model::{forward::forward, Gpt};
use crate::runtime::PjrtRuntime;
use crate::tensor::{matmul_at_b, Mat};
use crate::util::pool::parallel_map;

/// All sequences must be non-empty and equal-length: a gram sums
/// per-position outer products, so silently mixing lengths would skew
/// the per-layer scaling (and panics deep in the forward otherwise).
/// Shared by the one-shot paths here and [`EmbedPrefix::new`].
pub(crate) fn validate_seq_lens(seqs: &[Vec<u8>]) -> Result<usize> {
    ensure!(!seqs.is_empty(), "no calibration sequences");
    let seq_len = seqs[0].len();
    ensure!(seq_len > 0, "empty calibration sequence");
    for (i, s) in seqs.iter().enumerate() {
        ensure!(
            s.len() == seq_len,
            "mixed-length calibration sequences: sequence {i} has {} tokens, sequence 0 has {seq_len}",
            s.len()
        );
    }
    Ok(seq_len)
}

/// Per-layer gram matrices for one model + calibration sample.
#[derive(Clone)]
pub struct Calibration {
    /// Layer param name → G = XXᵀ (d_in × d_in), summed over all
    /// calibration positions.
    pub grams: BTreeMap<String, Mat>,
    pub n_samples: usize,
    pub seq_len: usize,
}

impl Calibration {
    /// Sample `n_samples` sequences from `bin` (seeded) and accumulate
    /// grams with the native backend, parallel over sequences.
    pub fn collect(model: &Gpt, bin: &TokenBin, n_samples: usize, seed: u64) -> Result<Self> {
        let seq_len = model.cfg.seq_len;
        let seqs = bin.sample(seq_len, n_samples, seed);
        Self::from_sequences(model, &seqs)
    }

    /// Accumulate grams from explicit sequences (native backend).
    /// Sequences must be non-empty and equal-length.
    pub fn from_sequences(model: &Gpt, seqs: &[Vec<u8>]) -> Result<Self> {
        let seq_len = validate_seq_lens(seqs)?;
        let layers = model.cfg.layers();

        // Map over sequences in parallel (each forward is itself cheap);
        // reduce partial grams at the end.
        let partials: Vec<BTreeMap<String, Mat>> = parallel_map(seqs.len(), |i| {
            let out = forward(model, &seqs[i], true);
            let caps = out.captures.unwrap();
            let mut grams = BTreeMap::new();
            for l in &layers {
                let x = &caps[&l.name]; // (L, d_in)
                grams.insert(l.name.clone(), matmul_at_b(x, x));
            }
            grams
        });

        let mut grams: BTreeMap<String, Mat> = BTreeMap::new();
        for p in partials {
            for (name, g) in p {
                match grams.get_mut(&name) {
                    Some(acc) => acc.add_inplace(&g),
                    None => {
                        grams.insert(name, g);
                    }
                }
            }
        }
        Ok(Self { grams, n_samples: seqs.len(), seq_len })
    }

    /// Accumulate grams through the AOT Pallas `gram` kernel: native
    /// forward captures X, PJRT folds each chunk into G.
    pub fn from_sequences_pjrt(
        model: &Gpt,
        seqs: &[Vec<u8>],
        runtime: &PjrtRuntime,
    ) -> Result<Self> {
        let seq_len = validate_seq_lens(seqs)?;
        let layers = model.cfg.layers();
        let mut grams: BTreeMap<String, Mat> = layers
            .iter()
            .map(|l| (l.name.clone(), Mat::zeros(l.d_in, l.d_in)))
            .collect();
        for seq in seqs {
            let out = forward(model, seq, true);
            let caps = out.captures.unwrap();
            for l in &layers {
                let x = caps[&l.name].transpose(); // (d_in, L) chunk
                let g = grams.get_mut(&l.name).unwrap();
                *g = runtime.gram_acc(g, &x)?;
            }
        }
        Ok(Self { grams, n_samples: seqs.len(), seq_len })
    }

    /// Gram lookup as a `Result` with a named-layer error — what the
    /// coordinator's dispatch paths use instead of a panicking index.
    pub fn try_gram(&self, layer: &str) -> Result<&Mat> {
        self.grams
            .get(layer)
            .ok_or_else(|| anyhow::anyhow!("no calibration gram for layer {layer}"))
    }

    /// Panicking gram lookup (callers that have already validated the
    /// layer set; prefer [`Calibration::try_gram`] on fallible paths).
    pub fn gram(&self, layer: &str) -> &Mat {
        self.try_gram(layer).unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::{random_model, tiny_cfg};

    fn test_bin(n: usize) -> TokenBin {
        TokenBin::from_tokens(crate::data::corpus::generate(5, n))
    }

    #[test]
    fn grams_are_psd_and_shaped() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 1);
        let calib = Calibration::collect(&model, &test_bin(4096), 6, 3).unwrap();
        assert_eq!(calib.grams.len(), 4 * cfg.n_layers);
        for l in cfg.layers() {
            let g = calib.gram(&l.name);
            assert_eq!((g.rows, g.cols), (l.d_in, l.d_in));
            // symmetric
            for i in 0..g.rows {
                for j in 0..i {
                    assert!((g.at(i, j) - g.at(j, i)).abs() < 2e-2 * (1.0 + g.at(i, j).abs()));
                }
                // PSD necessary condition: nonneg diagonal
                assert!(g.at(i, i) >= -1e-4);
            }
        }
    }

    #[test]
    fn gram_scales_with_samples() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 2);
        let bin = test_bin(8192);
        let c1 = Calibration::collect(&model, &bin, 2, 7).unwrap();
        let c2 = Calibration::collect(&model, &bin, 8, 7).unwrap();
        // more samples => larger trace (G is a sum, not a mean)
        let l = &cfg.layers()[0].name;
        let tr1: f32 = (0..16).map(|i| c1.gram(l).at(i, i)).sum();
        let tr2: f32 = (0..16).map(|i| c2.gram(l).at(i, i)).sum();
        assert!(tr2 > tr1 * 2.0, "{tr2} vs {tr1}");
    }

    #[test]
    fn mixed_length_sequences_are_rejected() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 4);
        let mut seqs = test_bin(4096).sample(cfg.seq_len, 3, 1);
        seqs[2].truncate(cfg.seq_len - 5);
        let err = Calibration::from_sequences(&model, &seqs).unwrap_err().to_string();
        assert!(err.contains("mixed-length"), "{err}");
        assert!(err.contains("sequence 2"), "{err}");
    }

    #[test]
    fn try_gram_names_the_missing_layer() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 1);
        let calib = Calibration::collect(&model, &test_bin(4096), 2, 3).unwrap();
        assert!(calib.try_gram("blocks.0.wqkv").is_ok());
        let err = calib.try_gram("blocks.7.wo").unwrap_err().to_string();
        assert!(err.contains("blocks.7.wo"), "{err}");
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 3);
        let bin = test_bin(4096);
        let a = Calibration::collect(&model, &bin, 4, 11).unwrap();
        let b = Calibration::collect(&model, &bin, 4, 11).unwrap();
        let l = &cfg.layers()[2].name;
        assert_eq!(a.gram(l).data, b.gram(l).data);
    }
}
