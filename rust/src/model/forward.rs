//! Native transformer forward pass (f32), numerically matching
//! `python/compile/model.py`.
//!
//! The pass is a *resumable stepper*: [`forward_embed`] produces the
//! initial hidden states, [`forward_block`] advances them through one
//! transformer block (optionally capturing the four pruned-linear
//! inputs), and [`forward_head`] applies the final layernorm + weight-
//! tied head.  [`forward`] is the historical one-shot wrapper over the
//! three stages; the staged block-sequential calibration pipeline
//! ([`crate::calib::CalibState`]) drives the stages directly so hidden
//! states can be re-forwarded through already-masked blocks.
//!
//! Used for (a) calibration-activation capture — the X matrices behind
//! `G = XXᵀ` — and (b) evaluation when the PJRT path is not selected.
//! An integration test checks logits against the AOT `model_fwd`
//! executable to ~1e-3.
//!
//! The stepper is generic over [`ForwardModel`]: the layer-application
//! seam through which the four pruned linears per block are applied.
//! The dense [`Gpt`] routes them through the blocked dense matmul; a
//! [`crate::model::compiled::CompiledModel`] dispatches per layer to
//! packed CSR / n:m kernels.  Everything that is never pruned
//! (embeddings, layernorm gains/biases, the weight-tied head) stays a
//! dense [`Mat`] on both sides of the seam.

use std::collections::BTreeMap;

use crate::tensor::{matmul_a_bt, Mat};

use super::{Gpt, GptConfig};

/// Layer-application seam: anything the transformer stepper can run on.
///
/// `linear_into` is the only place a pruned linear's weights are
/// touched during a forward; implementations choose the representation
/// (dense, CSR, packed n:m) per layer.  `accumulate` folds the residual
/// add into the kernel (`out += x·Wᵀ`).
pub trait ForwardModel {
    fn cfg(&self) -> &GptConfig;
    /// A never-pruned dense parameter: embeddings, layernorm params.
    fn dense(&self, name: &str) -> &Mat;
    /// out = x·Wᵀ for pruned linear `name` (out += x·Wᵀ when
    /// `accumulate`); `out` must be pre-shaped (x.rows × d_out).
    fn linear_into(&self, name: &str, x: &Mat, out: &mut Mat, accumulate: bool);
    fn block_names(&self) -> &[BlockNames];
}

impl ForwardModel for Gpt {
    fn cfg(&self) -> &GptConfig {
        &self.cfg
    }

    fn dense(&self, name: &str) -> &Mat {
        self.mat(name)
    }

    fn linear_into(&self, name: &str, x: &Mat, out: &mut Mat, accumulate: bool) {
        let c = matmul_a_bt(x, self.mat(name));
        if accumulate {
            out.add_inplace(&c);
        } else {
            *out = c;
        }
    }

    fn block_names(&self) -> &[BlockNames] {
        Gpt::block_names(self)
    }
}

/// Per-layer linear inputs captured during a forward pass, keyed by the
/// pruned-layer param name; each is (L, d_in) for one sequence.
pub type Captures = BTreeMap<String, Mat>;

pub struct ForwardOutput {
    /// (L, vocab) logits.
    pub logits: Mat,
    /// Present when capture was requested.
    pub captures: Option<Captures>,
}

/// Precomputed parameter names of one transformer block.
///
/// The block loop used to re-`format!` all eight param names on every
/// call (per block, per sequence); callers build these once and reuse
/// them across forwards.
#[derive(Clone, Debug)]
pub struct BlockNames {
    /// 0-based block index.
    pub block: usize,
    pub ln1_g: String,
    pub ln1_b: String,
    pub wqkv: String,
    pub wo: String,
    pub ln2_g: String,
    pub ln2_b: String,
    pub wup: String,
    pub wdown: String,
}

impl BlockNames {
    pub fn new(block: usize) -> Self {
        let p = format!("blocks.{block}.");
        Self {
            block,
            ln1_g: format!("{p}ln1_g"),
            ln1_b: format!("{p}ln1_b"),
            wqkv: format!("{p}wqkv"),
            wo: format!("{p}wo"),
            ln2_g: format!("{p}ln2_g"),
            ln2_b: format!("{p}ln2_b"),
            wup: format!("{p}wup"),
            wdown: format!("{p}wdown"),
        }
    }

    /// Names for every block of `cfg`, in block order.
    pub fn for_model(cfg: &GptConfig) -> Vec<BlockNames> {
        (0..cfg.n_layers).map(Self::new).collect()
    }
}

pub(crate) fn layernorm(x: &Mat, g: &Mat, b: &Mat) -> Mat {
    let eps = 1e-5f32;
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let row = x.row(i);
        let mean = row.iter().sum::<f32>() / x.cols as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let orow = out.row_mut(i);
        for j in 0..x.cols {
            orow[j] = (row[j] - mean) * inv * g.data[j] + b.data[j];
        }
    }
    out
}

/// tanh-approximation GELU, identical to the jax model.
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn softmax_row(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Causal multi-head self-attention for one sequence; `h` is (L, d).
/// Thin wrapper computing the qkv projection densely — the generic
/// stepper projects through the [`ForwardModel`] seam first and calls
/// [`attention_from_qkv`] directly.
pub(crate) fn attention(h: &Mat, wqkv: &Mat, n_heads: usize) -> Mat {
    let qkv = matmul_a_bt(h, wqkv); // (L, 3d)
    attention_from_qkv(&qkv, n_heads)
}

/// Attention over a precomputed `qkv` projection (L, 3d).  One (L×L)
/// scores buffer is reused across heads — every entry of a row is
/// overwritten before the softmax, so reuse is exact.
pub(crate) fn attention_from_qkv(qkv: &Mat, n_heads: usize) -> Mat {
    let l = qkv.rows;
    let d = qkv.cols / 3;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();

    let mut out = Mat::zeros(l, d);
    let mut scores = Mat::zeros(l, l);
    for head in 0..n_heads {
        let (qoff, koff, voff) = (head * hd, d + head * hd, 2 * d + head * hd);
        // scores (L, L) lower-triangular
        for i in 0..l {
            let qrow = &qkv.row(i)[qoff..qoff + hd];
            let srow = scores.row_mut(i);
            for j in 0..=i {
                let krow = &qkv.row(j)[koff..koff + hd];
                srow[j] = crate::tensor::matmul::dot(qrow, krow) * scale;
            }
            for s in srow.iter_mut().skip(i + 1) {
                *s = f32::NEG_INFINITY;
            }
            softmax_row(&mut srow[..]);
        }
        // out_head = scores · V_head
        for i in 0..l {
            let srow = scores.row(i);
            let orow = &mut out.row_mut(i)[head * hd..(head + 1) * hd];
            for j in 0..=i {
                let vrow = &qkv.row(j)[voff..voff + hd];
                let s = srow[j];
                for (o, v) in orow.iter_mut().zip(vrow) {
                    *o += s * v;
                }
            }
        }
    }
    out
}

/// Stage 1 of the stepper: token + position embeddings for one
/// sequence — the (L, d_model) initial residual stream.
pub fn forward_embed<M: ForwardModel + ?Sized>(model: &M, tokens: &[u8]) -> Mat {
    let cfg = model.cfg();
    let l = tokens.len();
    assert!(l <= cfg.seq_len, "sequence longer than model seq_len");
    let d = cfg.d_model;

    let tok_emb = model.dense("tok_emb");
    let pos_emb = model.dense("pos_emb");
    let mut x = Mat::zeros(l, d);
    for (i, &t) in tokens.iter().enumerate() {
        let te = tok_emb.row(t as usize);
        let pe = pos_emb.row(i);
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = te[j] + pe[j];
        }
    }
    x
}

/// Stage 2 of the stepper: advance the residual stream `x` through
/// block `names.block`, using `model`'s *current* weights (which may
/// already carry pruning masks).  When `captures` is provided, the four
/// pruned-linear inputs are recorded under their full param names.
pub fn forward_block<M: ForwardModel + ?Sized>(
    model: &M,
    names: &BlockNames,
    x: &mut Mat,
    mut captures: Option<&mut Captures>,
) {
    let h = layernorm(x, model.dense(&names.ln1_g), model.dense(&names.ln1_b));
    if let Some(c) = captures.as_deref_mut() {
        c.insert(names.wqkv.clone(), h.clone());
    }
    let d = h.cols;
    let mut qkv = Mat::zeros(h.rows, 3 * d);
    model.linear_into(&names.wqkv, &h, &mut qkv, false);
    let attn_h = attention_from_qkv(&qkv, model.cfg().n_heads);
    if let Some(c) = captures.as_deref_mut() {
        c.insert(names.wo.clone(), attn_h.clone());
    }
    // residual add folded into the kernel: x += attn_h · Wᵀ
    model.linear_into(&names.wo, &attn_h, x, true);

    let h2 = layernorm(x, model.dense(&names.ln2_g), model.dense(&names.ln2_b));
    if let Some(c) = captures.as_deref_mut() {
        c.insert(names.wup.clone(), h2.clone());
    }
    let mut up = Mat::zeros(h2.rows, model.cfg().d_ff);
    model.linear_into(&names.wup, &h2, &mut up, false);
    for v in &mut up.data {
        *v = gelu(*v);
    }
    if let Some(c) = captures.as_deref_mut() {
        c.insert(names.wdown.clone(), up.clone());
    }
    model.linear_into(&names.wdown, &up, x, true);
}

/// Stage 3 of the stepper: final layernorm + weight-tied head (the
/// head is never pruned, so it stays a dense matmul on every
/// representation).
pub fn forward_head<M: ForwardModel + ?Sized>(model: &M, x: &Mat) -> Mat {
    let xf = layernorm(x, model.dense("lnf_g"), model.dense("lnf_b"));
    matmul_a_bt(&xf, model.dense("tok_emb"))
}

/// Forward one sequence of token ids; optionally capture pruned-linear
/// inputs.  Mirrors `model.forward` in python.  Thin wrapper over the
/// resumable stepper: embed → blocks → head.
pub fn forward<M: ForwardModel + ?Sized>(model: &M, tokens: &[u8], capture: bool) -> ForwardOutput {
    let mut x = forward_embed(model, tokens);
    let mut captures: Option<Captures> = capture.then(BTreeMap::new);
    for names in model.block_names() {
        forward_block(model, names, &mut x, captures.as_mut());
    }
    let logits = forward_head(model, &x);
    ForwardOutput { logits, captures }
}

/// Mean next-token negative log-likelihood of one sequence (positions
/// 0..L-1 predict 1..L), from raw logits.
pub fn sequence_nll(logits: &Mat, tokens: &[u8]) -> f64 {
    let l = tokens.len();
    assert_eq!(logits.rows, l);
    let mut total = 0.0f64;
    for i in 0..l - 1 {
        let row = logits.row(i);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let logsum = row.iter().map(|&x| ((x - max) as f64).exp()).sum::<f64>().ln() + max as f64;
        let tgt = tokens[i + 1] as usize;
        total += logsum - row[tgt] as f64;
    }
    total / (l - 1) as f64
}

/// Total log-likelihood of a sequence (for zero-shot A/B scoring).
pub fn sequence_loglik(logits: &Mat, tokens: &[u8]) -> f64 {
    -sequence_nll(logits, tokens) * (tokens.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::{random_model, tiny_cfg};

    #[test]
    fn forward_shapes_and_captures() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 3);
        let tokens: Vec<u8> = (0..cfg.seq_len as u8).map(|i| i % 60).collect();
        let out = forward(&model, &tokens, true);
        assert_eq!(out.logits.rows, cfg.seq_len);
        assert_eq!(out.logits.cols, cfg.vocab_size);
        let caps = out.captures.unwrap();
        assert_eq!(caps.len(), 4 * cfg.n_layers);
        assert_eq!(caps["blocks.0.wqkv"].cols, cfg.d_model);
        assert_eq!(caps["blocks.0.wdown"].cols, cfg.d_ff);
    }

    #[test]
    fn stepper_matches_one_shot_wrapper() {
        // driving embed → block → head by hand must reproduce forward()
        // exactly (the staged calibration pipeline relies on this)
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 7);
        let tokens: Vec<u8> = (0..24).map(|i| (i * 5) % 250).collect();
        let whole = forward(&model, &tokens, true);

        let mut x = forward_embed(&model, &tokens);
        let mut caps = Captures::new();
        for bi in 0..cfg.n_layers {
            forward_block(&model, &BlockNames::new(bi), &mut x, Some(&mut caps));
        }
        let logits = forward_head(&model, &x);
        assert_eq!(logits.data, whole.logits.data);
        let wcaps = whole.captures.unwrap();
        assert_eq!(caps.len(), wcaps.len());
        for (k, v) in &caps {
            assert_eq!(v.data, wcaps[k].data, "{k}");
        }
    }

    #[test]
    fn block_names_match_param_names() {
        let cfg = tiny_cfg();
        let names = BlockNames::for_model(&cfg);
        assert_eq!(names.len(), cfg.n_layers);
        assert_eq!(names[1].wqkv, "blocks.1.wqkv");
        assert_eq!(names[1].ln2_b, "blocks.1.ln2_b");
        assert_eq!(names[0].wdown, "blocks.0.wdown");
    }

    #[test]
    fn causality() {
        // changing a later token must not affect earlier logits
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 4);
        let mut t1: Vec<u8> = (0..16).map(|i| (i * 3) % 60).collect();
        let out1 = forward(&model, &t1, false);
        t1[15] = 59;
        let out2 = forward(&model, &t1, false);
        for i in 0..15 {
            for j in 0..cfg.vocab_size {
                assert!((out1.logits.at(i, j) - out2.logits.at(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn nll_of_uniform_logits_is_log_vocab() {
        let cfg = tiny_cfg();
        let tokens: Vec<u8> = vec![1, 2, 3, 4];
        let logits = Mat::zeros(4, cfg.vocab_size);
        let nll = sequence_nll(&logits, &tokens);
        assert!((nll - (cfg.vocab_size as f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn zero_mask_changes_logits() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 5);
        let tokens: Vec<u8> = (0..16).collect();
        let base = forward(&model, &tokens, false);
        let mut masks = std::collections::BTreeMap::new();
        masks.insert("blocks.0.wup".to_string(), Mat::zeros(cfg.d_ff, cfg.d_model));
        let pruned = model.apply_masks(&masks).unwrap();
        let out = forward(&pruned, &tokens, false);
        assert!(base.logits.max_abs_diff(&out.logits) > 1e-4);
    }
}
