//! Compiled sparse models — the deployment artifact of a pruning run.
//!
//! A [`CompiledModel`] packs each pruned linear of a [`Gpt`] into the
//! cheapest representation its mask supports — dense (`W ⊙ M`), CSR
//! ([`CsrMat`]), or packed n:m ([`NmMat`]) — straight from a pruning
//! result's masks and reconstructed weights, without materializing a
//! second dense model.  It implements the stepper's
//! [`ForwardModel`] seam, so perplexity evaluation reuses the exact
//! same `forward_embed/block/head` code as the dense path, and adds a
//! KV-cached batch=1 decode loop ([`CompiledModel::decode_step`]) for
//! the latency-bound `generate` regime where sparsity pays most: the
//! decode step runs on the `matvec_into` kernels, never the full
//! matmul.
//!
//! Format choice (`auto`):
//! 1. mask has n:m structure (every aligned group ≤ `keep` survivors,
//!    packed density ≈ raw density) → [`NmMat`];
//! 2. density above [`DEFAULT_CROSSOVER`] → masked dense (index
//!    chasing loses to the blocked dense matmul there);
//! 3. otherwise → [`CsrMat`].

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::matmul::dot;
use crate::tensor::nm::NmMat;
use crate::tensor::sparse::CsrMat;
use crate::tensor::{matmul_a_bt, Mat};
use crate::util::prng::Xoshiro256;

use super::forward::{gelu, BlockNames, ForwardModel};
use super::{Gpt, GptConfig};

/// Measured CSR-vs-dense crossover density: above this, the blocked
/// dense matmul beats index chasing (see `benches/sparse_infer.rs`),
/// so `auto` keeps the layer dense.
pub const DEFAULT_CROSSOVER: f64 = 0.4;

/// User-selectable packing policy (`--sparse-format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseFormat {
    /// Per-layer choice from mask pattern + density crossover.
    Auto,
    /// Masked dense everywhere (the baseline the benches A/B against).
    Dense,
    /// CSR everywhere.
    Csr,
    /// Packed n:m everywhere; compilation fails if a mask has no n:m
    /// structure.
    Nm,
}

impl SparseFormat {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "auto" => Ok(SparseFormat::Auto),
            "dense" => Ok(SparseFormat::Dense),
            "csr" => Ok(SparseFormat::Csr),
            "nm" => Ok(SparseFormat::Nm),
            _ => bail!("unknown sparse format {s:?} (want auto|dense|csr|nm)"),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            SparseFormat::Auto => "auto",
            SparseFormat::Dense => "dense",
            SparseFormat::Csr => "csr",
            SparseFormat::Nm => "nm",
        }
    }
}

/// One compiled linear layer.
#[derive(Clone, Debug)]
pub enum LayerWeights {
    DenseW(Mat),
    Csr(CsrMat),
    Nm(NmMat),
}

impl LayerWeights {
    pub fn label(&self) -> &'static str {
        match self {
            LayerWeights::DenseW(_) => "dense",
            LayerWeights::Csr(_) => "csr",
            LayerWeights::Nm(_) => "nm",
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            LayerWeights::DenseW(w) => w.numel() * 4,
            LayerWeights::Csr(c) => c.size_bytes(),
            LayerWeights::Nm(n) => n.size_bytes(),
        }
    }

    /// out = a·Wᵀ (out += when `accumulate`) — the prefill kernel.
    pub fn matmul_a_bt_into(&self, a: &Mat, out: &mut Mat, accumulate: bool) {
        match self {
            LayerWeights::DenseW(w) => {
                let c = matmul_a_bt(a, w);
                if accumulate {
                    out.add_inplace(&c);
                } else {
                    *out = c;
                }
            }
            LayerWeights::Csr(c) => c.matmul_a_bt_into(a, out, accumulate),
            LayerWeights::Nm(n) => n.matmul_a_bt_into(a, out, accumulate),
        }
    }

    /// y = W·x (y += when `accumulate`) — the batch=1 decode kernel.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32], accumulate: bool) {
        match self {
            LayerWeights::DenseW(w) => {
                assert_eq!(x.len(), w.cols);
                assert_eq!(y.len(), w.rows);
                for i in 0..w.rows {
                    let acc = dot(w.row(i), x);
                    if accumulate {
                        y[i] += acc;
                    } else {
                        y[i] = acc;
                    }
                }
            }
            LayerWeights::Csr(c) => c.matvec_into(x, y, accumulate),
            LayerWeights::Nm(n) => n.matvec_into(x, y, accumulate),
        }
    }
}

/// A model compiled for sparse inference.  Never-pruned params
/// (embeddings, layernorms, the tied head) stay dense; the 4·n_layers
/// pruned linears each carry their packed representation.
pub struct CompiledModel {
    cfg: GptConfig,
    dense_params: BTreeMap<String, Mat>,
    layer_weights: BTreeMap<String, LayerWeights>,
    names: Vec<BlockNames>,
}

impl CompiledModel {
    /// Pack `base`'s pruned linears under `masks`, preferring
    /// reconstructed weights from `new_weights` (SparseGPT / FW-refine
    /// output) over the base weights.  Layers without a mask stay
    /// dense.  No second dense `Gpt` is ever materialized — each layer
    /// goes straight from (weights, mask) to its packed form.
    pub fn compile(
        base: &Gpt,
        masks: &BTreeMap<String, Mat>,
        new_weights: &BTreeMap<String, Mat>,
        format: SparseFormat,
        crossover: f64,
    ) -> Result<Self> {
        let cfg = base.cfg.clone();
        let mut layer_weights = BTreeMap::new();
        for l in cfg.layers() {
            let w = new_weights
                .get(&l.name)
                .or_else(|| base.params.get(&l.name))
                .with_context(|| format!("compile: missing weights for {}", l.name))?;
            ensure!(
                (w.rows, w.cols) == (l.d_out, l.d_in),
                "compile: {} has shape {}x{}, want {}x{}",
                l.name,
                w.rows,
                w.cols,
                l.d_out,
                l.d_in
            );
            let lw = match masks.get(&l.name) {
                None => LayerWeights::DenseW(w.clone()),
                Some(mask) => {
                    ensure!(
                        (mask.rows, mask.cols) == (w.rows, w.cols),
                        "compile: mask shape mismatch for {}",
                        l.name
                    );
                    pack_layer(w, mask, format, crossover)
                        .with_context(|| format!("compile: packing {}", l.name))?
                }
            };
            layer_weights.insert(l.name.clone(), lw);
        }
        let dense_params: BTreeMap<String, Mat> = base
            .params
            .iter()
            .filter(|(name, _)| !layer_weights.contains_key(name.as_str()))
            .map(|(name, m)| (name.clone(), m.clone()))
            .collect();
        let names = BlockNames::for_model(&cfg);
        Ok(Self { cfg, dense_params, layer_weights, names })
    }

    /// (dense, csr, nm) layer counts over the pruned linears.
    pub fn format_counts(&self) -> (usize, usize, usize) {
        let mut c = (0, 0, 0);
        for lw in self.layer_weights.values() {
            match lw {
                LayerWeights::DenseW(_) => c.0 += 1,
                LayerWeights::Csr(_) => c.1 += 1,
                LayerWeights::Nm(_) => c.2 += 1,
            }
        }
        c
    }

    /// Bytes of the packed pruned linears.
    pub fn packed_bytes(&self) -> usize {
        self.layer_weights.values().map(LayerWeights::size_bytes).sum()
    }

    /// Bytes the same linears occupy dense (f32).
    pub fn dense_equiv_bytes(&self) -> usize {
        self.cfg.layers().iter().map(|l| l.d_out * l.d_in * 4).sum()
    }

    /// Per-layer packed format, for reporting.
    pub fn layer_format(&self, name: &str) -> Option<&'static str> {
        self.layer_weights.get(name).map(LayerWeights::label)
    }

    /// One-line compile report: `formats dense/csr/nm = a/b/c, packed
    /// X KiB vs dense Y KiB`.
    pub fn summary(&self) -> String {
        let (d, c, n) = self.format_counts();
        format!(
            "formats dense/csr/nm = {}/{}/{}, packed {:.1} KiB vs dense {:.1} KiB",
            d,
            c,
            n,
            self.packed_bytes() as f64 / 1024.0,
            self.dense_equiv_bytes() as f64 / 1024.0
        )
    }

    fn layer(&self, name: &str) -> &LayerWeights {
        self.layer_weights
            .get(name)
            .unwrap_or_else(|| panic!("missing compiled layer {name}"))
    }

    /// Fresh KV cache for a batch=1 decode stream.
    pub fn begin_decode(&self) -> DecodeState {
        let d = self.cfg.d_model;
        DecodeState {
            k: (0..self.cfg.n_layers).map(|_| Mat::zeros(0, d)).collect(),
            v: (0..self.cfg.n_layers).map(|_| Mat::zeros(0, d)).collect(),
            pos: 0,
        }
    }

    /// Advance the decode stream by one token; returns the next-token
    /// logits.  Every pruned linear runs through `matvec_into` — one
    /// row of work, no full-sequence matmul, attention against the
    /// cached K/V only.
    pub fn decode_step(&self, token: u8, st: &mut DecodeState) -> Vec<f32> {
        let cfg = &self.cfg;
        let (d, n_heads) = (cfg.d_model, cfg.n_heads);
        let hd = d / n_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let pos = st.pos;
        assert!(pos < cfg.seq_len, "decode past seq_len {}", cfg.seq_len);
        assert!((token as usize) < cfg.vocab_size, "token out of vocab");

        let te = self.dense_params["tok_emb"].row(token as usize);
        let pe = self.dense_params["pos_emb"].row(pos);
        let mut x: Vec<f32> = te.iter().zip(pe).map(|(a, b)| a + b).collect();

        let mut qkv = vec![0.0f32; 3 * d];
        let mut scores = vec![0.0f32; pos + 1];
        for (bi, names) in self.names.iter().enumerate() {
            let h = layernorm_row(
                &x,
                self.dense_params[&names.ln1_g].row(0),
                self.dense_params[&names.ln1_b].row(0),
            );
            self.layer(&names.wqkv).matvec_into(&h, &mut qkv, false);
            push_row(&mut st.k[bi], &qkv[d..2 * d]);
            push_row(&mut st.v[bi], &qkv[2 * d..3 * d]);

            let mut attn = vec![0.0f32; d];
            for head in 0..n_heads {
                let ho = head * hd;
                let q = &qkv[ho..ho + hd];
                for (j, s) in scores.iter_mut().enumerate() {
                    *s = dot(q, &st.k[bi].row(j)[ho..ho + hd]) * scale;
                }
                softmax_slice(&mut scores);
                for (j, &s) in scores.iter().enumerate() {
                    let vrow = &st.v[bi].row(j)[ho..ho + hd];
                    for (o, vv) in attn[ho..ho + hd].iter_mut().zip(vrow) {
                        *o += s * vv;
                    }
                }
            }
            self.layer(&names.wo).matvec_into(&attn, &mut x, true);

            let h2 = layernorm_row(
                &x,
                self.dense_params[&names.ln2_g].row(0),
                self.dense_params[&names.ln2_b].row(0),
            );
            let mut up = vec![0.0f32; cfg.d_ff];
            self.layer(&names.wup).matvec_into(&h2, &mut up, false);
            for v in &mut up {
                *v = gelu(*v);
            }
            self.layer(&names.wdown).matvec_into(&up, &mut x, true);
        }

        let xf = layernorm_row(
            &x,
            self.dense_params["lnf_g"].row(0),
            self.dense_params["lnf_b"].row(0),
        );
        let tok_emb = &self.dense_params["tok_emb"];
        let mut logits = vec![0.0f32; cfg.vocab_size];
        for (r, l) in logits.iter_mut().enumerate() {
            *l = dot(tok_emb.row(r), &xf);
        }
        st.pos += 1;
        logits
    }

    /// Greedy (`temperature <= 0`) or seeded temperature sampling off
    /// the decode stream's `forward_head` logits.  Generation stops at
    /// `prompt.len() + max_new` tokens or the model's `seq_len`,
    /// whichever comes first.
    pub fn generate(&self, prompt: &[u8], p: &GenerateParams) -> Result<Generated> {
        ensure!(!prompt.is_empty(), "generate: empty prompt");
        ensure!(
            prompt.len() <= self.cfg.seq_len,
            "generate: prompt len {} exceeds seq_len {}",
            prompt.len(),
            self.cfg.seq_len
        );
        for &t in prompt {
            ensure!(
                (t as usize) < self.cfg.vocab_size,
                "generate: token {t} out of vocab {}",
                self.cfg.vocab_size
            );
        }
        let cap = self.cfg.seq_len.min(prompt.len() + p.max_new);
        let mut st = self.begin_decode();
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.decode_step(t, &mut st);
        }
        let mut rng = Xoshiro256::new(p.seed);
        let mut tokens = prompt.to_vec();
        let mut decode_steps = prompt.len();
        while tokens.len() < cap {
            let next = sample_token(&logits, p.temperature, &mut rng);
            tokens.push(next);
            if tokens.len() < cap {
                logits = self.decode_step(next, &mut st);
                decode_steps += 1;
            }
        }
        Ok(Generated { prompt_len: prompt.len(), tokens, decode_steps })
    }
}

impl ForwardModel for CompiledModel {
    fn cfg(&self) -> &GptConfig {
        &self.cfg
    }

    fn dense(&self, name: &str) -> &Mat {
        self.dense_params
            .get(name)
            .unwrap_or_else(|| panic!("missing dense param {name}"))
    }

    fn linear_into(&self, name: &str, x: &Mat, out: &mut Mat, accumulate: bool) {
        self.layer(name).matmul_a_bt_into(x, out, accumulate);
    }

    fn block_names(&self) -> &[BlockNames] {
        &self.names
    }
}

/// KV cache of one batch=1 decode stream.
pub struct DecodeState {
    /// Per block, cached key rows (pos × d_model).
    k: Vec<Mat>,
    /// Per block, cached value rows (pos × d_model).
    v: Vec<Mat>,
    pos: usize,
}

impl DecodeState {
    pub fn pos(&self) -> usize {
        self.pos
    }
}

/// Sampling knobs for [`CompiledModel::generate`].
pub struct GenerateParams {
    pub max_new: usize,
    /// `<= 0` means greedy argmax.
    pub temperature: f64,
    pub seed: u64,
}

/// Output of [`CompiledModel::generate`].
pub struct Generated {
    /// Prompt followed by the sampled continuation.
    pub tokens: Vec<u8>,
    pub prompt_len: usize,
    /// Decode-loop iterations taken (for ms/token accounting).
    pub decode_steps: usize,
}

fn pack_layer(
    w: &Mat,
    mask: &Mat,
    format: SparseFormat,
    crossover: f64,
) -> Result<LayerWeights> {
    let density = mask.count_nonzero() as f64 / mask.numel().max(1) as f64;
    match format {
        SparseFormat::Dense => Ok(LayerWeights::DenseW(w.hadamard(mask))),
        SparseFormat::Csr => Ok(LayerWeights::Csr(CsrMat::from_masked(w, mask))),
        SparseFormat::Nm => {
            let (keep, block) = NmMat::detect(mask, 1.0)
                .context("mask has no n:m structure (some aligned group is full)")?;
            Ok(LayerWeights::Nm(NmMat::from_masked(w, mask, keep, block)?))
        }
        SparseFormat::Auto => {
            // balanced n:m structure packs tighter than CSR and
            // partitions statically — take it whenever padding waste
            // is negligible (packed density ≈ raw density)
            if let Some((keep, block)) = NmMat::detect(mask, density * 1.1 + 1e-9) {
                return Ok(LayerWeights::Nm(NmMat::from_masked(w, mask, keep, block)?));
            }
            if density > crossover {
                return Ok(LayerWeights::DenseW(w.hadamard(mask)));
            }
            Ok(LayerWeights::Csr(CsrMat::from_masked(w, mask)))
        }
    }
}

fn layernorm_row(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5f32).sqrt();
    x.iter()
        .zip(g.iter().zip(b))
        .map(|(&v, (&gv, &bv))| (v - mean) * inv * gv + bv)
        .collect()
}

fn softmax_slice(row: &mut [f32]) {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

fn push_row(m: &mut Mat, row: &[f32]) {
    debug_assert_eq!(row.len(), m.cols);
    m.data.extend_from_slice(row);
    m.rows += 1;
}

fn sample_token(logits: &[f32], temperature: f64, rng: &mut Xoshiro256) -> u8 {
    if temperature <= 0.0 {
        let mut best = 0usize;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best as u8;
    }
    let max = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x)) as f64;
    let probs: Vec<f64> = logits
        .iter()
        .map(|&x| ((x as f64 - max) / temperature).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.next_f64() * total;
    for (i, &p) in probs.iter().enumerate() {
        u -= p;
        if u <= 0.0 {
            return i as u8;
        }
    }
    (logits.len() - 1) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::forward;
    use crate::model::testutil::{random_model, tiny_cfg};
    use crate::pruner::saliency::{magnitude_scores, saliency_mask};
    use crate::pruner::SparsityPattern;

    fn masks_for(model: &Gpt, pattern: &SparsityPattern) -> BTreeMap<String, Mat> {
        model
            .cfg
            .layers()
            .iter()
            .map(|l| {
                let w = model.mat(&l.name);
                (l.name.clone(), saliency_mask(&magnitude_scores(w), pattern))
            })
            .collect()
    }

    fn check_equivalence(pattern: &SparsityPattern, format: SparseFormat) {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 21);
        let masks = masks_for(&model, pattern);
        let masked = model.apply_masks(&masks).unwrap();
        let compiled =
            CompiledModel::compile(&model, &masks, &BTreeMap::new(), format, DEFAULT_CROSSOVER)
                .unwrap();
        let tokens: Vec<u8> = (0..24u8).map(|i| i.wrapping_mul(11)).collect();
        let dense_logits = forward(&masked, &tokens, false).logits;
        let sparse_logits = forward(&compiled, &tokens, false).logits;
        assert!(
            dense_logits.max_abs_diff(&sparse_logits) < 1e-3,
            "{} / {}: max diff {}",
            pattern.label(),
            format.label(),
            dense_logits.max_abs_diff(&sparse_logits)
        );
    }

    #[test]
    fn compiled_matches_dense_all_patterns_and_formats() {
        let patterns = [
            SparsityPattern::Unstructured { sparsity: 0.6 },
            SparsityPattern::PerRow { sparsity: 0.75 },
            SparsityPattern::NM { keep: 2, block: 4 },
        ];
        for pat in &patterns {
            check_equivalence(pat, SparseFormat::Csr);
            check_equivalence(pat, SparseFormat::Auto);
        }
        // full-nm packing needs an n:m-structured mask
        check_equivalence(&SparsityPattern::NM { keep: 2, block: 4 }, SparseFormat::Nm);
        check_equivalence(&SparsityPattern::NM { keep: 1, block: 8 }, SparseFormat::Nm);
    }

    #[test]
    fn auto_picks_nm_for_nm_masks_and_csr_below_crossover() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 5);
        let nm_masks = masks_for(&model, &SparsityPattern::NM { keep: 1, block: 4 });
        let c = CompiledModel::compile(
            &model,
            &nm_masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        assert_eq!(
            c.format_counts(),
            (0, 0, 8),
            "1:4 masks must all compile to NmMat, got {}",
            c.summary()
        );
        assert_eq!(c.layer_format("blocks.0.wqkv"), Some("nm"));

        let un_masks = masks_for(&model, &SparsityPattern::Unstructured { sparsity: 0.8 });
        let c2 = CompiledModel::compile(
            &model,
            &un_masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        assert_eq!(c2.format_counts().0, 0, "20% density must not stay dense");

        // near-dense masks stay dense under auto
        let dense_masks = masks_for(&model, &SparsityPattern::Unstructured { sparsity: 0.05 });
        let c3 = CompiledModel::compile(
            &model,
            &dense_masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        assert!(c3.format_counts().0 > 0, "95% density should stay dense: {}", c3.summary());
    }

    #[test]
    fn reconstructed_weights_take_priority() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 9);
        let masks = masks_for(&model, &SparsityPattern::PerRow { sparsity: 0.5 });
        let mut new_weights = BTreeMap::new();
        new_weights.insert("blocks.0.wqkv".to_string(), Mat::zeros(48, 16));
        let c = CompiledModel::compile(
            &model,
            &masks,
            &new_weights,
            SparseFormat::Csr,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        let x = Mat::ones(2, 16);
        let mut out = Mat::zeros(2, 48);
        c.linear_into("blocks.0.wqkv", &x, &mut out, false);
        assert_eq!(out.data, vec![0.0; 96]);
    }

    #[test]
    fn decode_matches_prefill_logits() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 33);
        let masks = masks_for(&model, &SparsityPattern::PerRow { sparsity: 0.5 });
        let compiled = CompiledModel::compile(
            &model,
            &masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        let tokens: Vec<u8> = vec![5, 17, 40, 3, 99, 250, 1, 7];
        let full = forward(&compiled, &tokens, false).logits;
        let mut st = compiled.begin_decode();
        let mut last = Vec::new();
        for &t in &tokens {
            last = compiled.decode_step(t, &mut st);
        }
        assert_eq!(st.pos(), tokens.len());
        let frow = full.row(tokens.len() - 1);
        for (j, &l) in last.iter().enumerate() {
            assert!((l - frow[j]).abs() < 1e-3, "logit {j}: {} vs {}", l, frow[j]);
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 2);
        let masks = masks_for(&model, &SparsityPattern::NM { keep: 2, block: 4 });
        let compiled = CompiledModel::compile(
            &model,
            &masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        let p = GenerateParams { max_new: 12, temperature: 0.8, seed: 7 };
        let a = compiled.generate(&[1, 2, 3], &p).unwrap();
        let b = compiled.generate(&[1, 2, 3], &p).unwrap();
        assert_eq!(a.tokens, b.tokens, "same seed ⇒ same sample");
        assert_eq!(a.tokens.len(), 15);
        assert_eq!(&a.tokens[..3], &[1, 2, 3]);

        let greedy = GenerateParams { max_new: 6, temperature: 0.0, seed: 0 };
        let g1 = compiled.generate(&[9, 9], &greedy).unwrap();
        let g2 = compiled.generate(&[9, 9], &greedy).unwrap();
        assert_eq!(g1.tokens, g2.tokens);

        // capped by seq_len
        let long = GenerateParams { max_new: 500, temperature: 0.0, seed: 0 };
        let l = compiled.generate(&[4], &long).unwrap();
        assert_eq!(l.tokens.len(), cfg.seq_len);

        assert!(compiled.generate(&[], &greedy).is_err());
    }

    #[test]
    fn nm_format_rejects_unstructured_masks() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 4);
        // 5% sparsity: groups are full almost surely → no n:m structure
        let masks = masks_for(&model, &SparsityPattern::Unstructured { sparsity: 0.05 });
        let err = CompiledModel::compile(
            &model,
            &masks,
            &BTreeMap::new(),
            SparseFormat::Nm,
            DEFAULT_CROSSOVER,
        );
        assert!(err.is_err());
    }

    #[test]
    fn packed_smaller_than_dense_at_high_sparsity() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 6);
        let masks = masks_for(&model, &SparsityPattern::NM { keep: 1, block: 4 });
        let c = CompiledModel::compile(
            &model,
            &masks,
            &BTreeMap::new(),
            SparseFormat::Auto,
            DEFAULT_CROSSOVER,
        )
        .unwrap();
        assert!(c.packed_bytes() < c.dense_equiv_bytes());
    }
}
