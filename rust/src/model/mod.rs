//! The mini-GPT pruning target: architecture description, checkpoint
//! loading, and the native forward pass.
//!
//! The architecture mirrors `python/compile/model.py` exactly (pre-LN
//! transformer, learned positions, tanh-GELU MLP, weight-tied head);
//! an integration test cross-checks native logits against the AOT
//! `model_fwd` executable.

pub mod compiled;
pub mod forward;
pub mod safetensors;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::OnceLock;

use anyhow::{ensure, Context, Result};

use crate::tensor::Mat;
use crate::util::json::Json;

use self::forward::BlockNames;

/// Architecture hyper-parameters (mirrors `configs.ModelConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct GptConfig {
    pub name: String,
    pub vocab_size: usize,
    pub seq_len: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
}

/// One pruned linear layer (name + family + shape), in model order.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerInfo {
    pub name: String,
    pub family: String,
    pub d_out: usize,
    pub d_in: usize,
}

impl GptConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let g = |k: &str| -> Result<usize> {
            v.at(&[k]).as_usize().with_context(|| format!("config field {k}"))
        };
        Ok(Self {
            name: v.at(&["name"]).as_str().unwrap_or("unnamed").to_string(),
            vocab_size: g("vocab_size")?,
            seq_len: g("seq_len")?,
            d_model: g("d_model")?,
            n_layers: g("n_layers")?,
            n_heads: g("n_heads")?,
            d_ff: g("d_ff")?,
        })
    }

    /// Pruned linear layers in canonical order (mirror of
    /// `ModelConfig.layer_shapes`).
    pub fn layers(&self) -> Vec<LayerInfo> {
        let mut out = Vec::with_capacity(4 * self.n_layers);
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            out.push(LayerInfo {
                name: format!("{p}wqkv"),
                family: "attn_qkv".into(),
                d_out: 3 * self.d_model,
                d_in: self.d_model,
            });
            out.push(LayerInfo {
                name: format!("{p}wo"),
                family: "attn_out".into(),
                d_out: self.d_model,
                d_in: self.d_model,
            });
            out.push(LayerInfo {
                name: format!("{p}wup"),
                family: "mlp_up".into(),
                d_out: self.d_ff,
                d_in: self.d_model,
            });
            out.push(LayerInfo {
                name: format!("{p}wdown"),
                family: "mlp_down".into(),
                d_out: self.d_model,
                d_in: self.d_ff,
            });
        }
        out
    }

    /// Canonical parameter order (mirror of `ModelConfig.param_names`) —
    /// the flattened AOT signature of `model_fwd`.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string(), "pos_emb".to_string()];
        for i in 0..self.n_layers {
            let p = format!("blocks.{i}.");
            for s in ["ln1_g", "ln1_b", "wqkv", "wo", "ln2_g", "ln2_b", "wup", "wdown"] {
                names.push(format!("{p}{s}"));
            }
        }
        names.push("lnf_g".to_string());
        names.push("lnf_b".to_string());
        names
    }
}

/// A loaded model: config + parameter matrices.
pub struct Gpt {
    pub cfg: GptConfig,
    pub params: BTreeMap<String, Mat>,
    /// Per-block param names, built lazily once per model instance —
    /// the forward hot path used to re-`format!` them per block call.
    block_names: OnceLock<Vec<BlockNames>>,
}

impl Clone for Gpt {
    fn clone(&self) -> Self {
        // the name cache rebuilds lazily; cloning it would be wasted
        // work for clones that only get masked and evaluated
        Self {
            cfg: self.cfg.clone(),
            params: self.params.clone(),
            block_names: OnceLock::new(),
        }
    }
}

impl Gpt {
    pub fn load(cfg: GptConfig, checkpoint: &Path) -> Result<Self> {
        let raw = safetensors::load(checkpoint)?;
        let mut params = BTreeMap::new();
        for name in cfg.param_names() {
            let t = raw
                .get(&name)
                .with_context(|| format!("checkpoint missing param {name}"))?;
            params.insert(name.clone(), t.to_mat()?);
        }
        Self::from_params(cfg, params)
    }

    pub fn from_params(cfg: GptConfig, params: BTreeMap<String, Mat>) -> Result<Self> {
        let model = Self { cfg, params, block_names: OnceLock::new() };
        model.validate()?;
        Ok(model)
    }

    /// Cached per-block parameter names (computed on first use).
    pub fn block_names(&self) -> &[BlockNames] {
        self.block_names
            .get_or_init(|| BlockNames::for_model(&self.cfg))
    }

    fn validate(&self) -> Result<()> {
        let c = &self.cfg;
        ensure!(c.d_model % c.n_heads == 0, "d_model % n_heads != 0");
        let expect = |name: &str, r: usize, co: usize| -> Result<()> {
            let m = self.params.get(name).with_context(|| format!("missing {name}"))?;
            ensure!(
                m.rows == r && m.cols == co,
                "param {name}: got {}x{}, want {r}x{co}",
                m.rows,
                m.cols
            );
            Ok(())
        };
        expect("tok_emb", c.vocab_size, c.d_model)?;
        expect("pos_emb", c.seq_len, c.d_model)?;
        for l in self.cfg.layers() {
            expect(&l.name, l.d_out, l.d_in)?;
        }
        expect("lnf_g", 1, c.d_model)?;
        Ok(())
    }

    pub fn mat(&self, name: &str) -> &Mat {
        self.params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    }

    pub fn n_params(&self) -> usize {
        self.params.values().map(Mat::numel).sum()
    }

    /// Clone with binary masks multiplied into the pruned linears —
    /// evaluation-side application of a pruning result.
    pub fn apply_masks(&self, masks: &BTreeMap<String, Mat>) -> Result<Self> {
        let mut out = self.clone();
        for (name, mask) in masks {
            let w = out
                .params
                .get_mut(name)
                .with_context(|| format!("mask for unknown layer {name}"))?;
            ensure!(
                w.rows == mask.rows && w.cols == mask.cols,
                "mask shape mismatch for {name}"
            );
            w.hadamard_inplace(mask);
        }
        Ok(out)
    }

    /// Fraction of zero weights over the pruned linear layers.
    pub fn pruned_sparsity(&self) -> f64 {
        let mut zeros = 0usize;
        let mut total = 0usize;
        for l in self.cfg.layers() {
            let m = self.mat(&l.name);
            total += m.numel();
            zeros += m.numel() - m.count_nonzero();
        }
        zeros as f64 / total.max(1) as f64
    }
}

pub mod testutil {
    //! Randomly-initialized models for tests, benches, and the demo
    //! server mode (no artifacts needed).  Always compiled: integration
    //! tests and `sparsefw serve --demo` need workspace-free models.
    use super::*;
    use crate::util::prng::Xoshiro256;

    /// Vocab matches the corpus generator (256) so corpus-driven tests
    /// can feed tokens straight into a test model.
    pub fn tiny_cfg() -> GptConfig {
        GptConfig {
            name: "test".into(),
            vocab_size: 256,
            seq_len: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
        }
    }

    pub fn random_model(cfg: &GptConfig, seed: u64) -> Gpt {
        let mut rng = Xoshiro256::new(seed);
        let mut params = BTreeMap::new();
        let d = cfg.d_model;
        params.insert("tok_emb".into(), Mat::gaussian(cfg.vocab_size, d, 0.05, &mut rng));
        params.insert("pos_emb".into(), Mat::gaussian(cfg.seq_len, d, 0.05, &mut rng));
        for i in 0..cfg.n_layers {
            let p = format!("blocks.{i}.");
            params.insert(format!("{p}ln1_g"), Mat::ones(1, d));
            params.insert(format!("{p}ln1_b"), Mat::zeros(1, d));
            params.insert(format!("{p}wqkv"), Mat::gaussian(3 * d, d, 0.1, &mut rng));
            params.insert(format!("{p}wo"), Mat::gaussian(d, d, 0.05, &mut rng));
            params.insert(format!("{p}ln2_g"), Mat::ones(1, d));
            params.insert(format!("{p}ln2_b"), Mat::zeros(1, d));
            params.insert(format!("{p}wup"), Mat::gaussian(cfg.d_ff, d, 0.1, &mut rng));
            params.insert(format!("{p}wdown"), Mat::gaussian(d, cfg.d_ff, 0.05, &mut rng));
        }
        params.insert("lnf_g".into(), Mat::ones(1, d));
        params.insert("lnf_b".into(), Mat::zeros(1, d));
        Gpt::from_params(cfg.clone(), params).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::*;
    use super::*;

    #[test]
    fn layers_and_params() {
        let cfg = tiny_cfg();
        let layers = cfg.layers();
        assert_eq!(layers.len(), 8);
        assert_eq!(layers[0].d_out, 48);
        assert_eq!(cfg.param_names().len(), 2 + 8 * 2 + 2);
    }

    #[test]
    fn mask_application() {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 1);
        let mut masks = BTreeMap::new();
        masks.insert("blocks.0.wqkv".to_string(), Mat::zeros(48, 16));
        let pruned = model.apply_masks(&masks).unwrap();
        assert_eq!(pruned.mat("blocks.0.wqkv").count_nonzero(), 0);
        assert!(pruned.pruned_sparsity() > 0.0);
        // unmasked layers untouched
        assert_eq!(
            pruned.mat("blocks.1.wqkv").data,
            model.mat("blocks.1.wqkv").data
        );
    }

    #[test]
    fn validate_rejects_bad_shape() {
        let cfg = tiny_cfg();
        let mut model = random_model(&cfg, 2);
        model.params.insert("tok_emb".into(), Mat::zeros(3, 3));
        assert!(Gpt::from_params(cfg, model.params).is_err());
    }
}
