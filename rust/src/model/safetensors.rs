//! safetensors reader/writer (f32 only) — counterpart of
//! `python/compile/checkpoint.py`.
//!
//! Format: `[8-byte LE header length][JSON header][raw data]`, header
//! maps tensor name → {dtype, shape, data_offsets}.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::tensor::Mat;
use crate::util::json::{self, Json};

/// A named tensor of arbitrary rank (we materialize rank ≤ 2 as [`Mat`]).
#[derive(Clone, Debug)]
pub struct TensorData {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl TensorData {
    /// View as a matrix: rank-2 as-is, rank-1 as a single row.
    pub fn to_mat(&self) -> Result<Mat> {
        match self.shape.len() {
            1 => Ok(Mat::from_vec(1, self.shape[0], self.data.clone())),
            2 => Ok(Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())),
            r => bail!("cannot view rank-{r} tensor as Mat"),
        }
    }
}

pub fn load(path: &Path) -> Result<BTreeMap<String, TensorData>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
    ensure!(bytes.len() >= 8, "file too short");
    let hlen = u64::from_le_bytes(bytes[..8].try_into().unwrap()) as usize;
    ensure!(hlen <= bytes.len().saturating_sub(8), "header length out of range");
    let header_str = std::str::from_utf8(&bytes[8..8 + hlen]).context("header not utf-8")?;
    let header = json::parse(header_str).context("parsing safetensors header")?;
    let data = &bytes[8 + hlen..];

    let mut out = BTreeMap::new();
    let obj = header.as_obj().context("header must be an object")?;
    for (name, meta) in obj {
        if name == "__metadata__" {
            continue;
        }
        let dtype = meta.at(&["dtype"]).as_str().context("missing dtype")?;
        ensure!(dtype == "F32", "unsupported dtype {dtype} for {name}");
        let shape: Vec<usize> = meta
            .at(&["shape"])
            .as_arr()
            .context("missing shape")?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        let offs = meta.at(&["data_offsets"]).as_arr().context("missing offsets")?;
        ensure!(offs.len() == 2, "bad data_offsets");
        let (b, e) = (offs[0].as_usize().unwrap(), offs[1].as_usize().unwrap());
        ensure!(e <= data.len() && b <= e, "offsets out of range for {name}");
        let numel: usize = shape.iter().product();
        ensure!(e - b == numel * 4, "size mismatch for {name}");
        let mut vals = Vec::with_capacity(numel);
        for chunk in data[b..e].chunks_exact(4) {
            vals.push(f32::from_le_bytes(chunk.try_into().unwrap()));
        }
        out.insert(name.clone(), TensorData { shape, data: vals });
    }
    Ok(out)
}

pub fn save(path: &Path, tensors: &BTreeMap<String, TensorData>) -> Result<()> {
    let mut header = BTreeMap::new();
    let mut offset = 0usize;
    let mut blobs: Vec<&[f32]> = Vec::new();
    for (name, t) in tensors {
        let nbytes = t.data.len() * 4;
        header.insert(
            name.clone(),
            Json::obj(vec![
                ("dtype", Json::Str("F32".into())),
                ("shape", Json::Arr(t.shape.iter().map(|&s| Json::from(s)).collect())),
                (
                    "data_offsets",
                    Json::Arr(vec![Json::from(offset), Json::from(offset + nbytes)]),
                ),
            ]),
        );
        offset += nbytes;
        blobs.push(&t.data);
    }
    let mut hjson = json::to_string(&Json::Obj(header)).into_bytes();
    let pad = (8 - hjson.len() % 8) % 8;
    hjson.extend(std::iter::repeat(b' ').take(pad));

    let mut out = Vec::with_capacity(8 + hjson.len() + offset);
    out.extend_from_slice(&(hjson.len() as u64).to_le_bytes());
    out.extend_from_slice(&hjson);
    for blob in blobs {
        for &x in blob {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
    std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sparsefw_st_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.safetensors");
        let mut tensors = BTreeMap::new();
        tensors.insert(
            "a.weight".to_string(),
            TensorData { shape: vec![2, 3], data: vec![1.0, -2.0, 3.5, 0.0, 1e-8, -1e8] },
        );
        tensors.insert(
            "b".to_string(),
            TensorData { shape: vec![4], data: vec![0.5; 4] },
        );
        save(&path, &tensors).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded["a.weight"].shape, vec![2, 3]);
        assert_eq!(loaded["a.weight"].data, tensors["a.weight"].data);
        assert_eq!(loaded["b"].to_mat().unwrap().rows, 1);
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("sparsefw_st_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.safetensors");
        std::fs::write(&path, b"short").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, [0xFFu8; 64]).unwrap();
        assert!(load(&path).is_err());
    }
}
