//! Blocking client for the job server.
//!
//! One short-lived connection per call (`Connection: close`) keeps the
//! client trivially correct; the server's keep-alive path exists for
//! clients that want it.  Used by the CLI (`sparsefw
//! submit/status/shutdown`), the CI smoke test, examples, and the
//! integration tests.
//!
//! Failure handling: every socket carries connect/read/write timeouts,
//! so no call blocks forever on a dead peer.  [`Client::wait`] follows
//! the `/events` stream and *reconnects* when the stream drops
//! mid-response (a network partition, a restarted server), resuming
//! from the last event it saw — the server replays recorded events on
//! a fresh stream, and the client skips the prefix it already
//! processed.  HTTP-level rejections (404, 400) are permanent and
//! surface immediately; only transport drops are retried.

use std::io::{BufReader, Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::coordinator::JobSpec;
use crate::util::json::{self, Json};

use super::http::{read_chunked, read_response_head};
use super::queue::JobId;

/// A classified `/events` stream failure: retrying cannot fix a
/// [`StreamFailure::Permanent`] rejection (the server answered and said
/// no), while a [`StreamFailure::Dropped`] transport error is exactly
/// what reconnect-with-backoff exists for.
#[derive(Debug)]
enum StreamFailure {
    Permanent(anyhow::Error),
    Dropped(anyhow::Error),
}

impl StreamFailure {
    fn into_error(self) -> anyhow::Error {
        match self {
            StreamFailure::Permanent(e) | StreamFailure::Dropped(e) => e,
        }
    }
}

pub struct Client {
    addr: String,
    /// Per-request socket read timeout.
    pub timeout: Duration,
    /// TCP connect timeout (a black-holed address otherwise blocks for
    /// the OS default, minutes on some platforms).
    pub connect_timeout: Duration,
    /// Correlation ID sent as `X-Sparsefw-Corr-Id` on every request;
    /// the server tags submitted jobs (and their worker-side trace
    /// spans + log lines) with it.  `None` lets the server mint one
    /// per job.
    pub corr_id: Option<String>,
    /// Bearer token sent as `Authorization: Bearer …` on every request
    /// — required by servers running with `--auth-token`.
    pub token: Option<String>,
}

impl Client {
    pub fn new(addr: impl Into<String>) -> Self {
        Self {
            addr: addr.into(),
            timeout: Duration::from_secs(30),
            connect_timeout: Duration::from_secs(5),
            corr_id: None,
            token: None,
        }
    }

    /// Builder: tag every request from this client with `corr_id`.
    pub fn with_corr_id(mut self, corr_id: impl Into<String>) -> Self {
        self.corr_id = Some(corr_id.into());
        self
    }

    /// Builder: authenticate every request with a bearer `token`.
    pub fn with_token(mut self, token: impl Into<String>) -> Self {
        self.token = Some(token.into());
        self
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    // -- transport ----------------------------------------------------------

    fn connect(&self) -> Result<TcpStream> {
        let addrs = self
            .addr
            .to_socket_addrs()
            .with_context(|| format!("resolving sparsefw server address {}", self.addr))?;
        let mut last: Option<std::io::Error> = None;
        for addr in addrs {
            match TcpStream::connect_timeout(&addr, self.connect_timeout) {
                Ok(stream) => {
                    stream.set_read_timeout(Some(self.timeout))?;
                    stream.set_write_timeout(Some(self.timeout))?;
                    let _ = stream.set_nodelay(true);
                    return Ok(stream);
                }
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => {
                Err(e).with_context(|| format!("connecting to sparsefw server at {}", self.addr))
            }
            None => bail!("address {} resolved to nothing", self.addr),
        }
    }

    fn send_request(
        &self,
        stream: &mut TcpStream,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<()> {
        let body_text = body.map(json::to_string).unwrap_or_default();
        let corr = self
            .corr_id
            .as_deref()
            .map(|c| format!("X-Sparsefw-Corr-Id: {c}\r\n"))
            .unwrap_or_default();
        let auth = self
            .token
            .as_deref()
            .map(|t| format!("Authorization: Bearer {t}\r\n"))
            .unwrap_or_default();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nConnection: close\r\n\
             Content-Type: application/json\r\n{}{}Content-Length: {}\r\n\r\n{}",
            self.addr,
            corr,
            auth,
            body_text.len(),
            body_text,
        )?;
        stream.flush()?;
        Ok(())
    }

    /// One request → `(status, parsed JSON body)` (Null for empty bodies).
    fn request(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let mut stream = self.connect()?;
        self.send_request(&mut stream, method, path, body)?;
        let mut reader = BufReader::new(stream);
        let (code, headers) = read_response_head(&mut reader)?;
        let mut body = Vec::new();
        match headers.get("content-length") {
            Some(n) => {
                body.resize(n.parse::<usize>().context("bad Content-Length")?, 0);
                reader.read_exact(&mut body).context("reading response body")?;
            }
            None => {
                reader.read_to_end(&mut body).context("reading response body")?;
            }
        }
        let v = if body.is_empty() {
            Json::Null
        } else {
            json::parse(std::str::from_utf8(&body).context("non-UTF-8 response")?)
                .context("parsing response JSON")?
        };
        Ok((code, v))
    }

    /// Like [`Client::request`] but non-2xx becomes an error carrying
    /// the server's `"error"` message.
    fn request_ok(&self, method: &str, path: &str, body: Option<&Json>) -> Result<Json> {
        let (code, v) = self.request(method, path, body)?;
        if !(200..300).contains(&code) {
            let msg = v.at(&["error"]).as_str().unwrap_or("unknown error").to_string();
            bail!("{method} {path}: HTTP {code}: {msg}");
        }
        Ok(v)
    }

    // -- API ----------------------------------------------------------------

    /// Generic `POST path` with a JSON body — the fleet worker's
    /// transport (register / poll / shard results all go through here).
    pub fn post(&self, path: &str, body: &Json) -> Result<Json> {
        self.request_ok("POST", path, Some(body))
    }

    /// Generic `GET path`.
    pub fn get(&self, path: &str) -> Result<Json> {
        self.request_ok("GET", path, None)
    }

    /// `POST /jobs`; returns the assigned job id.
    pub fn submit(&self, spec: &JobSpec, priority: i64) -> Result<JobId> {
        self.submit_json(&spec.to_json(), priority)
    }

    /// `POST /jobs` with a raw spec JSON value — for clients that build
    /// specs as data (and for probing a server's validation: unknown
    /// methods come back as a 400 naming the registered set).
    pub fn submit_json(&self, spec: &Json, priority: i64) -> Result<JobId> {
        let body = Json::obj(vec![
            ("spec", spec.clone()),
            ("priority", (priority as f64).into()),
        ]);
        let v = self.request_ok("POST", "/jobs", Some(&body))?;
        let id = v
            .at(&["id"])
            .as_usize()
            .context("submit response has no id")?;
        Ok(id as JobId)
    }

    /// `GET /methods` — the server's method registry listing.
    pub fn methods(&self) -> Result<Json> {
        self.request_ok("GET", "/methods", None)
    }

    /// `GET /jobs/:id` — the full status payload.
    pub fn job(&self, id: JobId) -> Result<Json> {
        self.request_ok("GET", &format!("/jobs/{id}"), None)
    }

    /// `GET /jobs` — brief listings.
    pub fn jobs(&self) -> Result<Json> {
        self.request_ok("GET", "/jobs", None)
    }

    /// `DELETE /jobs/:id` — cancel a queued job.
    pub fn cancel(&self, id: JobId) -> Result<Json> {
        self.request_ok("DELETE", &format!("/jobs/{id}"), None)
    }

    pub fn healthz(&self) -> Result<Json> {
        self.request_ok("GET", "/healthz", None)
    }

    pub fn metrics(&self) -> Result<Json> {
        self.request_ok("GET", "/metrics", None)
    }

    /// `GET /jobs/:id/trace` — recent trace spans recorded under the
    /// job's correlation ID.
    pub fn trace(&self, id: JobId) -> Result<Json> {
        self.request_ok("GET", &format!("/jobs/{id}/trace"), None)
    }

    /// `POST /jobs/:id/eval` — score the completed job's compiled
    /// sparse model on the server's held-out bin; `max_seqs = None`
    /// uses the server default.
    pub fn eval_job(&self, id: JobId, max_seqs: Option<usize>) -> Result<Json> {
        let body = match max_seqs {
            Some(n) => Some(Json::obj(vec![("max_seqs", n.into())])),
            None => None,
        };
        self.request_ok("POST", &format!("/jobs/{id}/eval"), body.as_ref())
    }

    /// `POST /jobs/:id/generate` — sample a continuation from the
    /// completed job's compiled model (`temperature <= 0` is greedy).
    pub fn generate_job(
        &self,
        id: JobId,
        prompt: &[u8],
        max_new: usize,
        temperature: f64,
        seed: u64,
    ) -> Result<Json> {
        let tokens: Vec<Json> = prompt.iter().map(|&t| (t as usize).into()).collect();
        let body = Json::obj(vec![
            ("prompt", Json::Arr(tokens)),
            ("max_new", max_new.into()),
            ("temperature", temperature.into()),
            ("seed", (seed as usize).into()),
        ]);
        self.request_ok("POST", &format!("/jobs/{id}/generate"), Some(&body))
    }

    /// `GET /metrics?format=prometheus` — the raw text exposition.
    pub fn metrics_prometheus(&self) -> Result<String> {
        let mut stream = self.connect()?;
        self.send_request(&mut stream, "GET", "/metrics?format=prometheus", None)?;
        let mut reader = BufReader::new(stream);
        let (code, headers) = read_response_head(&mut reader)?;
        let mut body = Vec::new();
        match headers.get("content-length") {
            Some(n) => {
                body.resize(n.parse::<usize>().context("bad Content-Length")?, 0);
                reader.read_exact(&mut body).context("reading response body")?;
            }
            None => {
                reader.read_to_end(&mut body).context("reading response body")?;
            }
        }
        ensure!(
            (200..300).contains(&code),
            "GET /metrics?format=prometheus: HTTP {code}"
        );
        String::from_utf8(body).context("non-UTF-8 metrics exposition")
    }

    /// `POST /shutdown` — graceful; `drain_queued` runs the backlog
    /// first, otherwise queued jobs are cancelled.
    pub fn shutdown(&self, drain_queued: bool) -> Result<Json> {
        let path = if drain_queued { "/shutdown?drain=1" } else { "/shutdown" };
        self.request_ok("POST", path, None)
    }

    /// Block until the job reaches a terminal state; returns the final
    /// `GET /jobs/:id` payload.  Equivalent to [`Client::follow`] with
    /// no event callback.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Result<Json> {
        self.follow(id, timeout, |_| {})
    }

    /// Block until the job reaches a terminal state, firing `on_event`
    /// for each layer event; returns the final `GET /jobs/:id` payload.
    ///
    /// Follows the event stream — server-side that parks on a condvar,
    /// so a waiting client costs one idle connection, not a poll loop.
    /// A stream severed mid-response reconnects with exponential
    /// backoff, resuming after the last event already delivered (the
    /// server replays recorded events; the client skips the seen
    /// prefix).  HTTP-level rejections fail immediately; `timeout`
    /// bounds the total wait including all reconnect attempts, and the
    /// eventual error says how many drops were survived.
    pub fn follow(
        &self,
        id: JobId,
        timeout: Duration,
        mut on_event: impl FnMut(&Json),
    ) -> Result<Json> {
        let deadline = Instant::now() + timeout;
        let mut seen = 0usize;
        let mut drops = 0usize;
        let mut backoff = Duration::from_millis(50);
        loop {
            match self.stream_events_from(id, &mut seen, &mut on_event) {
                Ok(Some(fin)) => {
                    let state = fin.at(&["state"]).as_str().unwrap_or("");
                    if matches!(state, "done" | "failed" | "cancelled") {
                        // the stream trailer omits progress/events; re-fetch
                        return self.job(id);
                    }
                    break; // non-terminal trailer — poll below
                }
                Ok(None) => break, // clean end, server draining — poll below
                Err(StreamFailure::Permanent(e)) => return Err(e),
                Err(StreamFailure::Dropped(e)) => {
                    drops += 1;
                    // the job may have finished while we were cut off
                    if let Ok(v) = self.job(id) {
                        let state = v.at(&["state"]).as_str().unwrap_or("");
                        if matches!(state, "done" | "failed" | "cancelled") {
                            return Ok(v);
                        }
                    }
                    if Instant::now() >= deadline {
                        return Err(e.context(format!(
                            "job {id} not finished after {timeout:?} \
                             ({drops} dropped event stream(s))"
                        )));
                    }
                    std::thread::sleep(backoff.min(remaining(deadline)));
                    backoff = (backoff * 2).min(Duration::from_secs(1));
                }
            }
        }
        // coarse polling fallback: the stream ended without a terminal
        // line (e.g. server draining) but the job record persists
        let mut interval = Duration::from_millis(50);
        loop {
            let v = self.job(id)?;
            let state = v.at(&["state"]).as_str().unwrap_or("");
            if matches!(state, "done" | "failed" | "cancelled") {
                return Ok(v);
            }
            ensure!(
                Instant::now() < deadline,
                "job {id} still {state:?} after {timeout:?}"
            );
            std::thread::sleep(interval);
            interval = (interval * 2).min(Duration::from_secs(1));
        }
    }

    /// Follow `GET /jobs/:id/events`: `on_event` fires per layer event;
    /// the returned value is the stream's final state line (id, state,
    /// result / error).  Falls back to [`Client::job`] if the stream
    /// ends without a terminal line (server shutting down mid-stream).
    /// Single-shot: a severed stream is an error here — use
    /// [`Client::follow`] for the reconnecting variant.
    pub fn stream(&self, id: JobId, mut on_event: impl FnMut(&Json)) -> Result<Json> {
        let mut seen = 0usize;
        match self.stream_events_from(id, &mut seen, &mut on_event) {
            Ok(Some(v)) => Ok(v),
            Ok(None) => self.job(id),
            Err(f) => Err(f.into_error()),
        }
    }

    /// One `/events` connection, skipping the first `*seen` layer
    /// events (already delivered on a previous connection) and counting
    /// the rest into `*seen` as they are handed to `on_event`.  Returns
    /// the terminal state line if the stream reached one, `Ok(None)` on
    /// a clean end without it.
    fn stream_events_from(
        &self,
        id: JobId,
        seen: &mut usize,
        on_event: &mut impl FnMut(&Json),
    ) -> Result<Option<Json>, StreamFailure> {
        let attempt = || -> Result<(BufReader<TcpStream>, u16, bool)> {
            let mut stream = self.connect()?;
            self.send_request(&mut stream, "GET", &format!("/jobs/{id}/events"), None)?;
            let mut reader = BufReader::new(stream);
            let (code, headers) = read_response_head(&mut reader)?;
            let chunked =
                headers.get("transfer-encoding").map(String::as_str) == Some("chunked");
            Ok((reader, code, chunked))
        };
        let (mut reader, code, chunked) = attempt().map_err(StreamFailure::Dropped)?;
        if (200..300).contains(&code) && !chunked {
            return Err(StreamFailure::Permanent(anyhow!(
                "GET /jobs/{id}/events: expected a chunked stream"
            )));
        }
        if !(200..300).contains(&code) {
            // the error payload is a plain (non-chunked) response
            let mut body = String::new();
            let _ = reader.read_to_string(&mut body);
            let msg = json::parse(&body)
                .ok()
                .and_then(|v| v.at(&["error"]).as_str().map(String::from))
                .unwrap_or(body);
            return Err(StreamFailure::Permanent(anyhow!(
                "GET /jobs/{id}/events: HTTP {code}: {msg}"
            )));
        }
        let mut skip = *seen;
        let mut terminal: Option<Json> = None;
        read_chunked(&mut reader, |line| {
            if let Ok(v) = json::parse(line) {
                if v.get("state").is_some() {
                    terminal = Some(v);
                } else if v.get("layer").is_some() {
                    if skip > 0 {
                        skip -= 1;
                    } else {
                        *seen += 1;
                        on_event(&v);
                    }
                }
                // other lines (heartbeats) are dropped
            }
        })
        .map_err(StreamFailure::Dropped)?;
        Ok(terminal)
    }
}

/// Time left until `deadline` (zero once past it).
fn remaining(deadline: Instant) -> Duration {
    deadline.saturating_duration_since(Instant::now())
}
