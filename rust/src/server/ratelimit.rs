//! Token-bucket rate limiting for the submit endpoint.
//!
//! Each peer IP gets an independent bucket: `SUBMIT_BURST` tokens of
//! capacity refilling at `SUBMIT_RATE_PER_SEC`.  A submit with an empty
//! bucket is shed with `429 Too Many Requests` + `Retry-After` instead
//! of being queued — the queue's own capacity bound then only has to
//! absorb *accepted* work, and a single misbehaving client cannot
//! starve the others' submissions.
//!
//! The table of buckets is itself bounded (`MAX_PEERS`): under a
//! rotating-address flood, buckets idle longer than [`IDLE_EVICT`] are
//! dropped before a new peer is admitted, so memory stays O(active
//! peers), not O(distinct addresses ever seen).

use std::collections::BTreeMap;
use std::net::IpAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::util::sync::lock_recover;

/// Sustained refill rate for `POST /jobs`, tokens per second per peer.
/// Generous: real submissions are seconds apart (a job runs far longer
/// than that); only a tight submit loop ever sees a 429.
pub const SUBMIT_RATE_PER_SEC: f64 = 50.0;
/// Bucket capacity — short bursts above the sustained rate are fine.
pub const SUBMIT_BURST: f64 = 100.0;
/// Upper bound on tracked peers before idle buckets are evicted.
const MAX_PEERS: usize = 1024;
/// Buckets untouched this long are eligible for eviction.
const IDLE_EVICT: Duration = Duration::from_secs(60);

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-peer token buckets behind one mutex (the critical section is a
/// map lookup + float arithmetic; contention is negligible next to the
/// request parse that precedes it).
pub struct RateLimiter {
    rate: f64,
    burst: f64,
    max_peers: usize,
    peers: Mutex<BTreeMap<IpAddr, Bucket>>,
}

impl RateLimiter {
    pub fn new(rate: f64, burst: f64) -> Self {
        Self::with_capacity(rate, burst, MAX_PEERS)
    }

    fn with_capacity(rate: f64, burst: f64, max_peers: usize) -> Self {
        Self { rate, burst, max_peers, peers: Mutex::new(BTreeMap::new()) }
    }

    /// The default limiter for `POST /jobs`.
    pub fn for_submit() -> Self {
        Self::new(SUBMIT_RATE_PER_SEC, SUBMIT_BURST)
    }

    /// Take one token for `peer`.  A `None` peer (the socket's address
    /// lookup failed) is allowed through: the limiter sheds load, it is
    /// not authentication.
    pub fn allow(&self, peer: Option<IpAddr>) -> bool {
        match peer {
            Some(ip) => self.allow_at(ip, Instant::now()),
            None => true,
        }
    }

    fn allow_at(&self, ip: IpAddr, now: Instant) -> bool {
        let mut peers = lock_recover(&self.peers);
        if peers.len() >= self.max_peers && !peers.contains_key(&ip) {
            peers.retain(|_, b| now.saturating_duration_since(b.last) < IDLE_EVICT);
            if peers.len() >= self.max_peers {
                // every tracked peer is active and the table is full:
                // shed the newcomer rather than grow without bound
                return false;
            }
        }
        let bucket = peers
            .entry(ip)
            .or_insert(Bucket { tokens: self.burst, last: now });
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * self.rate).min(self.burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ip(last: u8) -> IpAddr {
        IpAddr::from([127, 0, 0, last])
    }

    #[test]
    fn burst_is_bounded_and_refills_over_time() {
        let rl = RateLimiter::new(10.0, 3.0);
        let t0 = Instant::now();
        assert!(rl.allow_at(ip(1), t0));
        assert!(rl.allow_at(ip(1), t0));
        assert!(rl.allow_at(ip(1), t0));
        assert!(!rl.allow_at(ip(1), t0), "burst exhausted");
        // 0.25 s at 10 tokens/s refills two-and-a-half tokens
        let t1 = t0 + Duration::from_millis(250);
        assert!(rl.allow_at(ip(1), t1));
        assert!(rl.allow_at(ip(1), t1));
        assert!(!rl.allow_at(ip(1), t1));
    }

    #[test]
    fn peers_have_independent_buckets() {
        let rl = RateLimiter::new(1.0, 1.0);
        let t0 = Instant::now();
        assert!(rl.allow_at(ip(1), t0));
        assert!(!rl.allow_at(ip(1), t0));
        assert!(rl.allow_at(ip(2), t0), "peer 2 has its own bucket");
    }

    #[test]
    fn unknown_peer_is_always_allowed() {
        let rl = RateLimiter::new(1.0, 1.0);
        assert!(rl.allow(None));
        assert!(rl.allow(None));
    }

    #[test]
    fn idle_buckets_are_evicted_under_table_pressure() {
        let rl = RateLimiter::with_capacity(1.0, 1.0, 2);
        let t0 = Instant::now();
        assert!(rl.allow_at(ip(1), t0));
        assert!(rl.allow_at(ip(2), t0));
        // a third peer two minutes later evicts the two idle buckets
        let t1 = t0 + Duration::from_secs(120);
        assert!(rl.allow_at(ip(3), t1));
        assert_eq!(lock_recover(&rl.peers).len(), 1);
    }

    #[test]
    fn full_table_of_active_peers_sheds_newcomers() {
        let rl = RateLimiter::with_capacity(10.0, 10.0, 2);
        let t0 = Instant::now();
        assert!(rl.allow_at(ip(1), t0));
        assert!(rl.allow_at(ip(2), t0));
        assert!(!rl.allow_at(ip(3), t0), "no room and nothing idle");
    }
}
