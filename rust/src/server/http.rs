//! Minimal HTTP/1.1 layer for the job server.
//!
//! The offline registry has no `hyper`/`tokio`, so this module speaks
//! just enough HTTP/1.1 over blocking `std::net` streams for the JSON
//! API and its blocking client: request-line + header parsing with a
//! `Content-Length` body, plain responses, `Transfer-Encoding: chunked`
//! responses for live progress streaming, and keep-alive (persistent
//! connections are the default in 1.1; `Connection: close` opts out).
//!
//! Deliberately not implemented: TLS, compression, trailers, multipart,
//! `%`-escapes beyond the query split — the server binds loopback by
//! default and both ends of the protocol live in this crate.

use std::collections::BTreeMap;
use std::io::{BufRead, Read, Write};

use anyhow::{bail, ensure, Context, Result};

use crate::util::json::{self, Json};

/// Upper bound on a request body (a JobSpec is ~1 KB; 4 MB is generous).
pub const MAX_BODY: usize = 4 << 20;
/// Upper bound on a single header line.
pub const MAX_LINE: usize = 64 << 10;
/// Upper bound on header count (the API uses ~4; 128 is generous).
pub const MAX_HEADERS: usize = 128;
/// Upper bound on one chunk in a chunked stream.  Also bounds the
/// carry-over buffer for a payload line split across chunks.
pub const MAX_CHUNK: usize = MAX_BODY;

// ---------------------------------------------------------------------------
// Request
// ---------------------------------------------------------------------------

/// One parsed HTTP request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/jobs/3`.
    pub path: String,
    /// Decoded `?k=v&flag` pairs (missing `=` ⇒ empty value).
    pub query: BTreeMap<String, String>,
    /// Header names lower-cased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Read one request off a buffered stream.  Returns `Ok(None)` on a
    /// clean EOF before the request line (keep-alive peer went away).
    pub fn read(r: &mut impl BufRead) -> Result<Option<Request>> {
        let Some(line) = read_crlf_line(r)? else { return Ok(None) };
        let mut parts = line.split_whitespace();
        let method = parts.next().context("empty request line")?.to_string();
        let target = parts.next().context("request line has no target")?;
        let version = parts.next().context("request line has no version")?;
        ensure!(
            version == "HTTP/1.1" || version == "HTTP/1.0",
            "unsupported HTTP version {version:?}"
        );

        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p.to_string(), parse_query(q)),
            None => (target.to_string(), BTreeMap::new()),
        };

        let headers = read_headers(r)?;

        let len: usize = match headers.get("content-length") {
            Some(v) => v.parse().context("bad Content-Length")?,
            None => 0,
        };
        ensure!(len <= MAX_BODY, "body too large ({len} bytes)");
        let mut body = vec![0u8; len];
        r.read_exact(&mut body).context("reading request body")?;

        Ok(Some(Request { method, path, query, headers, body }))
    }

    /// The body parsed as JSON.
    pub fn body_json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("body is not UTF-8")?;
        Ok(json::parse(text).context("body is not valid JSON")?)
    }

    /// Keep the connection open after responding?  (HTTP/1.1 default.)
    pub fn keep_alive(&self) -> bool {
        !self
            .headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    /// `/jobs/3/events` → `["jobs", "3", "events"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// Read a CRLF- (or bare-LF-) terminated line; `None` on immediate EOF.
fn read_crlf_line(r: &mut impl BufRead) -> Result<Option<String>> {
    let mut buf = Vec::new();
    loop {
        let mut byte = [0u8; 1];
        match r.read(&mut byte) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None);
                }
                bail!("connection closed mid-line");
            }
            Ok(_) => {
                let [b] = byte;
                if b == b'\n' {
                    if buf.last() == Some(&b'\r') {
                        buf.pop();
                    }
                    let s = String::from_utf8(buf).context("non-UTF-8 header line")?;
                    return Ok(Some(s));
                }
                buf.push(b);
                ensure!(buf.len() <= MAX_LINE, "header line too long");
            }
            Err(e) => return Err(e).context("reading header line"),
        }
    }
}

/// Header block (both directions of the protocol): lines until the
/// blank separator, names lower-cased.
fn read_headers(r: &mut impl BufRead) -> Result<BTreeMap<String, String>> {
    let mut headers = BTreeMap::new();
    loop {
        let Some(line) = read_crlf_line(r)? else {
            bail!("connection closed mid-headers")
        };
        if line.is_empty() {
            return Ok(headers);
        }
        ensure!(headers.len() < MAX_HEADERS, "too many headers");
        let (k, v) = line.split_once(':').context("malformed header line")?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
}

fn parse_query(q: &str) -> BTreeMap<String, String> {
    q.split('&')
        .filter(|p| !p.is_empty())
        .map(|p| match p.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (p.to_string(), String::new()),
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Response
// ---------------------------------------------------------------------------

pub fn status_reason(code: u16) -> &'static str {
    match code {
        200 => "OK",
        202 => "Accepted",
        204 => "No Content",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// A complete (non-streaming) response.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on a 429).
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Json) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: json::to_string_pretty(v).into_bytes(),
            headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            content_type: "text/plain",
            body: body.as_bytes().to_vec(),
            headers: Vec::new(),
        }
    }

    /// JSON `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: &str) -> Self {
        Self::json(status, &Json::obj(vec![("error", msg.into())]))
    }

    /// Attach an extra response header (builder style).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Self {
        self.headers.push((name, value.into()));
        self
    }

    pub fn write(&self, w: &mut impl Write, keep_alive: bool) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_reason(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Writer for a `Transfer-Encoding: chunked` response — the progress
/// streaming endpoint emits one JSON line per chunk as layers complete.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    /// Write the status line + headers and hand back the chunk writer.
    /// A chunked response always closes the connection when done (the
    /// stream end is job completion, not a byte count).
    pub fn begin(w: &'a mut W, status: u16, content_type: &str) -> std::io::Result<Self> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
            status,
            status_reason(status),
            content_type,
        )?;
        w.flush()?;
        Ok(Self { w })
    }

    /// Send one chunk (empty input is skipped: a zero-size chunk would
    /// terminate the stream).
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream with the zero-size chunk.
    pub fn finish(self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// Client side: read a status line + headers (names lower-cased).
pub fn read_response_head(r: &mut impl BufRead) -> Result<(u16, BTreeMap<String, String>)> {
    let line = read_crlf_line(r)?.context("EOF before status line")?;
    let mut parts = line.split_whitespace();
    let version = parts.next().context("empty status line")?;
    ensure!(version.starts_with("HTTP/1."), "not an HTTP response: {line:?}");
    let code: u16 = parts
        .next()
        .context("status line has no code")?
        .parse()
        .context("bad status code")?;
    Ok((code, read_headers(r)?))
}

/// Client side of a chunked response: read chunks, invoking `on_line`
/// per newline-terminated line of payload, until the terminal chunk.
pub fn read_chunked(r: &mut impl BufRead, mut on_line: impl FnMut(&str)) -> Result<()> {
    let mut pending = String::new();
    loop {
        let size_line = read_crlf_line(r)?.context("EOF mid chunked stream")?;
        let size = usize::from_str_radix(size_line.trim(), 16)
            .with_context(|| format!("bad chunk size {size_line:?}"))?;
        // bound BEFORE allocating: a hostile "ffffffffffffffff" size
        // line would otherwise panic (or OOM) in `vec![0u8; size]`
        ensure!(size <= MAX_CHUNK, "chunk too large ({size} bytes)");
        let mut data = vec![0u8; size];
        r.read_exact(&mut data).context("reading chunk")?;
        // consume the CRLF after the chunk payload
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf).context("reading chunk terminator")?;
        if size == 0 {
            if !pending.is_empty() {
                on_line(&pending);
            }
            return Ok(());
        }
        pending.push_str(std::str::from_utf8(&data).context("non-UTF-8 chunk")?);
        while let Some(nl) = pending.find('\n') {
            let line: String = pending.drain(..=nl).collect();
            let line = line.trim_end();
            if !line.is_empty() {
                on_line(line);
            }
        }
        // whatever is left is one payload line still missing its
        // newline — bound it so a newline-free stream can't grow the
        // carry-over buffer forever
        ensure!(
            pending.len() <= MAX_CHUNK,
            "chunked payload line too long ({} bytes)",
            pending.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn req(raw: &str) -> Request {
        Request::read(&mut BufReader::new(raw.as_bytes()))
            .unwrap()
            .unwrap()
    }

    #[test]
    fn parses_get_with_query() {
        let r = req("GET /jobs/3?stream=1&x=a%20b HTTP/1.1\r\nHost: h\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/jobs/3");
        assert_eq!(r.segments(), vec!["jobs", "3"]);
        assert_eq!(r.query.get("stream").map(String::as_str), Some("1"));
        assert_eq!(r.headers.get("host").map(String::as_str), Some("h"));
        assert!(r.keep_alive());
    }

    #[test]
    fn parses_post_body_and_close() {
        let body = r#"{"model":"tiny"}"#;
        let raw = format!(
            "POST /jobs HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{}",
            body.len(),
            body
        );
        let r = req(&raw);
        assert_eq!(r.method, "POST");
        assert!(!r.keep_alive());
        assert_eq!(r.body_json().unwrap().at(&["model"]).as_str(), Some("tiny"));
    }

    #[test]
    fn eof_before_request_is_none() {
        let out = Request::read(&mut BufReader::new(&b""[..])).unwrap();
        assert!(out.is_none());
    }

    #[test]
    fn two_requests_on_one_connection() {
        let raw = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\n";
        let mut r = BufReader::new(raw.as_bytes());
        assert_eq!(Request::read(&mut r).unwrap().unwrap().path, "/a");
        assert_eq!(Request::read(&mut r).unwrap().unwrap().path, "/b");
        assert!(Request::read(&mut r).unwrap().is_none());
    }

    #[test]
    fn rejects_garbage() {
        let mut r = BufReader::new(&b"NOT-HTTP\r\n\r\n"[..]);
        assert!(Request::read(&mut r).is_err());
        let mut r = BufReader::new(&b"GET / HTTP/9.9\r\n\r\n"[..]);
        assert!(Request::read(&mut r).is_err());
    }

    #[test]
    fn response_wire_format() {
        let resp = Response::json(200, &Json::obj(vec![("ok", true.into())]));
        let mut out = Vec::new();
        resp.write(&mut out, true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        assert_eq!(
            text[..body_at].to_lowercase().contains("content-length"),
            true
        );
        assert_eq!(json::parse(&text[body_at..]).unwrap().at(&["ok"]).as_bool(), Some(true));
    }

    #[test]
    fn extra_headers_are_emitted_before_the_body() {
        let resp = Response::error(429, "slow down").with_header("Retry-After", "1");
        let mut out = Vec::new();
        resp.write(&mut out, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        assert!(text[..body_at].contains("Retry-After: 1\r\n"), "{text}");
        assert!(text[body_at..].contains("slow down"));
    }

    #[test]
    fn chunked_roundtrip() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::begin(&mut wire, 200, "application/json").unwrap();
            cw.chunk(b"{\"a\":1}\n").unwrap();
            cw.chunk(b"").unwrap(); // skipped, must not terminate
            cw.chunk(b"{\"b\":2}\n{\"c\"").unwrap();
            cw.chunk(b":3}\n").unwrap();
            cw.finish().unwrap();
        }
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked"));
        // skip the headers, then decode the chunk stream
        let body_at = text.find("\r\n\r\n").unwrap() + 4;
        let mut r = BufReader::new(&wire[body_at..]);
        let mut lines = Vec::new();
        read_chunked(&mut r, |l| lines.push(l.to_string())).unwrap();
        assert_eq!(lines, vec!["{\"a\":1}", "{\"b\":2}", "{\"c\":3}"]);
    }
}
