//! Worker side of the pruning fleet (`sparsefw serve --worker`).
//!
//! A worker owns one [`PruneSession`] and no listener: it registers
//! with the coordinator, then pulls work over the same blocking
//! [`Client`] the CLI uses — `POST /fleet/workers/:id/poll` leases a
//! shard, [`PruneSession::execute_shard`] runs it on the standard
//! per-layer drivers, and `POST /fleet/shards/:id/result` ships the
//! layers back as journal checkpoints (the bit-exact codec).  While a
//! shard runs, a sidecar thread keeps heartbeating (`{busy: true}`)
//! so a long FW solve is not mistaken for a dead worker.
//!
//! The worker records its trace spans into a private [`RingSink`]
//! under the job's correlation ID and ships them with the result; the
//! coordinator grafts them into its own ring so `sparsefw trace --job`
//! shows one tree spanning both processes.
//!
//! Failure is the coordinator's problem by design: a worker that dies
//! mid-shard simply stops heartbeating and its lease requeues.  The
//! only local failure policy is a bounded retry on coordinator
//! round-trips — after [`MAX_CONSECUTIVE_FAILURES`] straight network
//! errors the worker exits instead of spinning forever.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::PruneSession;
use crate::server::journal::LayerCheckpoint;
use crate::server::Client;
use crate::util::json::Json;
use crate::util::telemetry::{self, RingSink, TraceSink};

use super::wire::{self, ShardAssignment, ShardResult};

/// Consecutive failed coordinator round-trips before the worker gives
/// up and exits (a dead coordinator must not leave workers spinning).
pub const MAX_CONSECUTIVE_FAILURES: usize = 30;

/// How a worker process runs.
pub struct WorkerOptions {
    /// Coordinator address (`host:port`).
    pub coordinator: String,
    /// Bearer token, when the coordinator runs with `--auth-token`.
    pub token: Option<String>,
    /// Human-readable label shown in `GET /fleet`.
    pub label: String,
    /// Idle poll / heartbeat interval.
    pub poll_ms: u64,
    /// Cooperative shutdown flag (tests; the CLI runs until killed).
    pub stop: Arc<AtomicBool>,
    /// Test hook: on taking lease number N (0-based), exit without
    /// reporting or heartbeating — indistinguishable from a worker
    /// SIGKILLed mid-shard, which is exactly what it simulates.
    pub abscond_on_lease: Option<usize>,
}

impl WorkerOptions {
    pub fn new(coordinator: impl Into<String>, label: impl Into<String>) -> Self {
        Self {
            coordinator: coordinator.into(),
            token: None,
            label: label.into(),
            poll_ms: 100,
            stop: Arc::new(AtomicBool::new(false)),
            abscond_on_lease: None,
        }
    }

    fn client(&self) -> Client {
        let mut c = Client::new(self.coordinator.clone());
        if let Some(t) = &self.token {
            c = c.with_token(t.clone());
        }
        c
    }
}

/// Register, then poll-execute-report until `stop` is set or the
/// coordinator stays unreachable past the retry budget.
pub fn run_worker(opts: &WorkerOptions, mut session: PruneSession) -> Result<()> {
    let c = opts.client();
    let reg = c
        .post(
            "/fleet/workers",
            &Json::obj(vec![("label", Json::from(opts.label.as_str()))]),
        )
        .context("registering with the fleet coordinator")?;
    let id = reg
        .at(&["worker"])
        .as_usize()
        .context("register response carries no worker id")? as u64;
    crate::info!(
        "fleet worker {id} ({}): registered with coordinator {}",
        opts.label,
        opts.coordinator
    );
    let poll_path = format!("/fleet/workers/{id}/poll");
    let mut failures = 0usize;
    let mut leases = 0usize;
    while !opts.stop.load(Ordering::Relaxed) {
        let resp = match c.post(&poll_path, &Json::obj(vec![("busy", Json::from(false))])) {
            Ok(v) => {
                failures = 0;
                v
            }
            Err(e) => {
                failures += 1;
                if failures >= MAX_CONSECUTIVE_FAILURES {
                    return Err(e.context(format!(
                        "fleet worker {id}: coordinator unreachable \
                         ({failures} consecutive poll failures)"
                    )));
                }
                std::thread::sleep(Duration::from_millis(opts.poll_ms));
                continue;
            }
        };
        let Some(aj) = resp.get("assignment") else {
            std::thread::sleep(Duration::from_millis(opts.poll_ms));
            continue;
        };
        let a = wire::assignment_from_json(aj).context("decoding shard assignment")?;
        if opts.abscond_on_lease == Some(leases) {
            crate::warnlog!(
                "fleet worker {id}: absconding with job {} shard {} (test hook)",
                a.job,
                a.shard
            );
            return Ok(());
        }
        leases += 1;
        crate::info!(
            "fleet worker {id}: leased job {} shard {} (blocks {}..{})",
            a.job,
            a.shard,
            a.lo,
            a.hi
        );
        // heartbeat sidecar: `{busy: true}` refreshes the lease without
        // requesting work, so a slow shard never looks like a death
        let done = Arc::new(AtomicBool::new(false));
        let hb = {
            let done = done.clone();
            let hb_client = opts.client();
            let path = poll_path.clone();
            let interval = Duration::from_millis(opts.poll_ms.max(1));
            std::thread::spawn(move || {
                while !done.load(Ordering::Relaxed) {
                    // best-effort: a missed beat just ages the lease
                    let _ = hb_client.post(&path, &Json::obj(vec![("busy", Json::from(true))]));
                    std::thread::sleep(interval);
                }
            })
        };
        let result = execute_assignment(id, &a, &mut session);
        done.store(true, Ordering::Relaxed);
        let _ = hb.join();
        let path = format!("/fleet/shards/{}/result", a.shard);
        match c.post(&path, &wire::result_to_json(&result)) {
            Ok(v) => crate::info!(
                "fleet worker {id}: job {} shard {} reported ({})",
                a.job,
                a.shard,
                v.at(&["state"]).as_str().unwrap_or("?")
            ),
            Err(e) => crate::warnlog!(
                "fleet worker {id}: reporting job {} shard {} failed: {e:#} \
                 (coordinator will requeue it)",
                a.job,
                a.shard
            ),
        }
    }
    crate::info!("fleet worker {id}: stopping");
    Ok(())
}

/// Run one leased shard and package the outcome — including the spans
/// it traced — as a wire [`ShardResult`].  Never errors: a failed
/// shard becomes an `ok: false` result the coordinator requeues.
fn execute_assignment(worker: u64, a: &ShardAssignment, session: &mut PruneSession) -> ShardResult {
    let ring = Arc::new(RingSink::new(2048, 4));
    let sink: Arc<dyn TraceSink> = ring.clone();
    telemetry::add_sink(sink.clone());
    let outcome = {
        let _corr = telemetry::with_correlation(&a.corr);
        let _sp = crate::span!("shard", job = a.job, shard = a.shard, lo = a.lo, hi = a.hi);
        session.execute_shard(&a.spec, a.lo, a.hi, a.entry.clone())
    };
    telemetry::remove_sink(&sink);
    let spans = ring.events_for(&a.corr);
    match outcome {
        Ok(out) => ShardResult {
            worker,
            job: a.job,
            shard: a.shard,
            ok: true,
            error: None,
            entry_digest: out.entry_digest,
            layers: out
                .layers
                .iter()
                .enumerate()
                .map(|(i, (info, o))| LayerCheckpoint::from_output(4 * a.lo + i, &info.name, o))
                .collect(),
            exit: out.exit,
            spans,
        },
        Err(e) => ShardResult {
            worker,
            job: a.job,
            shard: a.shard,
            ok: false,
            error: Some(format!("{e:#}")),
            entry_digest: 0,
            layers: Vec::new(),
            exit: None,
            spans,
        },
    }
}
