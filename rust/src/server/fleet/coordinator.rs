//! Coordinator side of the pruning fleet.
//!
//! One [`FleetState`] lives on a `sparsefw serve --coordinator`
//! process.  Jobs still arrive through the unchanged public API
//! (`POST /jobs` → the same [`JobQueue`]); instead of worker *threads*
//! popping the queue, a single [`dispatcher_loop`] thread pops each job
//! and runs it across the registered worker *processes*:
//!
//! 1. **Plan** — [`plan_shards`] cuts the job's blocks into contiguous
//!    shards (contiguity is forced by the staged hand-off; blocks are
//!    the natural unit because the layer-wise objective is
//!    block-decomposable).
//! 2. **Dispatch** — workers pull work: `POST /fleet/workers/:id/poll`
//!    leases the *costliest ready* pending shard (pull-based LPT — the
//!    same greedy [`assign_shards`] computes statically, realized
//!    online as each worker frees up).  Dense shards are all ready at
//!    once and run in parallel; staged shards become ready as their
//!    predecessor lands, forming a pipeline whose hand-off is the
//!    predecessor's exit hiddens (O(shard) memory per worker, never
//!    O(model)).
//! 3. **Collect** — results are accepted by `(job, shard)`, so a
//!    worker presumed dead that reports late is simply a second,
//!    bit-identical copy (execution is deterministic) and the stale
//!    copy is dropped.  Missed heartbeats mark a worker dead and
//!    requeue its leased shards on the live set, with a bounded
//!    attempt budget.
//! 4. **Assemble** — shard results are journal [`LayerCheckpoint`]s;
//!    the same `to_output` path the crash-recovery suite proves
//!    bit-identical reconstructs every layer, and the standard
//!    [`collect_outputs`] builds the [`PruneResult`], so
//!    `JobSummary::mask_digest` matches a single-node run bit for bit.
//!
//! If no worker registers within the heartbeat window (or the job
//! targets a non-native backend), the dispatcher falls back to plain
//! local execution — a coordinator with no fleet degrades to a
//! single-worker server, it never wedges.
//!
//! Lock discipline: `FleetState.inner` is a plain mutex held only for
//! in-memory bookkeeping; all I/O (journal appends, trace recording,
//! HTTP) happens outside it, in the API handlers or the dispatcher.
//!
//! [`JobQueue`]: crate::server::queue::JobQueue
//! [`plan_shards`]: crate::coordinator::schedule::plan_shards
//! [`assign_shards`]: crate::coordinator::schedule::assign_shards
//! [`collect_outputs`]: crate::coordinator::collect_outputs
//! [`PruneResult`]: crate::coordinator::PruneResult

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::calib::EmbedPrefix;
use crate::config::Backend;
use crate::coordinator::schedule::{plan_shards, ShardPlan};
use crate::coordinator::{
    collect_outputs, JobResult, JobSpec, LayerEvent, PruneSession, StagedStats,
};
use crate::server::journal::LayerCheckpoint;
use crate::server::queue::{JobId, JobSummary};
use crate::util::json::Json;
use crate::util::sync::{lock_recover, wait_timeout_recover};
use crate::util::telemetry::{self, TraceEvent};

use super::super::ServerState;
use super::wire::{self, ShardAssignment, ShardResult};

/// A shard is abandoned (and the job failed) after this many lease
/// attempts — a shard that kills every worker it lands on must not
/// requeue forever (the `unbounded-retry` lint's concern, applied to
/// the cluster).
pub const MAX_SHARD_ATTEMPTS: usize = 5;

/// Remapped span IDs for grafted worker spans start here, far above
/// anything the local `span!` counter will reach, so coordinator-local
/// and remote span IDs can never collide in the trace ring.
const REMOTE_SPAN_BASE: u64 = 1 << 48;

// ---------------------------------------------------------------------------
// State
// ---------------------------------------------------------------------------

struct WorkerEntry {
    label: String,
    last_seen: Instant,
    live: bool,
    shards_done: usize,
}

enum ShardPhase {
    Pending,
    Leased { worker: u64 },
    Done,
}

impl ShardPhase {
    fn label(&self) -> &'static str {
        match self {
            ShardPhase::Pending => "pending",
            ShardPhase::Leased { .. } => "leased",
            ShardPhase::Done => "done",
        }
    }
}

struct ShardState {
    plan: ShardPlan,
    phase: ShardPhase,
    attempts: usize,
    /// Staged entry hiddens, populated when the predecessor lands
    /// (`None` for shard 0 and for dense shards: no hand-off).
    entry: Option<EmbedPrefix>,
    /// Digest the dispatched entry decodes to; the worker echoes the
    /// digest it actually started from and the two must agree.
    expect_digest: Option<u64>,
    layers: Vec<LayerCheckpoint>,
}

struct ActiveJob {
    id: JobId,
    corr: String,
    spec: JobSpec,
    n_blocks: usize,
    staged: bool,
    total_layers: usize,
    completed_layers: usize,
    shards: Vec<ShardState>,
    failed: Option<String>,
}

impl ActiveJob {
    fn done(&self) -> bool {
        self.shards.iter().all(|s| matches!(s.phase, ShardPhase::Done))
    }
}

#[derive(Default)]
struct FleetInner {
    workers: BTreeMap<u64, WorkerEntry>,
    job: Option<ActiveJob>,
}

/// Everything the coordinator knows about its fleet: the worker
/// registry, the active job's shard table, and the fleet counters
/// behind the `sparsefw_fleet_*` metrics.
pub struct FleetState {
    /// A worker whose last heartbeat is older than this is presumed
    /// dead; its leased shards requeue on the live set.
    pub heartbeat_timeout: Duration,
    pub workers_registered: AtomicUsize,
    pub shards_dispatched: AtomicUsize,
    pub shards_requeued: AtomicUsize,
    pub handoff_bytes: AtomicUsize,
    next_worker: AtomicU64,
    next_span: AtomicU64,
    inner: Mutex<FleetInner>,
    cv: Condvar,
}

/// What accepting one shard result produced — everything the API
/// handler needs to do the I/O the lock must not hold: journal lines,
/// progress events for the live stream, and remapped trace spans.
pub(crate) struct Accepted {
    pub job: JobId,
    pub shard: usize,
    pub worker: u64,
    /// `"done"`, `"requeued"`, or `"stale"` (duplicate of a shard that
    /// already landed — deterministic execution makes it bit-identical,
    /// so it is simply dropped).
    pub state_label: &'static str,
    pub layer_events: Vec<LayerEvent>,
    pub spans: Vec<TraceEvent>,
}

impl FleetState {
    pub fn new(heartbeat_timeout: Duration) -> Self {
        Self {
            heartbeat_timeout,
            workers_registered: AtomicUsize::new(0),
            shards_dispatched: AtomicUsize::new(0),
            shards_requeued: AtomicUsize::new(0),
            handoff_bytes: AtomicUsize::new(0),
            next_worker: AtomicU64::new(0),
            next_span: AtomicU64::new(REMOTE_SPAN_BASE),
            inner: Mutex::new(FleetInner::default()),
            cv: Condvar::new(),
        }
    }

    /// Register a worker; returns its fleet-unique ID.
    pub fn register(&self, label: &str) -> u64 {
        let id = self.next_worker.fetch_add(1, Ordering::Relaxed) + 1;
        let mut inner = lock_recover(&self.inner);
        inner.workers.insert(
            id,
            WorkerEntry {
                label: label.to_string(),
                last_seen: Instant::now(),
                live: true,
                shards_done: 0,
            },
        );
        self.workers_registered.fetch_add(1, Ordering::Relaxed);
        self.cv.notify_all();
        id
    }

    /// Live (heartbeating) worker count — the `sparsefw_fleet_workers_live`
    /// gauge, and the shard-count input to job planning.
    pub fn live_workers(&self) -> usize {
        let inner = lock_recover(&self.inner);
        inner
            .workers
            .values()
            .filter(|w| w.live && w.last_seen.elapsed() <= self.heartbeat_timeout)
            .count()
    }

    /// Heartbeat + lease: refresh the worker's liveness and, unless it
    /// is mid-shard (`busy`), lease it the costliest ready shard.
    pub(crate) fn poll(&self, worker: u64, busy: bool) -> Result<Option<ShardAssignment>> {
        let mut inner = lock_recover(&self.inner);
        let Some(w) = inner.workers.get_mut(&worker) else {
            bail!("unknown worker {worker}; register first (POST /fleet/workers)")
        };
        w.last_seen = Instant::now();
        w.live = true;
        if busy {
            return Ok(None);
        }
        let Some(job) = inner.job.as_mut() else { return Ok(None) };
        if job.failed.is_some() {
            return Ok(None);
        }
        // pull-based LPT: the costliest *ready* pending shard.  Dense
        // jobs have every shard ready (parallel fan-out); staged jobs
        // expose shard i only once shard i-1 landed (pipeline).
        let mut best: Option<usize> = None;
        for i in 0..job.shards.len() {
            let pending = job
                .shards
                .get(i)
                .is_some_and(|s| matches!(s.phase, ShardPhase::Pending));
            if !pending {
                continue;
            }
            let ready = !job.staged
                || i == 0
                || job
                    .shards
                    .get(i - 1)
                    .is_some_and(|p| matches!(p.phase, ShardPhase::Done));
            if !ready {
                continue;
            }
            let cost = job.shards.get(i).map(|s| s.plan.cost).unwrap_or(0);
            let best_cost =
                best.and_then(|b| job.shards.get(b)).map(|s| s.plan.cost).unwrap_or(0);
            if best.is_none() || cost > best_cost {
                best = Some(i);
            }
        }
        let Some(i) = best else { return Ok(None) };
        let Some(s) = job.shards.get_mut(i) else { return Ok(None) };
        s.phase = ShardPhase::Leased { worker };
        let assignment = ShardAssignment {
            job: job.id,
            shard: i,
            corr: job.corr.clone(),
            lo: s.plan.lo,
            hi: s.plan.hi,
            n_blocks: job.n_blocks,
            spec: job.spec.clone(),
            entry: s.entry.clone(),
        };
        self.shards_dispatched.fetch_add(1, Ordering::Relaxed);
        if let Some(p) = &assignment.entry {
            self.handoff_bytes.fetch_add(wire::handoff_bytes(p), Ordering::Relaxed);
        }
        Ok(Some(assignment))
    }

    /// Accept one shard result.  Success stores the shard's layers,
    /// arms the successor's hand-off, and reports progress; failure
    /// requeues the shard (bounded by [`MAX_SHARD_ATTEMPTS`]).
    pub(crate) fn accept_result(&self, r: ShardResult) -> Result<Accepted> {
        let mut inner = lock_recover(&self.inner);
        if let Some(w) = inner.workers.get_mut(&r.worker) {
            w.last_seen = Instant::now();
            w.live = true;
            if r.ok {
                w.shards_done += 1;
            }
        }
        let Some(job) = inner.job.as_mut() else {
            bail!("no active fleet job (result for job {} shard {})", r.job, r.shard)
        };
        let corr = job.corr.clone();
        let mut acc = Accepted {
            job: job.id,
            shard: r.shard,
            worker: r.worker,
            state_label: "stale",
            layer_events: Vec::new(),
            spans: Vec::new(),
        };
        if job.id != r.job {
            return Ok(acc); // a previous job's straggler: drop
        }
        let staged = job.staged;
        let n_blocks = job.n_blocks;
        let Some(s) = job.shards.get_mut(r.shard) else {
            bail!("job {} has no shard {}", r.job, r.shard)
        };
        if matches!(s.phase, ShardPhase::Done) {
            return Ok(acc); // duplicate of a landed shard: bit-identical, drop
        }
        // Any defect in the result — reported failure, hand-off digest
        // mismatch, wrong layer count, missing successor hand-off —
        // requeues the shard rather than erroring: erroring would leave
        // the lease stuck on a live worker, and re-execution is cheap
        // and deterministic.  The attempt budget bounds the retries.
        let span = 4 * (s.plan.hi - s.plan.lo);
        let needs_exit = staged && s.plan.hi < n_blocks;
        let defect = if !r.ok {
            Some(r.error.clone().unwrap_or_else(|| "unspecified worker error".into()))
        } else if s.expect_digest.is_some_and(|want| r.entry_digest != want) {
            Some(format!(
                "entry digest {:016x} != dispatched {:016x}",
                r.entry_digest,
                s.expect_digest.unwrap_or(0)
            ))
        } else if r.layers.len() != span {
            Some(format!("returned {} layers, want {span}", r.layers.len()))
        } else if needs_exit && r.exit.is_none() {
            Some("missing the hand-off its successor needs".into())
        } else {
            None
        };
        if let Some(err) = defect {
            s.phase = ShardPhase::Pending;
            s.attempts += 1;
            acc.state_label = "requeued";
            if s.attempts >= MAX_SHARD_ATTEMPTS {
                job.failed = Some(format!(
                    "shard {} failed {} times, giving up (last: {err})",
                    r.shard, s.attempts
                ));
            }
            self.shards_requeued.fetch_add(1, Ordering::Relaxed);
            self.cv.notify_all();
            return Ok(acc);
        }
        s.layers = r.layers;
        s.phase = ShardPhase::Done;
        acc.state_label = "done";
        if needs_exit {
            if let Some(exit) = r.exit {
                let digest = exit.digest();
                if let Some(next) = job.shards.get_mut(r.shard + 1) {
                    next.entry = Some(exit);
                    next.expect_digest = Some(digest);
                }
            }
        }
        // progress events (completion order, like the local pool)
        let total = job.total_layers;
        let mut completed = job.completed_layers;
        if let Some(s) = job.shards.get(r.shard) {
            for ck in &s.layers {
                acc.layer_events.push(LayerEvent {
                    layer: ck.name.clone(),
                    index: completed,
                    total,
                    obj: ck.obj,
                });
                completed += 1;
            }
        }
        job.completed_layers = completed;
        acc.spans = self.remap_spans(&corr, &r.spans);
        self.cv.notify_all();
        Ok(acc)
    }

    /// Graft worker-side spans into the coordinator's ID space: every
    /// remote span gets a fresh ID above [`REMOTE_SPAN_BASE`], parents
    /// are rewritten through the same map (unknown parents become
    /// roots), and every span is re-tagged with the job's correlation
    /// ID so `GET /jobs/:id/trace` returns one joined tree.
    fn remap_spans(&self, corr: &str, spans: &[TraceEvent]) -> Vec<TraceEvent> {
        if corr.is_empty() {
            return Vec::new(); // ring slices are keyed by corr; nothing to file under
        }
        let mut map: BTreeMap<u64, u64> = BTreeMap::new();
        for ev in spans {
            map.insert(ev.span_id, self.next_span.fetch_add(1, Ordering::Relaxed));
        }
        let corr: Arc<str> = Arc::from(corr);
        spans
            .iter()
            .map(|ev| TraceEvent {
                span_id: map.get(&ev.span_id).copied().unwrap_or(0),
                parent_id: map.get(&ev.parent_id).copied().unwrap_or(0),
                corr_id: Some(corr.clone()),
                name: ev.name,
                fields: Vec::new(),
                wall_ms: ev.wall_ms,
                mono_us: ev.mono_us,
                dur_us: ev.dur_us,
            })
            .collect()
    }

    /// Expire workers whose heartbeat lapsed and requeue their leased
    /// shards.  Returns the indices of the requeued shards.
    pub(crate) fn reap(&self) -> Vec<usize> {
        let mut inner = lock_recover(&self.inner);
        let timeout = self.heartbeat_timeout;
        let mut dead: Vec<u64> = Vec::new();
        for (&id, w) in inner.workers.iter_mut() {
            if w.live && w.last_seen.elapsed() > timeout {
                w.live = false;
                dead.push(id);
            }
        }
        if dead.is_empty() {
            return Vec::new();
        }
        let mut requeued = Vec::new();
        if let Some(job) = inner.job.as_mut() {
            for (i, s) in job.shards.iter_mut().enumerate() {
                let ShardPhase::Leased { worker } = s.phase else { continue };
                if !dead.contains(&worker) {
                    continue;
                }
                s.phase = ShardPhase::Pending;
                s.attempts += 1;
                requeued.push(i);
                if s.attempts >= MAX_SHARD_ATTEMPTS {
                    job.failed = Some(format!(
                        "shard {i} lost {} workers, giving up",
                        s.attempts
                    ));
                }
            }
        }
        if !requeued.is_empty() {
            self.shards_requeued.fetch_add(requeued.len(), Ordering::Relaxed);
            self.cv.notify_all();
        }
        requeued
    }

    /// Install a freshly planned job (one at a time: the dispatcher is
    /// single-threaded, mirroring the one-PruneSession-per-worker
    /// invariant of the local path).
    fn install_job(
        &self,
        id: JobId,
        corr: &str,
        spec: JobSpec,
        n_blocks: usize,
        total_layers: usize,
        plans: Vec<ShardPlan>,
        staged: bool,
    ) {
        let shards = plans
            .into_iter()
            .map(|plan| ShardState {
                plan,
                phase: ShardPhase::Pending,
                attempts: 0,
                entry: None,
                expect_digest: None,
                layers: Vec::new(),
            })
            .collect();
        let mut inner = lock_recover(&self.inner);
        inner.job = Some(ActiveJob {
            id,
            corr: corr.to_string(),
            spec,
            n_blocks,
            staged,
            total_layers,
            completed_layers: 0,
            shards,
            failed: None,
        });
        self.cv.notify_all();
    }

    /// Block until something changes (a result landed, a reap fired),
    /// then report `(all shards done, failure)`.
    fn wait_progress(&self, dur: Duration) -> (bool, Option<String>) {
        let inner = lock_recover(&self.inner);
        let (inner, _timed_out) = wait_timeout_recover(&self.cv, inner, dur);
        match &inner.job {
            Some(j) => (j.done(), j.failed.clone()),
            None => (false, Some("fleet job vanished mid-run".into())),
        }
    }

    /// Tear down the active job, returning its shards' checkpoints in
    /// shard (= model) order.
    fn take_job(&self, id: JobId) -> Result<Vec<LayerCheckpoint>> {
        let mut inner = lock_recover(&self.inner);
        let job = inner.job.take().context("no active fleet job to collect")?;
        ensure!(job.id == id, "active fleet job is {}, not {id}", job.id);
        Ok(job.shards.into_iter().flat_map(|s| s.layers).collect())
    }

    fn clear_job(&self) {
        lock_recover(&self.inner).job = None;
    }

    /// `GET /fleet` — registry + shard table snapshot.
    pub fn status_json(&self) -> Json {
        let inner = lock_recover(&self.inner);
        let workers: Vec<Json> = inner
            .workers
            .iter()
            .map(|(&id, w)| {
                Json::obj(vec![
                    ("id", Json::from(id as usize)),
                    ("label", Json::from(w.label.as_str())),
                    (
                        "live",
                        Json::from(w.live && w.last_seen.elapsed() <= self.heartbeat_timeout),
                    ),
                    ("shards_done", Json::from(w.shards_done)),
                    ("last_seen_secs", Json::from(w.last_seen.elapsed().as_secs_f64())),
                ])
            })
            .collect();
        let mut fields = vec![
            ("workers", Json::Arr(workers)),
            (
                "workers_registered",
                Json::from(self.workers_registered.load(Ordering::Relaxed)),
            ),
            (
                "shards_dispatched",
                Json::from(self.shards_dispatched.load(Ordering::Relaxed)),
            ),
            (
                "shards_requeued",
                Json::from(self.shards_requeued.load(Ordering::Relaxed)),
            ),
            ("handoff_bytes", Json::from(self.handoff_bytes.load(Ordering::Relaxed))),
        ];
        if let Some(job) = &inner.job {
            let shards: Vec<Json> = job
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    Json::obj(vec![
                        ("shard", Json::from(i)),
                        ("lo", Json::from(s.plan.lo)),
                        ("hi", Json::from(s.plan.hi)),
                        ("state", Json::from(s.phase.label())),
                        ("attempts", Json::from(s.attempts)),
                    ])
                })
                .collect();
            fields.push((
                "job",
                Json::obj(vec![
                    ("id", Json::from(job.id as usize)),
                    ("staged", Json::from(job.staged)),
                    ("shards", Json::Arr(shards)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

// ---------------------------------------------------------------------------
// Dispatcher
// ---------------------------------------------------------------------------

/// The coordinator's job thread: pops the public queue exactly like a
/// local [`worker_loop`] would, but executes each job across the fleet.
/// Runs until the queue shuts down and drains.
///
/// [`worker_loop`]: super::super::worker_loop
pub(crate) fn dispatcher_loop(state: Arc<ServerState>, mut session: PruneSession) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let Some(fleet) = state.fleet.clone() else { return };
    let (mut hits_seen, mut misses_seen) = session.calib_stats();
    while let Some((id, spec)) = state.queue.pop_blocking(0) {
        state.metrics.busy_workers.fetch_add(1, Ordering::Relaxed);
        let rec = state.queue.get(id);
        let corr = rec.as_ref().map(|r| r.corr_id.clone()).unwrap_or_default();
        if let Some(r) = &rec {
            state.metrics.queue_wait.observe(r.queued_secs());
        }
        let _corr_guard = telemetry::with_correlation(&corr);
        crate::info!("fleet dispatcher: job {id} starting ({})", spec.label());
        if let Some(j) = &state.journal {
            j.record_state(id, "running");
        }
        // local-fallback progress; fleet shards report theirs through
        // the /fleet/shards/:id/result handler instead
        let progress_state = state.clone();
        session.on_progress(move |e| progress_state.queue.push_event(id, e.clone()));
        // contain panics exactly like the local worker_loop: an unwound
        // dispatcher would wedge every subsequent job in Queued forever
        let outcome = {
            let _sp = crate::span!("job", id = id, fleet = 1);
            match catch_unwind(AssertUnwindSafe(|| {
                run_fleet_job(&state, &fleet, &mut session, id, &spec, &corr)
            })) {
                Ok(res) => res,
                Err(_) => {
                    fleet.clear_job();
                    Err(anyhow::anyhow!("fleet dispatcher panicked running job {id}"))
                }
            }
        };
        session.clear_progress();
        let (hits, misses) = session.calib_stats();
        state.metrics.calib_hits.fetch_add(hits - hits_seen, Ordering::Relaxed);
        state.metrics.calib_misses.fetch_add(misses - misses_seen, Ordering::Relaxed);
        (hits_seen, misses_seen) = (hits, misses);
        match outcome {
            Ok(res) => {
                let summary = JobSummary::from_result(&res);
                crate::info!(
                    "fleet dispatcher: job {id} done in {:.2}s (Σ err {:.4e}, digest {})",
                    summary.wall_seconds,
                    summary.total_err,
                    summary.mask_digest
                );
                state.metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                state.metrics.job_wall.observe(summary.wall_seconds);
                state
                    .metrics
                    .job_wall_ms
                    .fetch_add((summary.wall_seconds * 1e3) as u64, Ordering::Relaxed);
                state.metrics.fw_iters.fetch_add(summary.fw_iters, Ordering::Relaxed);
                if summary.calib_policy.is_some() {
                    state.metrics.jobs_propagated.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(b) = summary.peak_gram_bytes {
                    state.metrics.peak_gram_bytes.fetch_max(b, Ordering::Relaxed);
                }
                match super::super::compile_for_serving(&mut session, &res) {
                    Ok(entry) => {
                        state.compiled.insert(id, entry);
                    }
                    Err(e) => {
                        crate::warnlog!("fleet job {id}: serving compile failed: {e:#}");
                    }
                }
                state.queue.finish(id, Ok(summary));
                if let Some(j) = &state.journal {
                    j.record_state(id, "done");
                }
            }
            Err(e) => {
                crate::warnlog!("fleet dispatcher: job {id} failed: {e:#}");
                fleet.clear_job();
                state.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                state.queue.finish(id, Err(format!("{e:#}")));
                if let Some(j) = &state.journal {
                    j.record_state(id, "failed");
                }
            }
        }
        state.metrics.busy_workers.fetch_sub(1, Ordering::Relaxed);
    }
    crate::debuglog!("fleet dispatcher: exiting");
}

/// Execute one job across the fleet (or locally, when no worker is
/// live within the heartbeat window or the backend is not native).
fn run_fleet_job(
    state: &Arc<ServerState>,
    fleet: &Arc<FleetState>,
    session: &mut PruneSession,
    id: JobId,
    spec: &JobSpec,
    corr: &str,
) -> Result<JobResult> {
    let t0 = Instant::now();
    // wait out the registration window, then degrade gracefully
    let wait_until = Instant::now() + fleet.heartbeat_timeout;
    while fleet.live_workers() == 0 {
        if Instant::now() >= wait_until {
            crate::info!("fleet: no live workers; job {id} runs locally on the coordinator");
            return session.execute(spec);
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    if spec.backend != Backend::Native {
        crate::info!("fleet: {:?} backend is coordinator-local; job {id} runs locally", spec.backend);
        return session.execute(spec);
    }

    // plan: contiguous block shards, one per live worker (clamped)
    let staged = spec.calib_policy.is_propagated();
    let (layers, n_blocks) = {
        let model = session.model(&spec.model)?;
        // fail fast on an unresolvable allocation (OWL under staging)
        // before any shard is dispatched
        if staged {
            spec.allocation.resolve(model, None)?;
        }
        (model.cfg.layers(), model.cfg.n_layers)
    };
    let n_shards = fleet.live_workers().clamp(1, n_blocks.max(1));
    let plans = plan_shards(&layers, n_shards);
    ensure!(!plans.is_empty(), "job {id} has no blocks to shard");
    let n_planned = plans.len();
    fleet.install_job(id, corr, spec.clone(), n_blocks, layers.len(), plans, staged);
    if let Some(j) = &state.journal {
        for i in 0..n_planned {
            j.record_shard(id, i, "planned", 0);
        }
    }
    crate::info!(
        "fleet: job {id} planned as {n_planned} shard(s) across {} live worker(s){}",
        fleet.live_workers(),
        if staged { " (staged pipeline)" } else { "" }
    );

    // collect: workers pull shards via the API handlers; this thread
    // only reaps lapsed heartbeats and waits for the table to fill
    loop {
        let (done, failed) = fleet.wait_progress(Duration::from_millis(250));
        if let Some(msg) = failed {
            fleet.clear_job();
            bail!("fleet job {id} failed: {msg}");
        }
        if done {
            break;
        }
        let requeued = fleet.reap();
        if !requeued.is_empty() {
            crate::warnlog!(
                "fleet: requeued shard(s) {requeued:?} from lapsed worker(s) on job {id}"
            );
            if let Some(j) = &state.journal {
                for &i in &requeued {
                    j.record_shard(id, i, "requeued", 0);
                }
            }
        }
    }

    // assemble: checkpoints → outputs → PruneResult, identical to the
    // crash-recovery resume path (bit-exact by construction)
    let checkpoints = fleet.take_job(id)?;
    ensure!(
        checkpoints.len() == layers.len(),
        "fleet job {id} assembled {} layers, want {}",
        checkpoints.len(),
        layers.len()
    );
    let outputs: Vec<Result<_>> = checkpoints
        .into_iter()
        .map(|ck| {
            let l = layers
                .get(ck.index)
                .with_context(|| format!("checkpoint index {} out of range", ck.index))?;
            ensure!(
                l.name == ck.name,
                "checkpoint {} landed at index {} ({})",
                ck.name,
                ck.index,
                l.name
            );
            Ok((l.clone(), ck.to_output()?))
        })
        .collect();
    let mut prune = collect_outputs(outputs, t0)?;
    if staged {
        // calibration-memory accounting happened on the workers; the
        // coordinator records the policy + block walk (peak bytes are
        // per-worker O(shard) and not aggregated here)
        prune.staged = Some(StagedStats {
            policy: spec.calib_policy,
            blocks: n_blocks,
            peak_gram_bytes: 0,
            total_gram_bytes: layers.iter().map(|l| l.d_in * l.d_in * 4).sum(),
            peak_live_gram_sets: 0,
        });
    }

    // eval tail, mirroring PruneSession::execute
    let mut pruned_sparsity = None;
    let mut eval = None;
    if let Some(espec) = spec.eval {
        let _sp = crate::span!("io", model = &spec.model);
        let pruned = {
            let model = session.model(&spec.model)?;
            prune.apply(model)?
        };
        pruned_sparsity = Some(pruned.pruned_sparsity());
        eval = Some(session.evaluate(&pruned, &espec)?);
    }
    Ok(JobResult { spec: spec.clone(), prune, pruned_sparsity, eval })
}
