//! Wire formats for the fleet protocol.
//!
//! Everything here rides the same JSON-over-HTTP layer as the public
//! job API; the only new demand is *bit-exactness*.  Staged calibration
//! hands hidden states from one shard to its successor, and the fleet's
//! acceptance bar is a [`JobSummary::mask_digest`] identical to a
//! single-node run — so floats travel as exact little-endian f32 bit
//! patterns in hex (the journal's checkpoint encoding, proven
//! bit-identical by the crash-recovery suite), never as decimal JSON
//! numbers.  Hand-offs additionally carry their [`EmbedPrefix::digest`]
//! and the decoder verifies it, so a corrupted or truncated transfer
//! fails loudly at the boundary instead of silently skewing every
//! downstream gram.
//!
//! Shard results ship their layers as [`LayerCheckpoint`]s (reusing the
//! journal codec) plus the worker-side trace spans, so the coordinator
//! can graft remote spans into its own ring and `sparsefw trace --job`
//! shows one tree for a fleet job.
//!
//! [`JobSummary::mask_digest`]: crate::server::queue::JobSummary

use anyhow::{ensure, Context, Result};

use crate::calib::EmbedPrefix;
use crate::coordinator::JobSpec;
use crate::server::journal::{f32s_to_hex, hex_to_f32s, parse_hex_u64, u64_hex, LayerCheckpoint};
use crate::tensor::Mat;
use crate::util::json::Json;
use crate::util::telemetry::TraceEvent;

// ---------------------------------------------------------------------------
// Matrices + hidden-state hand-off
// ---------------------------------------------------------------------------

pub(crate) fn mat_to_json(m: &Mat) -> Json {
    Json::obj(vec![
        ("rows", Json::from(m.rows)),
        ("cols", Json::from(m.cols)),
        ("data_hex", Json::from(f32s_to_hex(&m.data))),
    ])
}

pub(crate) fn mat_from_json(j: &Json) -> Result<Mat> {
    let rows = j.at(&["rows"]).as_usize().context("mat missing `rows`")?;
    let cols = j.at(&["cols"]).as_usize().context("mat missing `cols`")?;
    let data =
        hex_to_f32s(j.at(&["data_hex"]).as_str().context("mat missing `data_hex`")?)?;
    ensure!(
        data.len() == rows * cols,
        "mat payload has {} f32s, want {rows}×{cols}",
        data.len()
    );
    let mut m = Mat::zeros(rows, cols);
    m.data.copy_from_slice(&data);
    Ok(m)
}

/// Serialize a staged hand-off: the predecessor shard's exit hiddens
/// plus their digest (the decoder re-derives and verifies it).
pub(crate) fn handoff_to_json(p: &EmbedPrefix) -> Json {
    Json::obj(vec![
        ("seq_len", Json::from(p.seq_len())),
        ("hiddens", Json::Arr(p.hiddens().iter().map(mat_to_json).collect())),
        ("digest", Json::from(u64_hex(p.digest()))),
    ])
}

pub(crate) fn handoff_from_json(j: &Json) -> Result<EmbedPrefix> {
    let seq_len = j.at(&["seq_len"]).as_usize().context("hand-off missing `seq_len`")?;
    let hiddens: Vec<Mat> = j
        .at(&["hiddens"])
        .as_arr()
        .context("hand-off missing `hiddens`")?
        .iter()
        .map(mat_from_json)
        .collect::<Result<_>>()?;
    let p = EmbedPrefix::from_parts(hiddens, seq_len);
    let want =
        parse_hex_u64(j.at(&["digest"]).as_str().context("hand-off missing `digest`")?)?;
    ensure!(
        p.digest() == want,
        "hand-off digest mismatch: decoded {:016x}, sender claimed {want:016x}",
        p.digest()
    );
    Ok(p)
}

/// Raw f32 payload size of a hand-off (feeds the
/// `sparsefw_fleet_handoff_bytes_total` counter).
pub(crate) fn handoff_bytes(p: &EmbedPrefix) -> usize {
    p.hiddens().iter().map(|m| m.data.len() * 4).sum()
}

// ---------------------------------------------------------------------------
// Trace spans
// ---------------------------------------------------------------------------

/// Span names the fleet ships across the wire.  [`TraceEvent::name`] is
/// `&'static str` by construction, so decoded names are interned
/// against this set; anything unrecognized (a future worker version)
/// lands as `"remote"` rather than being dropped.
const SPAN_NAMES: &[&str] =
    &["job", "shard", "calib", "gram", "fw", "refine", "io", "handoff", "remote"];

fn intern_span_name(s: &str) -> &'static str {
    SPAN_NAMES.iter().find(|n| **n == s).copied().unwrap_or("remote")
}

pub(crate) fn span_to_json(ev: &TraceEvent) -> Json {
    Json::obj(vec![
        ("span", Json::from(u64_hex(ev.span_id))),
        ("parent", Json::from(u64_hex(ev.parent_id))),
        ("name", Json::from(ev.name)),
        ("wall_ms", Json::from(ev.wall_ms as usize)),
        ("mono_us", Json::from(ev.mono_us as usize)),
        ("dur_us", Json::from(ev.dur_us as usize)),
    ])
}

/// Decode a worker-side span.  The correlation ID and structured fields
/// are intentionally not shipped: the coordinator re-tags every grafted
/// span with the job's own correlation ID when it remaps span IDs.
pub(crate) fn span_from_json(j: &Json) -> Result<TraceEvent> {
    Ok(TraceEvent {
        span_id: parse_hex_u64(
            j.at(&["span"]).as_str().context("span record missing `span`")?,
        )?,
        parent_id: parse_hex_u64(
            j.at(&["parent"]).as_str().context("span record missing `parent`")?,
        )?,
        corr_id: None,
        name: intern_span_name(
            j.at(&["name"]).as_str().context("span record missing `name`")?,
        ),
        fields: Vec::new(),
        wall_ms: j.at(&["wall_ms"]).as_usize().unwrap_or(0) as u64,
        mono_us: j.at(&["mono_us"]).as_usize().unwrap_or(0) as u64,
        dur_us: j.at(&["dur_us"]).as_usize().unwrap_or(0) as u64,
    })
}

// ---------------------------------------------------------------------------
// Shard assignment (coordinator → worker)
// ---------------------------------------------------------------------------

/// One leased unit of fleet work: blocks `lo..hi` of `spec`, plus the
/// predecessor's exit hiddens when the job runs staged calibration and
/// this is not the first shard.
pub struct ShardAssignment {
    pub job: u64,
    /// Shard index within the job's plan (also the lease identity —
    /// results are accepted by `(job, shard)`).
    pub shard: usize,
    /// The job's correlation ID; the worker executes under it so its
    /// spans join the coordinator's trace tree.
    pub corr: String,
    pub lo: usize,
    pub hi: usize,
    /// The *job's* total block count (the worker's final `advance` is
    /// skipped only when `hi == n_blocks`).
    pub n_blocks: usize,
    pub spec: JobSpec,
    /// Staged entry hiddens; `None` for dense shards and for shard 0
    /// (which embeds the prefix locally, same as single-node).
    pub entry: Option<EmbedPrefix>,
}

pub(crate) fn assignment_to_json(a: &ShardAssignment) -> Json {
    let mut fields = vec![
        ("job", Json::from(a.job as usize)),
        ("shard", Json::from(a.shard)),
        ("corr", Json::from(a.corr.as_str())),
        ("lo", Json::from(a.lo)),
        ("hi", Json::from(a.hi)),
        ("n_blocks", Json::from(a.n_blocks)),
        ("spec", a.spec.to_json()),
    ];
    if let Some(p) = &a.entry {
        fields.push(("entry", handoff_to_json(p)));
    }
    Json::obj(fields)
}

pub(crate) fn assignment_from_json(j: &Json) -> Result<ShardAssignment> {
    let entry = match j.get("entry") {
        Some(e) => Some(handoff_from_json(e)?),
        None => None,
    };
    Ok(ShardAssignment {
        job: j.at(&["job"]).as_usize().context("assignment missing `job`")? as u64,
        shard: j.at(&["shard"]).as_usize().context("assignment missing `shard`")?,
        corr: j.at(&["corr"]).as_str().unwrap_or_default().to_string(),
        lo: j.at(&["lo"]).as_usize().context("assignment missing `lo`")?,
        hi: j.at(&["hi"]).as_usize().context("assignment missing `hi`")?,
        n_blocks: j.at(&["n_blocks"]).as_usize().context("assignment missing `n_blocks`")?,
        spec: JobSpec::from_json(j.at(&["spec"])).context("assignment spec")?,
        entry,
    })
}

// ---------------------------------------------------------------------------
// Shard result (worker → coordinator)
// ---------------------------------------------------------------------------

/// What a worker reports back for one leased shard.
pub struct ShardResult {
    pub worker: u64,
    pub job: u64,
    pub shard: usize,
    pub ok: bool,
    pub error: Option<String>,
    /// Digest of the activations the shard started from — the
    /// coordinator cross-checks it against the digest of what it
    /// dispatched, closing the loop on the staged hand-off.
    pub entry_digest: u64,
    /// The shard's pruned layers, model order (journal codec: exact
    /// mask bits + f32 weight bit patterns).
    pub layers: Vec<LayerCheckpoint>,
    /// Exit hiddens for the successor shard (staged, `hi < n_blocks`).
    pub exit: Option<EmbedPrefix>,
    /// Worker-side trace spans captured during execution.
    pub spans: Vec<TraceEvent>,
}

pub(crate) fn result_to_json(r: &ShardResult) -> Json {
    let mut fields = vec![
        ("worker", Json::from(r.worker as usize)),
        ("job", Json::from(r.job as usize)),
        ("shard", Json::from(r.shard)),
        ("ok", Json::from(r.ok)),
        ("entry_digest", Json::from(u64_hex(r.entry_digest))),
        ("layers", Json::Arr(r.layers.iter().map(LayerCheckpoint::to_json).collect())),
        ("spans", Json::Arr(r.spans.iter().map(span_to_json).collect())),
    ];
    if let Some(e) = &r.error {
        fields.push(("error", Json::from(e.as_str())));
    }
    if let Some(p) = &r.exit {
        fields.push(("exit", handoff_to_json(p)));
    }
    Json::obj(fields)
}

pub(crate) fn result_from_json(j: &Json) -> Result<ShardResult> {
    let layers: Vec<LayerCheckpoint> = match j.at(&["layers"]).as_arr() {
        Some(a) => a.iter().map(LayerCheckpoint::from_json).collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let spans: Vec<TraceEvent> = match j.at(&["spans"]).as_arr() {
        Some(a) => a.iter().map(span_from_json).collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let exit = match j.get("exit") {
        Some(e) => Some(handoff_from_json(e)?),
        None => None,
    };
    Ok(ShardResult {
        worker: j.at(&["worker"]).as_usize().unwrap_or(0) as u64,
        job: j.at(&["job"]).as_usize().context("shard result missing `job`")? as u64,
        shard: j.at(&["shard"]).as_usize().context("shard result missing `shard`")?,
        ok: j.at(&["ok"]).as_bool().unwrap_or(false),
        error: j.at(&["error"]).as_str().map(str::to_string),
        entry_digest: parse_hex_u64(j.at(&["entry_digest"]).as_str().unwrap_or("0"))?,
        layers,
        exit,
        spans,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, seed: f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for (i, x) in m.data.iter_mut().enumerate() {
            // non-trivial bit patterns, including subnormals and exact
            // decimals that would not survive a decimal float round-trip
            *x = (seed + i as f32 * 0.3).sin() * 1e-3 + f32::MIN_POSITIVE * i as f32;
        }
        m
    }

    #[test]
    fn handoff_roundtrip_is_bit_exact() {
        let p = EmbedPrefix::from_parts(vec![mat(4, 6, 0.1), mat(4, 6, 2.7)], 4);
        let d = p.digest();
        let j = handoff_to_json(&p);
        // through a full text round-trip, like the real wire
        let text = crate::util::json::to_string(&j);
        let back = handoff_from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.digest(), d);
        for (a, b) in p.hiddens().iter().zip(back.hiddens()) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn handoff_decoder_rejects_corruption() {
        let p = EmbedPrefix::from_parts(vec![mat(3, 3, 1.0)], 3);
        // tamper with the claimed digest: the decoder must refuse
        let mut j = handoff_to_json(&p);
        if let Json::Obj(m) = &mut j {
            m.insert("digest".into(), Json::from(u64_hex(0xdeadbeef)));
        }
        let err = handoff_from_json(&j).unwrap_err();
        assert!(format!("{err:#}").contains("digest mismatch"), "{err:#}");
        // and a truncated payload fails the shape check
        let mut j = handoff_to_json(&p);
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Arr(hs)) = m.get_mut("hiddens") {
                if let Some(Json::Obj(h0)) = hs.first_mut() {
                    let short = f32s_to_hex(&[1.0f32; 3]);
                    h0.insert("data_hex".into(), Json::from(short));
                }
            }
        }
        assert!(handoff_from_json(&j).is_err());
    }

    #[test]
    fn assignment_roundtrip() {
        let a = ShardAssignment {
            job: 12,
            shard: 2,
            corr: "c-abc".into(),
            lo: 4,
            hi: 8,
            n_blocks: 12,
            spec: JobSpec::default(),
            entry: Some(EmbedPrefix::from_parts(vec![mat(2, 4, 0.5)], 2)),
        };
        let text = crate::util::json::to_string(&assignment_to_json(&a));
        let b = assignment_from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!((b.job, b.shard, b.lo, b.hi, b.n_blocks), (12, 2, 4, 8, 12));
        assert_eq!(b.corr, "c-abc");
        assert_eq!(b.spec.model, a.spec.model);
        assert_eq!(b.entry.unwrap().digest(), a.entry.unwrap().digest());
    }

    #[test]
    fn span_names_intern_to_statics() {
        let ev = TraceEvent {
            span_id: 7,
            parent_id: 3,
            corr_id: None,
            name: "fw",
            fields: vec![("layer", "blocks.0.wo".into())],
            wall_ms: 1,
            mono_us: 2,
            dur_us: 3,
        };
        let back = span_from_json(&span_to_json(&ev)).unwrap();
        assert_eq!(back.name, "fw");
        assert_eq!((back.span_id, back.parent_id, back.dur_us), (7, 3, 3));
        // unknown names land as "remote", not an error
        let j = Json::obj(vec![
            ("span", Json::from(u64_hex(1))),
            ("parent", Json::from(u64_hex(0))),
            ("name", Json::from("mystery")),
            ("wall_ms", Json::from(0usize)),
            ("mono_us", Json::from(0usize)),
            ("dur_us", Json::from(0usize)),
        ]);
        assert_eq!(span_from_json(&j).unwrap().name, "remote");
    }
}
