//! Distributed pruning fleet: shard one job across N workers.
//!
//! The layer-wise FW objective is block-decomposable, so a pruning job
//! splits naturally at transformer-block granularity.  This module
//! turns that observation into a coordinator/worker topology layered
//! on the existing HTTP/JSON server — no new transport, no new job
//! API:
//!
//! ```text
//!   client ── POST /jobs ──▶ coordinator (sparsefw serve --coordinator)
//!                               │  plan_shards: contiguous block ranges
//!                               │  pull-based LPT dispatch + heartbeats
//!              ┌────────────────┼─────────────────┐
//!              ▼                ▼                  ▼
//!          worker 0         worker 1     …    worker N-1
//!        (serve --worker, PruneSession::execute_shard)
//!              │   staged hand-off: exit hiddens of shard i are
//!              └──▶ the entry of shard i+1 (EmbedPrefix, digest-checked)
//! ```
//!
//! Submodules:
//! - [`wire`] — JSON codecs for assignments, results, hidden-state
//!   hand-offs, and trace spans (all symmetric reader/writer pairs).
//! - [`coordinator`] — shard table, worker registry, reaping/requeue,
//!   and the dispatcher thread that assembles shard results into a
//!   [`JobResult`](crate::coordinator::JobResult) bit-identical to a
//!   single-node run.
//! - [`worker`] — the poll–execute–report loop.

pub mod coordinator;
pub mod wire;
pub mod worker;

pub use coordinator::{FleetState, MAX_SHARD_ATTEMPTS};
pub use worker::{run_worker, WorkerOptions};
