//! Job queue + registry for the pruning server.
//!
//! A [`JobQueue`] owns both the bounded pending queue (priority, then
//! FIFO) and the registry of every job the server has seen.  Worker
//! threads block on [`JobQueue::pop_blocking`]; submitters, watchers and
//! the API read consistent [`JobRecord`] snapshots under one mutex.
//!
//! State machine: `Queued → Running → Done | Failed`, with `Queued →
//! Cancelled` via [`JobQueue::cancel`] (a running layer sweep is never
//! interrupted — cancellation is only honoured while a job is still in
//! the pending queue, so a cancelled job is guaranteed to never run).
//! [`JobQueue::shutdown`] stops intake; in-flight jobs always complete,
//! and queued jobs either drain or are cancelled en masse.
//!
//! Every lock acquisition goes through
//! [`crate::util::sync::lock_recover`]: a worker panic (contained by
//! the server's `catch_unwind`, reported as a `Failed` job) must never
//! poison this registry into 500-ing all subsequent requests.  Under
//! the `debug-invariants` feature the state machine above is asserted
//! at runtime on every transition.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::{JobResult, JobSpec, LayerEvent};
use crate::pruner::ConvergenceTrace;
use crate::util::json::Json;
use crate::util::sync::{lock_recover, wait_recover, wait_timeout_recover};

pub type JobId = u64;

// ---------------------------------------------------------------------------
// Job state + records
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }

    pub fn label(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

impl fmt::Display for JobState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// What the API reports for a finished job: the scalar outcome of a
/// [`JobResult`] (masks stay server-side — they are model-sized).
#[derive(Clone, Debug)]
pub struct JobSummary {
    pub layer_objs: BTreeMap<String, f64>,
    pub mean_rel_reduction: Option<f64>,
    pub wall_seconds: f64,
    pub total_err: f64,
    pub mask_layers: usize,
    /// Σ nonzeros across all masks — "the masks are non-empty" in one number.
    pub mask_nnz: usize,
    /// Σ FW iterations across layers (0 for greedy/one-shot methods).
    pub fw_iters: usize,
    /// Σ objective improvement from refine post-passes (`--refine`);
    /// `None` when the job ran no refinement.
    pub refine_obj_delta: Option<f64>,
    pub pruned_sparsity: Option<f64>,
    pub ppl: Option<f64>,
    /// Propagation granularity label (`"block"`/`"layer"`) when the
    /// job ran staged calibration; `None` for one-shot dense.
    pub calib_policy: Option<String>,
    /// Peak bytes of simultaneously-live calibration grams (staged
    /// jobs; the one-shot path holds every gram at once instead).
    pub peak_gram_bytes: Option<usize>,
    /// Per-layer FW convergence certificates, recorded when the job
    /// traced (`trace_every > 0`); empty — and absent from the JSON
    /// form — otherwise.
    pub convergence: BTreeMap<String, ConvergenceTrace>,
    /// Order-independent digest over every pruned mask (hex) — the
    /// bit-identity certificate the crash-recovery tests compare
    /// between an uninterrupted run and a kill-and-resume run.
    pub mask_digest: String,
    /// Units restored from verified checkpoints rather than recomputed
    /// (0 for a fresh, uninterrupted run; absent from the JSON then).
    pub resumed_units: usize,
}

impl JobSummary {
    pub fn from_result(res: &JobResult) -> Self {
        Self {
            layer_objs: res.prune.layer_objs.clone(),
            mean_rel_reduction: res.mean_rel_reduction(),
            wall_seconds: res.wall_seconds(),
            total_err: res.total_err(),
            mask_layers: res.masks().len(),
            mask_nnz: res.masks().values().map(|m| m.count_nonzero()).sum(),
            fw_iters: res.prune.fw_iters,
            refine_obj_delta: res.prune.refine_obj_delta,
            pruned_sparsity: res.pruned_sparsity,
            ppl: res.eval.as_ref().map(|e| e.ppl),
            calib_policy: res.prune.staged.map(|s| s.policy.label().to_string()),
            peak_gram_bytes: res.prune.staged.map(|s| s.peak_gram_bytes),
            convergence: res.prune.convergence.clone(),
            mask_digest: format!("{:016x}", super::journal::mask_digest(res.masks())),
            resumed_units: res.prune.resumed_units,
        }
    }

    /// FW iterations per wall second of this job (None for jobs that
    /// ran no FW iterations).
    pub fn iters_per_sec(&self) -> Option<f64> {
        (self.fw_iters > 0 && self.wall_seconds > 0.0)
            .then(|| self.fw_iters as f64 / self.wall_seconds)
    }

    pub fn to_json(&self) -> Json {
        let objs = self
            .layer_objs
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v)))
            .collect();
        let mut fields = vec![
            ("layer_objs", Json::Obj(objs)),
            ("total_err", self.total_err.into()),
            ("wall_seconds", self.wall_seconds.into()),
            ("mask_layers", self.mask_layers.into()),
            ("mask_nnz", self.mask_nnz.into()),
            ("fw_iters", self.fw_iters.into()),
        ];
        if let Some(ips) = self.iters_per_sec() {
            fields.push(("iters_per_sec", ips.into()));
        }
        if let Some(d) = self.refine_obj_delta {
            fields.push(("refine_obj_delta", d.into()));
        }
        if let Some(r) = self.mean_rel_reduction {
            fields.push(("mean_rel_reduction", r.into()));
        }
        if let Some(s) = self.pruned_sparsity {
            fields.push(("pruned_sparsity", s.into()));
        }
        if let Some(p) = self.ppl {
            fields.push(("ppl", p.into()));
        }
        if let Some(cp) = &self.calib_policy {
            fields.push(("calib_policy", cp.as_str().into()));
        }
        if let Some(b) = self.peak_gram_bytes {
            fields.push(("peak_gram_bytes", b.into()));
        }
        if !self.convergence.is_empty() {
            let conv = self
                .convergence
                .iter()
                .map(|(k, cv)| (k.clone(), cv.to_json()))
                .collect();
            fields.push(("convergence", Json::Obj(conv)));
        }
        fields.push(("mask_digest", self.mask_digest.as_str().into()));
        if self.resumed_units > 0 {
            fields.push(("resumed_units", self.resumed_units.into()));
        }
        Json::obj(fields)
    }
}

/// Everything known about one submitted job (snapshot-cloneable).
#[derive(Clone)]
pub struct JobRecord {
    pub id: JobId,
    pub spec: JobSpec,
    /// Correlation ID linking this job's trace spans, log lines and
    /// NDJSON records (client-supplied `X-Sparsefw-Corr-Id`, or minted
    /// at submit time).
    pub corr_id: String,
    pub priority: i64,
    pub state: JobState,
    pub submitted: Instant,
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
    pub worker: Option<usize>,
    /// Per-layer progress, in completion order.
    pub events: Vec<LayerEvent>,
    pub summary: Option<JobSummary>,
    pub error: Option<String>,
    /// Key into the pending queue while `Queued`.
    pending_key: Option<(i64, u64)>,
}

impl JobRecord {
    /// Seconds spent waiting in the queue (so far, if still queued).
    pub fn queued_secs(&self) -> f64 {
        match self.started {
            Some(t) => (t - self.submitted).as_secs_f64(),
            None => match self.finished {
                // cancelled while queued
                Some(t) => (t - self.submitted).as_secs_f64(),
                None => self.submitted.elapsed().as_secs_f64(),
            },
        }
    }

    /// Seconds spent running (so far, if still running).
    pub fn run_secs(&self) -> Option<f64> {
        let start = self.started?;
        Some(match self.finished {
            Some(t) => (t - start).as_secs_f64(),
            None => start.elapsed().as_secs_f64(),
        })
    }
}

/// One row of a job listing (see [`JobQueue::briefs`]).
#[derive(Clone, Debug)]
pub struct JobBrief {
    pub id: JobId,
    pub state: JobState,
    pub priority: i64,
    pub label: String,
    /// Layers completed so far.
    pub completed: usize,
    /// Total layers (0 until the first event arrives).
    pub total: usize,
}

/// Why [`JobQueue::cancel`] refused.
#[derive(Debug, PartialEq, Eq)]
pub enum CancelError {
    Unknown,
    /// The job already left the queue; its current state is attached.
    NotCancellable(JobState),
}

impl fmt::Display for CancelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CancelError::Unknown => write!(f, "unknown job"),
            CancelError::NotCancellable(s) => write!(f, "job is {s}, not cancellable"),
        }
    }
}

impl std::error::Error for CancelError {}

/// The listing row of one record (shared by [`JobQueue::briefs`] and
/// [`JobQueue::briefs_page`]).
fn brief_of(rec: &JobRecord) -> JobBrief {
    JobBrief {
        id: rec.id,
        state: rec.state,
        priority: rec.priority,
        label: rec.spec.label(),
        completed: rec.events.len(),
        total: rec.events.last().map(|e| e.total).unwrap_or(0),
    }
}

// ---------------------------------------------------------------------------
// Queue
// ---------------------------------------------------------------------------

struct Inner {
    next_id: JobId,
    seq: u64,
    /// `(-priority, submission seq) → id`: BTreeMap iteration order is
    /// highest priority first, FIFO within a priority.
    pending: BTreeMap<(i64, u64), JobId>,
    jobs: BTreeMap<JobId, JobRecord>,
    shutdown: bool,
}

/// Default bound on retained *terminal* job records (see
/// [`JobQueue::with_history_cap`]).
pub const DEFAULT_HISTORY_CAP: usize = 1024;

/// Bounded priority-FIFO queue + job registry (see module docs).
pub struct JobQueue {
    inner: Mutex<Inner>,
    /// Workers waiting for work.
    take: Condvar,
    /// Watchers waiting for job updates (events / state changes).
    update: Condvar,
    capacity: usize,
    history_cap: usize,
}

impl JobQueue {
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                next_id: 1,
                seq: 0,
                pending: BTreeMap::new(),
                jobs: BTreeMap::new(),
                shutdown: false,
            }),
            take: Condvar::new(),
            update: Condvar::new(),
            capacity: capacity.max(1),
            history_cap: DEFAULT_HISTORY_CAP,
        }
    }

    /// Bound the registry: once more than `cap` *terminal* records are
    /// retained, the oldest are dropped (their ids then 404).  Queued
    /// and running jobs are never evicted.  A long-lived server would
    /// otherwise grow one spec + event list + summary per job forever.
    pub fn with_history_cap(mut self, cap: usize) -> Self {
        self.history_cap = cap.max(1);
        self
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Drop the oldest terminal records beyond `history_cap` (ids are
    /// monotonic, so ascending id order is submission order).
    fn prune_history(&self, inner: &mut Inner) {
        let terminal: Vec<JobId> = inner
            .jobs
            .iter()
            .filter(|(_, r)| r.state.is_terminal())
            .map(|(&id, _)| id)
            .collect();
        let excess = terminal.len().saturating_sub(self.history_cap);
        for id in terminal.iter().take(excess) {
            inner.jobs.remove(id);
        }
    }

    /// Enqueue a job with a freshly minted correlation ID.  Fails when
    /// the pending queue is full or the server is shutting down.
    /// Higher `priority` runs first; equal priorities are FIFO.
    pub fn submit(&self, spec: JobSpec, priority: i64) -> Result<JobId> {
        self.submit_with_corr(spec, priority, crate::util::telemetry::gen_corr_id())
    }

    /// [`JobQueue::submit`] with a caller-supplied correlation ID (the
    /// API propagates the client's `X-Sparsefw-Corr-Id` header here).
    pub fn submit_with_corr(&self, spec: JobSpec, priority: i64, corr_id: String) -> Result<JobId> {
        let mut inner = lock_recover(&self.inner);
        if inner.shutdown {
            bail!("server is shutting down; not accepting jobs");
        }
        if inner.pending.len() >= self.capacity {
            bail!("queue full ({} pending)", self.capacity);
        }
        let id = inner.next_id;
        inner.next_id += 1;
        inner.seq += 1;
        let key = (-priority, inner.seq);
        inner.pending.insert(key, id);
        inner.jobs.insert(
            id,
            JobRecord {
                id,
                spec,
                corr_id,
                priority,
                state: JobState::Queued,
                submitted: Instant::now(),
                started: None,
                finished: None,
                worker: None,
                events: Vec::new(),
                summary: None,
                error: None,
                pending_key: Some(key),
            },
        );
        drop(inner);
        self.take.notify_one();
        self.update.notify_all();
        Ok(id)
    }

    /// Re-register a job replayed from the durable journal, `Queued`
    /// under its original id, priority and correlation ID — clients
    /// polling a job handle across a server restart keep it.  `next_id`
    /// advances past replayed ids so fresh submissions never collide;
    /// an id already present (double replay) is ignored.  Restores
    /// bypass the capacity bound: the jobs were already accepted.
    pub fn restore(&self, id: JobId, spec: JobSpec, priority: i64, corr_id: &str) {
        let mut inner = lock_recover(&self.inner);
        if inner.shutdown || inner.jobs.contains_key(&id) {
            return;
        }
        inner.seq += 1;
        inner.next_id = inner.next_id.max(id + 1);
        let key = (-priority, inner.seq);
        inner.pending.insert(key, id);
        inner.jobs.insert(
            id,
            JobRecord {
                id,
                spec,
                corr_id: corr_id.to_string(),
                priority,
                state: JobState::Queued,
                submitted: Instant::now(),
                started: None,
                finished: None,
                worker: None,
                events: Vec::new(),
                summary: None,
                error: None,
                pending_key: Some(key),
            },
        );
        drop(inner);
        self.take.notify_one();
        self.update.notify_all();
    }

    /// Block until a job is available (returning it marked `Running` and
    /// owned by `worker`) or the queue shuts down with nothing left to
    /// drain (`None` — the worker should exit).
    pub fn pop_blocking(&self, worker: usize) -> Option<(JobId, JobSpec)> {
        let mut inner = lock_recover(&self.inner);
        loop {
            let head = inner.pending.iter().next().map(|(&k, &v)| (k, v));
            if let Some((key, id)) = head {
                inner.pending.remove(&key);
                // a pending entry always has a registered record; if
                // that invariant ever breaks, skip the orphan entry
                // rather than panicking under the queue lock
                let Some(rec) = inner.jobs.get_mut(&id) else { continue };
                #[cfg(feature = "debug-invariants")]
                assert_eq!(
                    rec.state,
                    JobState::Queued,
                    "queue invariant: popped job {id} must be Queued, was {}",
                    rec.state
                );
                rec.state = JobState::Running;
                rec.started = Some(Instant::now());
                rec.worker = Some(worker);
                rec.pending_key = None;
                let spec = rec.spec.clone();
                drop(inner);
                self.update.notify_all();
                return Some((id, spec));
            }
            if inner.shutdown {
                return None;
            }
            inner = wait_recover(&self.take, inner);
        }
    }

    /// Append a progress event to a running job.
    pub fn push_event(&self, id: JobId, event: LayerEvent) {
        let mut inner = lock_recover(&self.inner);
        if let Some(rec) = inner.jobs.get_mut(&id) {
            if rec.state == JobState::Running {
                rec.events.push(event);
            }
        }
        drop(inner);
        self.update.notify_all();
    }

    /// Mark a running job finished (`Done` with a summary, or `Failed`).
    pub fn finish(&self, id: JobId, outcome: Result<JobSummary, String>) {
        let mut inner = lock_recover(&self.inner);
        if let Some(rec) = inner.jobs.get_mut(&id) {
            #[cfg(feature = "debug-invariants")]
            assert_eq!(
                rec.state,
                JobState::Running,
                "queue invariant: finish() on job {id} requires Running, was {}",
                rec.state
            );
            rec.finished = Some(Instant::now());
            match outcome {
                Ok(summary) => {
                    rec.state = JobState::Done;
                    rec.summary = Some(summary);
                }
                Err(msg) => {
                    rec.state = JobState::Failed;
                    rec.error = Some(msg);
                }
            }
        }
        self.prune_history(&mut inner);
        drop(inner);
        self.update.notify_all();
    }

    /// Cancel a *queued* job: it is removed from the pending queue under
    /// the same lock `pop_blocking` uses, so it can never start.
    pub fn cancel(&self, id: JobId) -> Result<(), CancelError> {
        let mut inner = lock_recover(&self.inner);
        let Some(rec) = inner.jobs.get_mut(&id) else {
            return Err(CancelError::Unknown);
        };
        if rec.state != JobState::Queued {
            return Err(CancelError::NotCancellable(rec.state));
        }
        rec.state = JobState::Cancelled;
        rec.finished = Some(Instant::now());
        if let Some(key) = rec.pending_key.take() {
            inner.pending.remove(&key);
        }
        self.prune_history(&mut inner);
        drop(inner);
        self.update.notify_all();
        Ok(())
    }

    /// Stop accepting jobs and wake every worker.  In-flight jobs always
    /// run to completion; with `drain_queued` the pending backlog is
    /// still executed, otherwise it is cancelled wholesale.
    pub fn shutdown(&self, drain_queued: bool) {
        let mut inner = lock_recover(&self.inner);
        inner.shutdown = true;
        if !drain_queued {
            let ids: Vec<JobId> = inner.pending.values().copied().collect();
            inner.pending.clear();
            for id in ids {
                if let Some(rec) = inner.jobs.get_mut(&id) {
                    rec.state = JobState::Cancelled;
                    rec.finished = Some(Instant::now());
                    rec.pending_key = None;
                }
            }
            self.prune_history(&mut inner);
        }
        drop(inner);
        self.take.notify_all();
        self.update.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        lock_recover(&self.inner).shutdown
    }

    /// Snapshot of one job.
    pub fn get(&self, id: JobId) -> Option<JobRecord> {
        lock_recover(&self.inner).jobs.get(&id).cloned()
    }

    /// Snapshot of every job, in submission order.  Deep-clones records
    /// (events and summaries included) — prefer [`JobQueue::briefs`]
    /// for listings.
    pub fn list(&self) -> Vec<JobRecord> {
        lock_recover(&self.inner).jobs.values().cloned().collect()
    }

    /// Lightweight listing rows, in submission order, without cloning
    /// event vectors or summaries under the lock.
    pub fn briefs(&self) -> Vec<JobBrief> {
        lock_recover(&self.inner).jobs.values().map(brief_of).collect()
    }

    /// One page of listing rows: jobs with `id > after` in ascending id
    /// (= submission) order, at most `limit`.  Returns the rows plus the
    /// cursor to pass as the next `after`; `None` means this page
    /// reached the end of the registry.
    pub fn briefs_page(&self, after: Option<JobId>, limit: usize) -> (Vec<JobBrief>, Option<JobId>) {
        let limit = limit.max(1);
        let start = after.map(|a| a.saturating_add(1)).unwrap_or(0);
        let inner = lock_recover(&self.inner);
        let mut rows = Vec::new();
        let mut more = false;
        for rec in inner.jobs.range(start..).map(|(_, r)| r) {
            if rows.len() == limit {
                more = true;
                break;
            }
            rows.push(brief_of(rec));
        }
        let next = if more { rows.last().map(|r| r.id) } else { None };
        (rows, next)
    }

    /// Jobs waiting in the pending queue.
    pub fn depth(&self) -> usize {
        lock_recover(&self.inner).pending.len()
    }

    /// `(queued, running, done, failed, cancelled)` counts.
    pub fn state_counts(&self) -> (usize, usize, usize, usize, usize) {
        let inner = lock_recover(&self.inner);
        let mut c = (0, 0, 0, 0, 0);
        for rec in inner.jobs.values() {
            match rec.state {
                JobState::Queued => c.0 += 1,
                JobState::Running => c.1 += 1,
                JobState::Done => c.2 += 1,
                JobState::Failed => c.3 += 1,
                JobState::Cancelled => c.4 += 1,
            }
        }
        c
    }

    /// Block until job `id` has more than `events_seen` events, reaches
    /// a terminal state, or `timeout` elapses; returns a fresh snapshot
    /// either way (`None` only for an unknown id).
    pub fn wait_update(
        &self,
        id: JobId,
        events_seen: usize,
        timeout: Duration,
    ) -> Option<JobRecord> {
        let deadline = Instant::now() + timeout;
        let mut inner = lock_recover(&self.inner);
        loop {
            let rec = inner.jobs.get(&id)?;
            if rec.events.len() > events_seen || rec.state.is_terminal() {
                return Some(rec.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return Some(rec.clone());
            }
            let (guard, _timed_out) =
                wait_timeout_recover(&self.update, inner, deadline - now);
            inner = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn spec(model: &str) -> JobSpec {
        JobSpec { model: model.into(), ..Default::default() }
    }

    #[test]
    fn fifo_within_priority_and_priority_first() {
        let q = JobQueue::new(16);
        let a = q.submit(spec("a"), 0).unwrap();
        let b = q.submit(spec("b"), 0).unwrap();
        let hi = q.submit(spec("hi"), 5).unwrap();
        let c = q.submit(spec("c"), 0).unwrap();
        let order: Vec<JobId> = (0..4).map(|_| q.pop_blocking(0).unwrap().0).collect();
        assert_eq!(order, vec![hi, a, b, c]);
    }

    #[test]
    fn capacity_bounds_pending_only() {
        let q = JobQueue::new(2);
        q.submit(spec("a"), 0).unwrap();
        q.submit(spec("b"), 0).unwrap();
        assert!(q.submit(spec("c"), 0).is_err(), "queue must be full");
        // popping one frees a slot (running jobs don't count)
        let (id, _) = q.pop_blocking(0).unwrap();
        q.submit(spec("c"), 0).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.get(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn cancel_queued_never_runs_and_running_is_refused() {
        let q = JobQueue::new(16);
        let a = q.submit(spec("a"), 0).unwrap();
        let b = q.submit(spec("b"), 0).unwrap();
        q.cancel(b).unwrap();
        assert_eq!(q.get(b).unwrap().state, JobState::Cancelled);
        let (popped, _) = q.pop_blocking(0).unwrap();
        assert_eq!(popped, a);
        assert_eq!(
            q.cancel(a).unwrap_err(),
            CancelError::NotCancellable(JobState::Running)
        );
        assert_eq!(q.cancel(999).unwrap_err(), CancelError::Unknown);
        // b was removed from pending: queue is now empty
        q.shutdown(true);
        assert!(q.pop_blocking(0).is_none());
    }

    #[test]
    fn finish_and_fail_are_recorded() {
        let q = JobQueue::new(4);
        let a = q.submit(spec("a"), 0).unwrap();
        let b = q.submit(spec("b"), 0).unwrap();
        q.pop_blocking(0).unwrap();
        q.pop_blocking(1).unwrap();
        q.finish(
            a,
            Ok(JobSummary {
                layer_objs: BTreeMap::new(),
                mean_rel_reduction: None,
                wall_seconds: 0.5,
                total_err: 1.0,
                mask_layers: 8,
                mask_nnz: 100,
                fw_iters: 4000,
                refine_obj_delta: None,
                pruned_sparsity: None,
                ppl: None,
                calib_policy: None,
                peak_gram_bytes: None,
                convergence: BTreeMap::new(),
                mask_digest: "0000000000000000".into(),
                resumed_units: 0,
            }),
        );
        q.finish(b, Err("boom".into()));
        let ra = q.get(a).unwrap();
        assert_eq!(ra.state, JobState::Done);
        assert_eq!(ra.summary.as_ref().unwrap().mask_layers, 8);
        assert!(ra.run_secs().unwrap() >= 0.0);
        let rb = q.get(b).unwrap();
        assert_eq!(rb.state, JobState::Failed);
        assert_eq!(rb.error.as_deref(), Some("boom"));
        assert_eq!(q.state_counts(), (0, 0, 1, 1, 0));
    }

    #[test]
    fn correlation_ids_are_minted_and_preserved() {
        let q = JobQueue::new(4);
        let a = q.submit(spec("a"), 0).unwrap();
        let b = q.submit_with_corr(spec("b"), 0, "corr-fixed".into()).unwrap();
        let ra = q.get(a).unwrap();
        assert!(!ra.corr_id.is_empty(), "submit must mint a corr id");
        assert_eq!(q.get(b).unwrap().corr_id, "corr-fixed");
        assert_ne!(ra.corr_id, "corr-fixed");
    }

    #[test]
    fn shutdown_without_drain_cancels_pending() {
        let q = JobQueue::new(16);
        let a = q.submit(spec("a"), 0).unwrap();
        let b = q.submit(spec("b"), 0).unwrap();
        let (running, _) = q.pop_blocking(0).unwrap();
        assert_eq!(running, a);
        q.shutdown(false);
        assert!(q.submit(spec("late"), 0).is_err());
        assert_eq!(q.get(b).unwrap().state, JobState::Cancelled);
        assert!(q.pop_blocking(1).is_none());
        // the in-flight job still finishes normally
        q.finish(a, Err("whatever".into()));
        assert_eq!(q.get(a).unwrap().state, JobState::Failed);
    }

    #[test]
    fn shutdown_with_drain_hands_out_backlog() {
        let q = JobQueue::new(16);
        q.submit(spec("a"), 0).unwrap();
        q.submit(spec("b"), 0).unwrap();
        q.shutdown(true);
        assert!(q.pop_blocking(0).is_some());
        assert!(q.pop_blocking(0).is_some());
        assert!(q.pop_blocking(0).is_none());
    }

    #[test]
    fn history_cap_evicts_oldest_terminal_records() {
        let q = JobQueue::new(16).with_history_cap(2);
        let ids: Vec<JobId> = (0..5).map(|_| q.submit(spec("m"), 0).unwrap()).collect();
        for &id in &ids[..4] {
            q.pop_blocking(0).unwrap();
            q.finish(id, Err("x".into()));
        }
        // 4 terminal records, cap 2: the two oldest are gone
        assert!(q.get(ids[0]).is_none());
        assert!(q.get(ids[1]).is_none());
        assert_eq!(q.get(ids[2]).unwrap().state, JobState::Failed);
        assert_eq!(q.get(ids[3]).unwrap().state, JobState::Failed);
        // the still-queued job is never evicted
        assert_eq!(q.get(ids[4]).unwrap().state, JobState::Queued);
    }

    #[test]
    fn restore_requeues_with_original_identity() {
        let q = JobQueue::new(4);
        q.restore(7, spec("replayed"), 3, "corr-7");
        q.restore(9, spec("replayed-too"), 0, "corr-9");
        // double replay of a known id is a no-op
        q.restore(7, spec("dup"), 0, "corr-dup");
        let rec = q.get(7).unwrap();
        assert_eq!(rec.state, JobState::Queued);
        assert_eq!(rec.corr_id, "corr-7");
        assert_eq!(rec.priority, 3);
        assert_eq!(rec.spec.model, "replayed");
        // fresh submissions never collide with replayed ids
        let fresh = q.submit(spec("fresh"), 0).unwrap();
        assert!(fresh > 9, "next_id must advance past replayed ids, got {fresh}");
        // priority order still applies across replayed + fresh jobs
        let (first, _) = q.pop_blocking(0).unwrap();
        assert_eq!(first, 7);
        // restored jobs satisfy the pop invariant (Queued → Running)
        assert_eq!(q.get(7).unwrap().state, JobState::Running);
    }

    #[test]
    fn briefs_page_cursors_through_the_registry() {
        let q = JobQueue::new(16);
        let ids: Vec<JobId> = (0..5).map(|_| q.submit(spec("m"), 0).unwrap()).collect();
        let (page1, cur1) = q.briefs_page(None, 2);
        assert_eq!(page1.iter().map(|b| b.id).collect::<Vec<_>>(), &ids[..2]);
        let cur1 = cur1.expect("more pages remain");
        let (page2, cur2) = q.briefs_page(Some(cur1), 2);
        assert_eq!(page2.iter().map(|b| b.id).collect::<Vec<_>>(), &ids[2..4]);
        let (page3, cur3) = q.briefs_page(cur2, 2);
        assert_eq!(page3.iter().map(|b| b.id).collect::<Vec<_>>(), &ids[4..]);
        assert!(cur3.is_none(), "final page carries no cursor");
        // an exhausted cursor yields an empty page
        let (rest, end) = q.briefs_page(Some(ids[4]), 2);
        assert!(rest.is_empty() && end.is_none());
    }

    #[test]
    fn pop_blocks_until_submit() {
        let q = Arc::new(JobQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.pop_blocking(0).map(|(id, _)| id));
        std::thread::sleep(Duration::from_millis(30));
        let id = q.submit(spec("a"), 0).unwrap();
        assert_eq!(t.join().unwrap(), Some(id));
    }

    #[test]
    fn wait_update_sees_events_and_terminal_state() {
        let q = Arc::new(JobQueue::new(4));
        let id = q.submit(spec("a"), 0).unwrap();
        q.pop_blocking(0).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push_event(
                id,
                LayerEvent { layer: "l".into(), index: 0, total: 1, obj: 0.0 },
            );
            std::thread::sleep(Duration::from_millis(20));
            q2.finish(id, Err("x".into()));
        });
        let rec = q.wait_update(id, 0, Duration::from_secs(5)).unwrap();
        assert_eq!(rec.events.len(), 1);
        let rec = q.wait_update(id, 1, Duration::from_secs(5)).unwrap();
        assert!(rec.state.is_terminal());
        t.join().unwrap();
        assert!(q.wait_update(999, 0, Duration::from_millis(1)).is_none());
        // timeout path returns a snapshot too
        let id2 = q.submit(spec("b"), 0).unwrap();
        let rec = q.wait_update(id2, 0, Duration::from_millis(10)).unwrap();
        assert_eq!(rec.state, JobState::Queued);
    }
}
