//! The job server's JSON API (routing + wire formats).
//!
//! Endpoints (all JSON over the [`super::http`] layer):
//!
//! | method | path              | semantics                                    |
//! |--------|-------------------|----------------------------------------------|
//! | POST   | `/jobs`           | submit a [`JobSpec`] (or `{spec, priority}`) |
//! | GET    | `/jobs`           | list jobs (page with `?after=ID&limit=N`)    |
//! | GET    | `/jobs/:id`       | status + per-layer progress + result summary |
//! | GET    | `/jobs/:id/events`| chunked NDJSON live progress stream          |
//! | GET    | `/jobs/:id/trace` | recent trace spans for the job's corr ID     |
//! | POST   | `/jobs/:id/eval`  | perplexity of the job's compiled sparse model|
//! | POST   | `/jobs/:id/generate` | sample tokens from the compiled model     |
//! | DELETE | `/jobs/:id`       | cancel a queued job                          |
//! | GET    | `/methods`        | the method registry: name, caps, defaults    |
//! | GET    | `/healthz`        | liveness + uptime + build info               |
//! | GET    | `/metrics`        | counters/gauges/histograms (JSON; append     |
//! |        |                   | `?format=prometheus` for text exposition)    |
//! | POST   | `/shutdown`       | graceful shutdown (`?drain=1` runs backlog)  |
//! | GET    | `/spec`           | machine-readable API description (routes +  |
//! |        |                   | metric catalog), generated from this file    |
//! | GET    | `/fleet`          | fleet status: workers, shard table, counters |
//! | POST   | `/fleet/workers`  | register a fleet worker (coordinator only)   |
//! | POST   | `/fleet/workers/:id/poll` | heartbeat + lease the next ready shard|
//! | POST   | `/fleet/shards/:id/result` | report a shard's layers / failure   |
//!
//! Auth: with `serve --auth-token` (or `SPARSEFW_AUTH_TOKEN`) every
//! mutating request (POST/DELETE/PUT/PATCH) must carry `Authorization:
//! Bearer <token>`; anything else is refused with `401` +
//! `WWW-Authenticate`.  Read-only GETs stay open so dashboards and
//! health probes keep working.
//!
//! Submitted specs parse through the global
//! [`crate::pruner::MethodRegistry`], so a job naming an unregistered
//! method is rejected with a 400 whose message names the known set.
//!
//! Robustness: `POST /jobs` is token-bucket rate limited per peer IP
//! and sheds queue saturation with `429 Too Many Requests` +
//! `Retry-After` (shutdown refusal stays 503); when the server runs
//! with `--journal`, accepted submissions and terminal transitions are
//! appended to the durable journal before the response goes out.
//!
//! Correlation: `POST /jobs` honours an `X-Sparsefw-Corr-Id` request
//! header (minting an ID when absent); the worker executes the job
//! under that ID, so `GET /jobs/:id/trace` can slice the server's trace
//! ring per job and external log aggregation can join client and
//! server lines.

use std::io::BufReader;
use std::net::{IpAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{JobSpec, LayerEvent};
use crate::util::json::Json;

use super::fleet::{self, wire};
use super::http::{ChunkedWriter, Request, Response};
use super::queue::{CancelError, JobId, JobRecord, JobState};
use super::{CompiledEntry, ServerState};
use crate::util::telemetry::TraceSink as _;

/// How long a streaming connection waits per wakeup before re-checking
/// the stop flag.
const STREAM_TICK: Duration = Duration::from_millis(200);
/// Idle keep-alive connections are dropped after this long.  Kept short
/// so shutdown (whose connection pool joins handlers parked in a read)
/// is never stalled long by an idle peer.
const READ_TIMEOUT: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------------------
// Connection loop
// ---------------------------------------------------------------------------

/// Serve one accepted connection: parse requests in a keep-alive loop,
/// dispatch, and hand `/jobs/:id/events` off to the chunked streamer.
pub(crate) fn handle_connection(stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let peer = stream.peer_addr().ok().map(|a| a.ip());
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;

    loop {
        let req = match Request::read(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return, // clean close between requests
            Err(e) => {
                // silent close on idle timeout; 400 on real parse errors
                let is_timeout = e.downcast_ref::<std::io::Error>().is_some_and(|io| {
                    matches!(
                        io.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::UnexpectedEof
                    )
                });
                if !is_timeout {
                    let _ = Response::error(400, &format!("{e:#}")).write(&mut writer, false);
                }
                return;
            }
        };
        let keep_alive = req.keep_alive();

        // the streaming endpoint owns the connection until the job ends,
        // on its own thread — a stream following a long job must not pin
        // one of the finite connection-pool threads (that would let a
        // handful of streamers starve /healthz and /shutdown)
        let segs: Vec<String> = req.segments().iter().map(|s| s.to_string()).collect();
        let stream_id = match (req.method.as_str(), segs.as_slice()) {
            ("GET", [a, id, c]) if a == "jobs" && c == "events" => Some(id.clone()),
            _ => None,
        };
        if let Some(id) = stream_id {
            let state = state.clone();
            let _ = std::thread::Builder::new()
                .name("sparsefw-stream".into())
                .spawn(move || {
                    let mut writer = writer;
                    stream_job_events(&mut writer, &state, &id);
                });
            return;
        }

        let resp = route(&req, &state, peer);
        if resp.write(&mut writer, keep_alive).is_err() {
            return;
        }
        if !keep_alive || state.stopping() {
            return;
        }
    }
}

fn route(req: &Request, state: &Arc<ServerState>, peer: Option<IpAddr>) -> Response {
    // bearer-token gate on every mutating method; reads stay open
    if let Some(resp) = check_auth(req, state) {
        return resp;
    }
    let segs = req.segments();
    match (req.method.as_str(), segs.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics(req, state),
        ("GET", ["methods"]) => list_methods(),
        ("GET", ["spec"]) => api_spec(),
        ("GET", ["jobs"]) => list_jobs(req, state),
        ("POST", ["jobs"]) => submit_job(req, state, peer),
        ("GET", ["jobs", id]) => job_status(state, id),
        ("GET", ["jobs", id, "trace"]) => job_trace(state, id),
        ("POST", ["jobs", id, "eval"]) => eval_job(req, state, id),
        ("POST", ["jobs", id, "generate"]) => generate_job(req, state, id),
        ("DELETE", ["jobs", id]) => cancel_job(state, id),
        ("GET", ["fleet"]) => fleet_status(state),
        ("POST", ["fleet", "workers"]) => fleet_register(req, state),
        ("POST", ["fleet", "workers", id, "poll"]) => fleet_poll(req, state, id),
        ("POST", ["fleet", "shards", id, "result"]) => fleet_result(req, state, id),
        ("POST", ["shutdown"]) => shutdown(req, state),
        (_, ["jobs", ..]) | (_, ["healthz"]) | (_, ["metrics"]) | (_, ["methods"])
        | (_, ["shutdown"]) | (_, ["spec"]) | (_, ["fleet", ..]) => {
            Response::error(405, &format!("{} not allowed here", req.method))
        }
        _ => Response::error(404, &format!("no route for {}", req.path)),
    }
}

/// `Some(401)` when the server requires a bearer token and this
/// mutating request lacks it (or presents the wrong one).
fn check_auth(req: &Request, state: &ServerState) -> Option<Response> {
    let token = state.auth_token.as_deref()?;
    if !matches!(req.method.as_str(), "POST" | "DELETE" | "PUT" | "PATCH") {
        return None;
    }
    let ok = req
        .headers
        .get("authorization")
        .and_then(|h| h.strip_prefix("Bearer "))
        .is_some_and(|t| t.trim() == token);
    if ok {
        return None;
    }
    Some(
        Response::error(401, "missing or invalid bearer token")
            .with_header("WWW-Authenticate", "Bearer realm=\"sparsefw\""),
    )
}

/// `GET /methods` — the registry listing: every registered method's
/// name, capability flags, and default configuration JSON.  Clients use
/// this to discover what a server can run before submitting.
pub fn methods_json() -> Json {
    let registry = crate::pruner::MethodRegistry::global();
    let methods: Vec<Json> = registry
        .names()
        .iter()
        .filter_map(|name| {
            let m = registry.default(name).ok()?;
            Some(Json::obj(vec![
                ("name", name.as_str().into()),
                ("label", m.label().into()),
                ("caps", m.caps().to_json()),
                ("default_config", crate::config::method_to_json(&m)),
            ]))
        })
        .collect();
    Json::obj(vec![("methods", Json::Arr(methods))])
}

fn list_methods() -> Response {
    Response::json(200, &methods_json())
}

// ---------------------------------------------------------------------------
// Handlers
// ---------------------------------------------------------------------------

fn parse_id(s: &str) -> Option<JobId> {
    s.parse().ok()
}

fn healthz(state: &ServerState) -> Response {
    let mut build = vec![("version", env!("CARGO_PKG_VERSION").into())];
    if let Some(sha) = option_env!("SPARSEFW_GIT_SHA") {
        build.push(("git_sha", sha.into()));
    }
    Response::json(
        200,
        &Json::obj(vec![
            ("ok", true.into()),
            ("status", "ok".into()),
            ("uptime_secs", state.started.elapsed().as_secs_f64().into()),
            ("workers", state.metrics.workers.into()),
            ("build", Json::obj(build)),
        ]),
    )
}

/// `GET /jobs/:id/trace` — the trace-ring slice for the job's
/// correlation ID: every recent span recorded while the job executed
/// (empty until a worker picks the job up, and for jobs old enough to
/// have been evicted from the bounded ring).
fn job_trace(state: &ServerState, id: &str) -> Response {
    let Some(id) = parse_id(id) else {
        return Response::error(400, "job id must be an integer");
    };
    let Some(rec) = state.queue.get(id) else {
        return Response::error(404, &format!("no job {id}"));
    };
    let events: Vec<Json> = state
        .trace_ring
        .events_for(&rec.corr_id)
        .iter()
        .map(|e| e.to_json())
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("id", (rec.id as usize).into()),
            ("corr_id", rec.corr_id.as_str().into()),
            ("count", events.len().into()),
            ("events", Json::Arr(events)),
        ]),
    )
}

fn metrics(req: &Request, state: &ServerState) -> Response {
    use std::sync::atomic::Ordering::Relaxed;
    if req.query.get("format").map(String::as_str) == Some("prometheus") {
        return Response::text(200, &super::render_prometheus(state));
    }
    let m = &state.metrics;
    let (queued, running, done, failed, cancelled) = state.queue.state_counts();
    let v = Json::obj(vec![
        ("uptime_secs", state.started.elapsed().as_secs_f64().into()),
        ("jobs_served", (m.jobs_done.load(Relaxed) + m.jobs_failed.load(Relaxed)).into()),
        (
            "jobs",
            Json::obj(vec![
                ("submitted", m.jobs_submitted.load(Relaxed).into()),
                ("queued", queued.into()),
                ("running", running.into()),
                ("done", done.into()),
                ("failed", failed.into()),
                ("cancelled", cancelled.into()),
            ]),
        ),
        ("queue_depth", state.queue.depth().into()),
        ("queue_capacity", state.queue.capacity().into()),
        (
            "calib_cache",
            Json::obj(vec![
                ("hits", m.calib_hits.load(Relaxed).into()),
                ("misses", m.calib_misses.load(Relaxed).into()),
            ]),
        ),
        // staged block-sequential calibration (`--propagate block|layer`):
        // how many completed jobs propagated, and the worst per-job peak
        // of simultaneously-live gram bytes (O(block), not O(model))
        (
            "calib_staged",
            Json::obj(vec![
                ("jobs_propagated", m.jobs_propagated.load(Relaxed).into()),
                ("peak_gram_bytes", m.peak_gram_bytes.load(Relaxed).into()),
            ]),
        ),
        (
            "workers",
            Json::obj(vec![
                ("total", m.workers.into()),
                ("busy", m.busy_workers.load(Relaxed).into()),
                ("utilization", m.utilization().into()),
            ]),
        ),
        // per-job wall time + FW throughput: the operator-visible
        // number the incremental FW engine moves (`--fw-engine`)
        (
            "timing",
            Json::obj(vec![
                ("job_wall_secs_total", m.job_wall_secs().into()),
                (
                    "mean_job_secs",
                    (m.job_wall_secs() / m.jobs_done.load(Relaxed).max(1) as f64).into(),
                ),
                ("fw_iters_total", m.fw_iters.load(Relaxed).into()),
                ("fw_iters_per_sec", m.fw_iters_per_sec().into()),
            ]),
        ),
        // latency distributions (same data as the Prometheus
        // histograms, summarized as count/sum/p50/p95/p99)
        (
            "latency",
            Json::obj(vec![
                ("queue_wait_seconds", m.queue_wait.to_json()),
                ("job_wall_seconds", m.job_wall.to_json()),
                (
                    "phases",
                    Json::obj(vec![
                        ("calib", m.phase_calib.to_json()),
                        ("gram", m.phase_gram.to_json()),
                        ("fw", m.phase_fw.to_json()),
                        ("refine", m.phase_refine.to_json()),
                        ("io", m.phase_io.to_json()),
                    ]),
                ),
            ]),
        ),
        // sparse inference serving (`POST /jobs/:id/{eval,generate}`):
        // compile-once counter, LRU cache traffic, request latency
        (
            "inference",
            Json::obj(vec![
                ("models_compiled", state.compiled.compiled_total.load(Relaxed).into()),
                ("cache_hits", state.compiled.hits.load(Relaxed).into()),
                ("cache_misses", state.compiled.misses.load(Relaxed).into()),
                ("cached_models", state.compiled.len().into()),
                ("eval_request_seconds", m.infer_eval.to_json()),
                ("generate_request_seconds", m.infer_generate.to_json()),
            ]),
        ),
    ]);
    Response::json(200, &v)
}

fn brief_json(b: &super::queue::JobBrief) -> Json {
    Json::obj(vec![
        ("id", (b.id as usize).into()),
        ("state", b.state.label().into()),
        ("priority", (b.priority as f64).into()),
        ("label", b.label.as_str().into()),
        (
            "progress",
            Json::obj(vec![
                ("completed", b.completed.into()),
                ("total", b.total.into()),
            ]),
        ),
    ])
}

/// `GET /jobs[?after=ID&limit=N]` — the registry listing.  Without
/// query parameters every job is returned (the original shape); with
/// `after`/`limit` the listing pages by cursor: `next_cursor` appears
/// iff more rows remain, and is passed back verbatim as `after`.
fn list_jobs(req: &Request, state: &ServerState) -> Response {
    let paged = req.query.contains_key("after") || req.query.contains_key("limit");
    let mut fields = Vec::new();
    if paged {
        let after = match req.query.get("after") {
            Some(v) => match v.parse::<JobId>() {
                Ok(id) => Some(id),
                Err(_) => return Response::error(400, "after must be a job id"),
            },
            None => None,
        };
        let limit = match req.query.get("limit") {
            Some(v) => match v.parse::<usize>() {
                Ok(n) => n,
                Err(_) => return Response::error(400, "limit must be a positive integer"),
            },
            None => 50,
        };
        let (briefs, next) = state.queue.briefs_page(after, limit);
        fields.push(("jobs", Json::Arr(briefs.iter().map(brief_json).collect())));
        if let Some(cursor) = next {
            fields.push(("next_cursor", (cursor as usize).into()));
        }
    } else {
        let jobs: Vec<Json> = state.queue.briefs().iter().map(brief_json).collect();
        fields.push(("jobs", Json::Arr(jobs)));
    }
    fields.push(("queue_depth", state.queue.depth().into()));
    Response::json(200, &Json::obj(fields))
}

fn submit_job(req: &Request, state: &ServerState, peer: Option<IpAddr>) -> Response {
    // shed abusive submit rates before parsing the body: the token
    // bucket is per peer IP, so one tight submit loop cannot starve
    // other clients (or the queue) of service
    if !state.limiter.allow(peer) {
        state
            .metrics
            .jobs_shed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        return Response::error(429, "submit rate limit exceeded; retry shortly")
            .with_header("Retry-After", "1");
    }
    let body = match req.body_json() {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // accept either a bare JobSpec or a {"spec": …, "priority": N} wrapper
    let (spec_json, priority) = if body.get("spec").is_some() {
        (body.at(&["spec"]).clone(), body.at(&["priority"]).as_f64().unwrap_or(0.0) as i64)
    } else {
        (body.clone(), 0)
    };
    let spec = match JobSpec::from_json(&spec_json) {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("bad job spec: {e:#}")),
    };
    if let Err(e) = super::validate_spec(&spec) {
        return Response::error(400, &format!("bad job spec: {e:#}"));
    }
    // propagate the client's correlation ID (or mint one) so worker-side
    // trace spans and log lines can be joined with the submitting client
    let corr_id = req
        .headers
        .get("x-sparsefw-corr-id")
        .filter(|c| !c.is_empty())
        .cloned()
        .unwrap_or_else(crate::util::telemetry::gen_corr_id);
    match state.queue.submit_with_corr(spec.clone(), priority, corr_id.clone()) {
        Ok(id) => {
            state
                .metrics
                .jobs_submitted
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            // durability: record the accepted job before acknowledging
            // it, so a crash after the 202 still replays the job
            if let Some(j) = &state.journal {
                j.record_submit(id, &corr_id, priority, &spec);
            }
            Response::json(
                202,
                &Json::obj(vec![
                    ("id", (id as usize).into()),
                    ("state", "queued".into()),
                    ("priority", (priority as f64).into()),
                    ("corr_id", corr_id.as_str().into()),
                ]),
            )
        }
        // queue saturation is load shedding, not an error the client
        // can fix: 429 + Retry-After, counted separately from submits
        // (shutdown refusal stays a 503 — retrying won't help there)
        Err(e) => {
            let msg = format!("{e:#}");
            if msg.contains("queue full") {
                state
                    .metrics
                    .jobs_shed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Response::error(429, &msg).with_header("Retry-After", "1")
            } else {
                Response::error(503, &msg)
            }
        }
    }
}

fn job_status(state: &ServerState, id: &str) -> Response {
    let Some(id) = parse_id(id) else {
        return Response::error(400, "job id must be an integer");
    };
    match state.queue.get(id) {
        Some(rec) => Response::json(200, &record_json(&rec)),
        None => Response::error(404, &format!("no job {id}")),
    }
}

// ---------------------------------------------------------------------------
// Inference serving (`POST /jobs/:id/{eval,generate}`)
// ---------------------------------------------------------------------------

/// Default / ceiling for `eval` sequence counts: enough for a stable
/// perplexity estimate without letting one request pin a handler thread.
const DEFAULT_EVAL_SEQS: usize = 8;
const MAX_EVAL_SEQS: usize = 256;
/// Default / ceiling for `generate` continuation length (the model's
/// own `seq_len` cap still applies underneath).
const DEFAULT_MAX_NEW: usize = 16;
const MAX_GENERATE_TOKENS: usize = 1024;

/// Shared preamble for the serving endpoints: the job must exist, be
/// `done`, and still have its compiled model in the LRU cache.
fn serving_entry(state: &ServerState, id: &str) -> Result<(JobId, CompiledEntry), Response> {
    let Some(id) = parse_id(id) else {
        return Err(Response::error(400, "job id must be an integer"));
    };
    let Some(rec) = state.queue.get(id) else {
        return Err(Response::error(404, &format!("no job {id}")));
    };
    if !matches!(rec.state, JobState::Done) {
        return Err(Response::error(
            409,
            &format!(
                "job {id} is {}; inference serves completed jobs only",
                rec.state.label()
            ),
        ));
    }
    match state.compiled.get(id) {
        Some(entry) => Ok((id, entry)),
        None => Err(Response::error(
            404,
            &format!("job {id} has no compiled model cached (evicted?); re-run the job"),
        )),
    }
}

/// Parse the request body as JSON, treating an absent body as `{}` —
/// every serving-endpoint parameter is optional except `prompt`.
fn optional_body(req: &Request) -> Result<Json, Response> {
    if req.body.is_empty() {
        return Ok(Json::obj(Vec::new()));
    }
    req.body_json().map_err(|e| Response::error(400, &format!("{e:#}")))
}

/// `POST /jobs/:id/eval` — perplexity of the job's compiled sparse
/// model over the held-out test bin (body: `{"max_seqs": N}`,
/// optional).  The response carries the packed-format breakdown so
/// clients can see what they are being served.
fn eval_job(req: &Request, state: &ServerState, id: &str) -> Response {
    let (id, entry) = match serving_entry(state, id) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let body = match optional_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let max_seqs = body
        .at(&["max_seqs"])
        .as_usize()
        .unwrap_or(DEFAULT_EVAL_SEQS)
        .clamp(1, MAX_EVAL_SEQS);
    let started = std::time::Instant::now();
    let ppl = match crate::eval::perplexity_native(&*entry.model, &entry.test_bin, max_seqs) {
        Ok(p) => p,
        Err(e) => return Response::error(500, &format!("eval failed: {e:#}")),
    };
    let wall = started.elapsed().as_secs_f64();
    state.metrics.infer_eval.observe(wall);
    let (dense, csr, nm) = entry.model.format_counts();
    Response::json(
        200,
        &Json::obj(vec![
            ("id", (id as usize).into()),
            ("ppl", ppl.into()),
            ("max_seqs", max_seqs.into()),
            (
                "formats",
                Json::obj(vec![
                    ("dense", dense.into()),
                    ("csr", csr.into()),
                    ("nm", nm.into()),
                ]),
            ),
            ("packed_bytes", entry.model.packed_bytes().into()),
            ("dense_equiv_bytes", entry.model.dense_equiv_bytes().into()),
            ("wall_ms", (wall * 1e3).into()),
        ]),
    )
}

/// `POST /jobs/:id/generate` — sample a continuation from the job's
/// compiled model via the KV-cached decode loop (body: `{"prompt":
/// [tokens], "max_new": N, "temperature": T, "seed": S}`; greedy when
/// `temperature <= 0`).
fn generate_job(req: &Request, state: &ServerState, id: &str) -> Response {
    use crate::model::forward::ForwardModel;

    let (id, entry) = match serving_entry(state, id) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let body = match req.body_json() {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let Some(Json::Arr(items)) = body.get("prompt") else {
        return Response::error(400, "generate body needs a \"prompt\" token array");
    };
    let vocab = entry.model.cfg().vocab_size.min(u8::MAX as usize + 1);
    let mut prompt = Vec::with_capacity(items.len());
    for it in items {
        match it.as_usize() {
            Some(t) if t < vocab => prompt.push(t as u8),
            _ => {
                return Response::error(
                    400,
                    &format!("prompt tokens must be integers below vocab size {vocab}"),
                )
            }
        }
    }
    let params = crate::model::compiled::GenerateParams {
        max_new: body
            .at(&["max_new"])
            .as_usize()
            .unwrap_or(DEFAULT_MAX_NEW)
            .min(MAX_GENERATE_TOKENS),
        temperature: body.at(&["temperature"]).as_f64().unwrap_or(0.0),
        seed: body.at(&["seed"]).as_usize().unwrap_or(0) as u64,
    };
    let started = std::time::Instant::now();
    let generated = match entry.model.generate(&prompt, &params) {
        Ok(g) => g,
        // generate's own failures are all input-shape violations
        // (empty/overlong prompt), i.e. client errors
        Err(e) => return Response::error(400, &format!("generate failed: {e:#}")),
    };
    let wall = started.elapsed().as_secs_f64();
    state.metrics.infer_generate.observe(wall);
    let tokens: Vec<Json> = generated.tokens.iter().map(|&t| (t as usize).into()).collect();
    let ms_per_token = if generated.decode_steps > 0 {
        wall * 1e3 / generated.decode_steps as f64
    } else {
        0.0
    };
    Response::json(
        200,
        &Json::obj(vec![
            ("id", (id as usize).into()),
            ("tokens", Json::Arr(tokens)),
            ("prompt_len", generated.prompt_len.into()),
            ("decode_steps", generated.decode_steps.into()),
            ("wall_ms", (wall * 1e3).into()),
            ("ms_per_token", ms_per_token.into()),
        ]),
    )
}

fn cancel_job(state: &ServerState, id: &str) -> Response {
    let Some(id) = parse_id(id) else {
        return Response::error(400, "job id must be an integer");
    };
    match state.queue.cancel(id) {
        Ok(()) => {
            if let Some(j) = &state.journal {
                j.record_state(id, "cancelled");
            }
            Response::json(
                200,
                &Json::obj(vec![("id", (id as usize).into()), ("state", "cancelled".into())]),
            )
        }
        Err(CancelError::Unknown) => Response::error(404, &format!("no job {id}")),
        Err(e @ CancelError::NotCancellable(_)) => Response::error(409, &e.to_string()),
    }
}

fn shutdown(req: &Request, state: &ServerState) -> Response {
    let drain = req.query.get("drain").map(String::as_str) == Some("1");
    crate::info!("shutdown requested (drain_queued={drain})");
    state.begin_shutdown(drain);
    Response::json(
        200,
        &Json::obj(vec![("ok", true.into()), ("draining", drain.into())]),
    )
}

// ---------------------------------------------------------------------------
// API self-description + fleet endpoints
// ---------------------------------------------------------------------------

/// `GET /spec` — a machine-readable description of this server's API,
/// generated from the same route table the `route-coverage` lint reads
/// (this very file) plus the [`super::METRIC_CATALOG`].  A client can
/// diff it against its expectations before submitting anything.
fn api_spec() -> Response {
    static ROUTES: std::sync::OnceLock<Vec<(String, String)>> = std::sync::OnceLock::new();
    let routes = ROUTES
        .get_or_init(|| crate::analyze::consistency::routes_in(include_str!("api.rs")));
    let routes_json: Vec<Json> = routes
        .iter()
        .map(|(m, p)| {
            Json::obj(vec![("method", m.as_str().into()), ("path", p.as_str().into())])
        })
        .collect();
    let metrics: Vec<Json> = super::METRIC_CATALOG
        .iter()
        .map(|&(n, k, h)| {
            Json::obj(vec![("name", n.into()), ("type", k.into()), ("help", h.into())])
        })
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("version", env!("CARGO_PKG_VERSION").into()),
            ("routes", Json::Arr(routes_json)),
            ("metrics", Json::Arr(metrics)),
        ]),
    )
}

fn not_coordinator() -> Response {
    Response::error(
        409,
        "this server is not a fleet coordinator (start it with serve --coordinator)",
    )
}

/// `GET /fleet` — worker registry + active shard table + fleet counters.
fn fleet_status(state: &ServerState) -> Response {
    match &state.fleet {
        Some(f) => Response::json(200, &f.status_json()),
        None => not_coordinator(),
    }
}

/// `POST /fleet/workers` — register a worker process; body
/// `{"label": …}` (optional).  Returns the fleet-unique worker id.
fn fleet_register(req: &Request, state: &ServerState) -> Response {
    let Some(f) = &state.fleet else { return not_coordinator() };
    let body = match optional_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let label = body.at(&["label"]).as_str().unwrap_or("worker").to_string();
    let id = f.register(&label);
    crate::info!("fleet: worker {id} ({label}) registered");
    Response::json(201, &Json::obj(vec![("worker", (id as usize).into())]))
}

/// `POST /fleet/workers/:id/poll` — heartbeat + lease.  Body
/// `{"busy": true}` refreshes the lease without requesting work; the
/// response carries an `assignment` key iff a shard was leased.
fn fleet_poll(req: &Request, state: &ServerState, id: &str) -> Response {
    let Some(f) = &state.fleet else { return not_coordinator() };
    let Ok(worker) = id.parse::<u64>() else {
        return Response::error(400, "worker id must be an integer");
    };
    let body = match optional_body(req) {
        Ok(v) => v,
        Err(resp) => return resp,
    };
    let busy = body.at(&["busy"]).as_bool().unwrap_or(false);
    match f.poll(worker, busy) {
        Ok(Some(a)) => Response::json(
            200,
            &Json::obj(vec![("assignment", wire::assignment_to_json(&a))]),
        ),
        Ok(None) => Response::json(200, &Json::obj(Vec::new())),
        Err(e) => Response::error(404, &format!("{e:#}")),
    }
}

/// `POST /fleet/shards/:id/result` — a worker reporting one shard.
/// Acceptance happens under the fleet lock; the follow-up I/O —
/// journal shard line, live progress events, grafting the worker's
/// trace spans into the coordinator ring — happens here, outside it.
fn fleet_result(req: &Request, state: &ServerState, id: &str) -> Response {
    let Some(f) = &state.fleet else { return not_coordinator() };
    let Ok(shard) = id.parse::<usize>() else {
        return Response::error(400, "shard id must be an integer");
    };
    let body = match req.body_json() {
        Ok(v) => v,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    let r = match wire::result_from_json(&body) {
        Ok(r) => r,
        Err(e) => return Response::error(400, &format!("bad shard result: {e:#}")),
    };
    if r.shard != shard {
        return Response::error(400, &format!("path shard {shard} != body shard {}", r.shard));
    }
    match f.accept_result(r) {
        Ok(acc) => {
            if let Some(j) = &state.journal {
                j.record_shard(acc.job, acc.shard, acc.state_label, acc.worker);
            }
            for ev in acc.layer_events {
                state.queue.push_event(acc.job, ev);
            }
            for ev in &acc.spans {
                state.trace_ring.record(ev);
            }
            Response::json(
                200,
                &Json::obj(vec![
                    ("job", (acc.job as usize).into()),
                    ("shard", acc.shard.into()),
                    ("state", acc.state_label.into()),
                ]),
            )
        }
        Err(e) => Response::error(409, &format!("{e:#}")),
    }
}

/// Chunked NDJSON stream: replay recorded [`LayerEvent`]s, then follow
/// the job live; the final line carries the terminal state + summary.
fn stream_job_events(writer: &mut TcpStream, state: &Arc<ServerState>, id: &str) {
    let Some(id) = parse_id(id) else {
        let _ = Response::error(400, "job id must be an integer").write(writer, false);
        return;
    };
    if state.queue.get(id).is_none() {
        let _ = Response::error(404, &format!("no job {id}")).write(writer, false);
        return;
    }
    let Ok(mut cw) = ChunkedWriter::begin(writer, 200, "application/x-ndjson") else {
        return;
    };
    let mut seen = 0usize;
    let mut last_write = std::time::Instant::now();
    loop {
        let Some(rec) = state.queue.wait_update(id, seen, STREAM_TICK) else { break };
        // fault site: sever the stream between chunks with no trailer,
        // exactly what a mid-response network partition looks like to
        // the client (exercised by the reconnect regression test)
        if crate::util::fault::hit("net.mid-response").is_err() {
            return;
        }
        let mut failed = false;
        for e in rec.events.get(seen..).unwrap_or(&[]) {
            let mut line = crate::util::json::to_string(&event_json(e));
            line.push('\n');
            failed |= cw.chunk(line.as_bytes()).is_err();
            last_write = std::time::Instant::now();
        }
        seen = rec.events.len();
        // heartbeat through long event gaps so the client's socket read
        // timeout doesn't kill a healthy stream (clients ignore it)
        if !rec.state.is_terminal() && last_write.elapsed() > Duration::from_secs(5) {
            failed |= cw.chunk(b"{\"heartbeat\": true}\n").is_err();
            last_write = std::time::Instant::now();
        }
        if failed {
            return; // client went away; skip the trailer
        }
        if rec.state.is_terminal() {
            let mut fields = vec![
                ("id", (rec.id as usize).into()),
                ("state", rec.state.label().into()),
            ];
            if let Some(s) = &rec.summary {
                fields.push(("result", s.to_json()));
            }
            if let Some(e) = &rec.error {
                fields.push(("error", e.as_str().into()));
            }
            let mut line = crate::util::json::to_string(&Json::obj(fields));
            line.push('\n');
            let _ = cw.chunk(line.as_bytes());
            let _ = cw.finish();
            return;
        }
        if state.stopping() {
            let _ = cw.finish();
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Wire formats
// ---------------------------------------------------------------------------

pub(crate) fn event_json(e: &LayerEvent) -> Json {
    Json::obj(vec![
        ("layer", e.layer.as_str().into()),
        ("index", e.index.into()),
        ("total", e.total.into()),
        ("obj", e.obj.into()),
    ])
}

fn progress_json(rec: &JobRecord) -> Json {
    let total = rec.events.last().map(|e| e.total).unwrap_or(0);
    Json::obj(vec![
        ("completed", rec.events.len().into()),
        ("total", total.into()),
    ])
}

/// Full status payload for `GET /jobs/:id`.
pub(crate) fn record_json(rec: &JobRecord) -> Json {
    let mut fields = vec![
        ("id", (rec.id as usize).into()),
        ("state", rec.state.label().into()),
        ("priority", (rec.priority as f64).into()),
        ("label", rec.spec.label().into()),
        ("spec", rec.spec.to_json()),
        ("corr_id", rec.corr_id.as_str().into()),
        ("queued_secs", rec.queued_secs().into()),
        ("progress", progress_json(rec)),
        (
            "events",
            Json::Arr(rec.events.iter().map(event_json).collect()),
        ),
    ];
    if let Some(w) = rec.worker {
        fields.push(("worker", w.into()));
    }
    if let Some(r) = rec.run_secs() {
        fields.push(("run_secs", r.into()));
    }
    if let Some(s) = &rec.summary {
        fields.push(("result", s.to_json()));
    }
    if let Some(e) = &rec.error {
        fields.push(("error", e.as_str().into()));
    }
    Json::obj(fields)
}
