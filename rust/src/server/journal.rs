//! Durable job journal + per-unit checkpoints (crash-safe pruning).
//!
//! Two artifacts live under the journal directory (`--journal DIR`,
//! or `<workspace>/journal` for workspace servers):
//!
//! ```text
//! <dir>/jobs.ndjson            append-only journal: one JSON line per
//!                              submit / state transition, with corr-id
//! <dir>/ckpt-<spec_hash>/      one checkpoint dir per distinct spec
//!     spec.json                the spec itself (what `sparsefw resume` re-runs)
//!     unit-0000.json           per-unit artifact: masks (1 bit/elem, hex),
//!     unit-0001.json           objectives, refine deltas, optional
//!     ...                      reconstructed weights (f32 LE, hex), and the
//!                              propagated-activation digest entering the unit
//! ```
//!
//! A *unit* is one block of four layers on the staged path
//! (`--propagate block|layer`) or one layer on the dense path.  Every
//! checkpoint file wraps its body in `{"body": …, "checksum": …}` where
//! the checksum is a [`mix64`] fold of the canonical serialized body —
//! [`CheckpointStore::load_prefix`] / [`CheckpointStore::load_present`]
//! verify checksum, spec hash, and mask/weight lengths, and silently
//! drop anything that fails verification (it simply recomputes), so a
//! torn write from a `kill -9` can never corrupt a resumed run.
//!
//! Replay folds `jobs.ndjson`: a job whose last recorded state is
//! `queued` or `running` did not finish before the crash and re-enters
//! the queue (same id, corr-id, priority) on the next `sparsefw serve`
//! startup.  Masks restored from checkpoints are bit-identical to the
//! originals — 1 bit per element, exact f32 round-trip for weights —
//! which is what makes resumed runs indistinguishable from
//! uninterrupted ones (asserted by `tests/crash_recovery.rs`).

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::job::JobSpec;
use crate::pruner::LayerPruneOutput;
use crate::tensor::Mat;
use crate::util::json::{self, Json};
use crate::util::prng::mix64;
use crate::util::sync::lock_recover;

/// Journal file name inside the journal directory.
pub const JOURNAL_FILE: &str = "jobs.ndjson";

// ---------------------------------------------------------------------------
// Hex + checksum primitives
// ---------------------------------------------------------------------------

pub(crate) fn bytes_to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        for nib in [b >> 4, b & 0xf] {
            s.push(char::from_digit(u32::from(nib), 16).unwrap_or('0'));
        }
    }
    s
}

pub(crate) fn hex_to_bytes(s: &str) -> Result<Vec<u8>> {
    ensure!(s.len() % 2 == 0, "odd-length hex string");
    let mut out = Vec::with_capacity(s.len() / 2);
    let mut hi: Option<u8> = None;
    for c in s.chars() {
        let d = c.to_digit(16).context("non-hex digit")? as u8;
        match hi.take() {
            None => hi = Some(d),
            Some(h) => out.push(h << 4 | d),
        }
    }
    Ok(out)
}

pub(crate) fn u64_hex(x: u64) -> String {
    format!("{x:016x}")
}

pub(crate) fn parse_hex_u64(s: &str) -> Result<u64> {
    u64::from_str_radix(s, 16).with_context(|| format!("bad hex u64 `{s}`"))
}

/// mix64 fold over a byte string (checksums, digests, spec hashes).
/// u64 values never pass through JSON numbers — the in-tree parser
/// stores them as f64 (53-bit mantissa), so they travel as hex strings.
pub fn fold_bytes(seed: u64, bytes: &[u8]) -> u64 {
    let mut h = mix64(seed ^ bytes.len() as u64);
    for chunk in bytes.chunks(8) {
        let mut v = [0u8; 8];
        for (dst, src) in v.iter_mut().zip(chunk) {
            *dst = *src;
        }
        h = mix64(h ^ u64::from_le_bytes(v));
    }
    h
}

/// Canonical hash of a job spec (its serialized JSON form — key order
/// is deterministic, the writer is canonical).  Checkpoints belong to
/// exactly one spec hash; resume refuses artifacts from any other.
pub fn spec_hash(spec: &JobSpec) -> u64 {
    fold_bytes(0x73706563, json::to_string(&spec.to_json()).as_bytes())
}

/// Order-independent digest of a full mask set (BTreeMap iteration is
/// name-sorted): the bit-identity certificate `tests/crash_recovery.rs`
/// compares between resumed and uninterrupted runs.
pub fn mask_digest(masks: &BTreeMap<String, Mat>) -> u64 {
    let mut h = mix64(0x6d61736b);
    for (name, m) in masks {
        h = fold_bytes(h, name.as_bytes());
        h = mix64(h ^ m.rows as u64);
        h = mix64(h ^ m.cols as u64);
        h = fold_bytes(h, &pack_mask(m));
    }
    h
}

// ---------------------------------------------------------------------------
// Mask / weight packing
// ---------------------------------------------------------------------------

/// 1 bit per element, row-major, LSB-first within each byte.
fn pack_mask(m: &Mat) -> Vec<u8> {
    let mut out = vec![0u8; (m.data.len() + 7) / 8];
    for (i, &x) in m.data.iter().enumerate() {
        if x != 0.0 {
            if let Some(b) = out.get_mut(i / 8) {
                *b |= 1 << (i % 8);
            }
        }
    }
    out
}

fn unpack_mask(bits: &[u8], rows: usize, cols: usize) -> Result<Mat> {
    ensure!(
        bits.len() == (rows * cols + 7) / 8,
        "mask bit string has {} bytes, want {} for {rows}×{cols}",
        bits.len(),
        (rows * cols + 7) / 8
    );
    let mut m = Mat::zeros(rows, cols);
    for (i, x) in m.data.iter_mut().enumerate() {
        if bits.get(i / 8).copied().unwrap_or(0) >> (i % 8) & 1 == 1 {
            *x = 1.0;
        }
    }
    Ok(m)
}

pub(crate) fn f32s_to_hex(xs: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    bytes_to_hex(&bytes)
}

pub(crate) fn hex_to_f32s(s: &str) -> Result<Vec<f32>> {
    let bytes = hex_to_bytes(s)?;
    ensure!(bytes.len() % 4 == 0, "f32 hex string not a multiple of 4 bytes");
    let mut out = Vec::with_capacity(bytes.len() / 4);
    let mut acc = [0u8; 4];
    for (i, b) in bytes.iter().enumerate() {
        if let Some(slot) = acc.get_mut(i % 4) {
            *slot = *b;
        }
        if i % 4 == 3 {
            out.push(f32::from_le_bytes(acc));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Checkpoint artifacts
// ---------------------------------------------------------------------------

/// One pruned layer inside a checkpoint unit.  Masks are stored at 1
/// bit per element and reconstructed weights as exact f32 bit patterns,
/// so [`LayerCheckpoint::to_output`] is bit-identical to the original
/// [`LayerPruneOutput`] (traces and convergence certificates are not
/// persisted — they are observability, not state).
#[derive(Clone, Debug)]
pub struct LayerCheckpoint {
    /// Index into `model.cfg.layers()`.
    pub index: usize,
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    mask_bits: Vec<u8>,
    pub obj: f64,
    pub warm_obj: Option<f64>,
    pub fw_iters: usize,
    pub refine_obj_delta: Option<f64>,
    pub new_weights: Option<Vec<f32>>,
}

impl LayerCheckpoint {
    pub fn from_output(index: usize, name: &str, out: &LayerPruneOutput) -> LayerCheckpoint {
        LayerCheckpoint {
            index,
            name: name.to_string(),
            rows: out.mask.rows,
            cols: out.mask.cols,
            mask_bits: pack_mask(&out.mask),
            obj: out.obj,
            warm_obj: out.warm_obj,
            fw_iters: out.fw_iters,
            refine_obj_delta: out.refine_obj_delta,
            new_weights: out.new_weights.as_ref().map(|m| m.data.clone()),
        }
    }

    /// Reconstruct the layer output this checkpoint was taken from.
    pub fn to_output(&self) -> Result<LayerPruneOutput> {
        let mask = unpack_mask(&self.mask_bits, self.rows, self.cols)
            .with_context(|| format!("checkpointed layer {}", self.name))?;
        let new_weights = match &self.new_weights {
            Some(data) => {
                ensure!(
                    data.len() == self.rows * self.cols,
                    "checkpointed layer {}: {} weights, want {}×{}",
                    self.name,
                    data.len(),
                    self.rows,
                    self.cols
                );
                let mut m = Mat::zeros(self.rows, self.cols);
                m.data.copy_from_slice(data);
                Some(m)
            }
            None => None,
        };
        Ok(LayerPruneOutput {
            mask,
            obj: self.obj,
            warm_obj: self.warm_obj,
            new_weights,
            trace: None,
            convergence: None,
            fw_iters: self.fw_iters,
            refine_obj_delta: self.refine_obj_delta,
        })
    }

    pub(crate) fn to_json(&self) -> Json {
        let mut fields = vec![
            ("index", Json::from(self.index)),
            ("name", Json::from(self.name.as_str())),
            ("rows", Json::from(self.rows)),
            ("cols", Json::from(self.cols)),
            ("mask_hex", Json::from(bytes_to_hex(&self.mask_bits))),
            ("obj", Json::from(self.obj)),
            ("fw_iters", Json::from(self.fw_iters)),
        ];
        if let Some(w) = self.warm_obj {
            fields.push(("warm_obj", Json::from(w)));
        }
        if let Some(d) = self.refine_obj_delta {
            fields.push(("refine_obj_delta", Json::from(d)));
        }
        if let Some(nw) = &self.new_weights {
            fields.push(("new_weights_hex", Json::from(f32s_to_hex(nw))));
        }
        Json::obj(fields)
    }

    pub(crate) fn from_json(j: &Json) -> Result<LayerCheckpoint> {
        let name = j
            .at(&["name"])
            .as_str()
            .context("layer checkpoint missing `name`")?
            .to_string();
        let rows = j.at(&["rows"]).as_usize().context("layer checkpoint missing `rows`")?;
        let cols = j.at(&["cols"]).as_usize().context("layer checkpoint missing `cols`")?;
        let mask_bits = hex_to_bytes(
            j.at(&["mask_hex"]).as_str().context("layer checkpoint missing `mask_hex`")?,
        )?;
        let new_weights = match j.at(&["new_weights_hex"]).as_str() {
            Some(h) => Some(hex_to_f32s(h)?),
            None => None,
        };
        Ok(LayerCheckpoint {
            index: j.at(&["index"]).as_usize().context("layer checkpoint missing `index`")?,
            name,
            rows,
            cols,
            mask_bits,
            obj: j.at(&["obj"]).as_f64().context("layer checkpoint missing `obj`")?,
            warm_obj: j.at(&["warm_obj"]).as_f64(),
            fw_iters: j.at(&["fw_iters"]).as_usize().unwrap_or(0),
            refine_obj_delta: j.at(&["refine_obj_delta"]).as_f64(),
            new_weights,
        })
    }
}

/// One completed unit of work: a block of four layers on the staged
/// path, a single layer on the dense path.
#[derive(Clone, Debug)]
pub struct BlockCheckpoint {
    /// Unit index (block index when staged, layer index when dense).
    pub unit: usize,
    /// Total units in the run (a checkpoint from a differently shaped
    /// run never resumes).
    pub n_units: usize,
    /// Calibration policy label (`off` / `block` / `layer`).
    pub policy: String,
    /// [`spec_hash`] of the owning spec.
    pub spec_hash: u64,
    /// [`crate::calib::CalibState::digest`] of the propagated
    /// activations *entering* this unit (0 when not applicable — dense
    /// path, or the first block).  On resume the rebuilt state must
    /// reproduce this digest before the unit's outputs are trusted.
    pub entry_digest: u64,
    /// Staged [`crate::calib::EmbedPrefix`] identity: model name,
    /// calibration samples and seed.
    pub calib_model: String,
    pub calib_samples: usize,
    pub calib_seed: u64,
    pub layers: Vec<LayerCheckpoint>,
}

impl BlockCheckpoint {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::from(1usize)),
            ("unit", Json::from(self.unit)),
            ("n_units", Json::from(self.n_units)),
            ("policy", Json::from(self.policy.as_str())),
            ("spec_hash", Json::from(u64_hex(self.spec_hash))),
            ("entry_digest", Json::from(u64_hex(self.entry_digest))),
            ("calib_model", Json::from(self.calib_model.as_str())),
            ("calib_samples", Json::from(self.calib_samples)),
            ("calib_seed", Json::from(u64_hex(self.calib_seed))),
            ("layers", Json::Arr(self.layers.iter().map(|l| l.to_json()).collect())),
        ])
    }

    fn from_json(j: &Json) -> Result<BlockCheckpoint> {
        let version = j.at(&["version"]).as_usize().unwrap_or(0);
        ensure!(version == 1, "unsupported checkpoint version {version}");
        let mut layers = Vec::new();
        for l in j.at(&["layers"]).as_arr().context("checkpoint missing `layers`")? {
            layers.push(LayerCheckpoint::from_json(l)?);
        }
        Ok(BlockCheckpoint {
            unit: j.at(&["unit"]).as_usize().context("checkpoint missing `unit`")?,
            n_units: j.at(&["n_units"]).as_usize().context("checkpoint missing `n_units`")?,
            policy: j.at(&["policy"]).as_str().unwrap_or("off").to_string(),
            spec_hash: parse_hex_u64(
                j.at(&["spec_hash"]).as_str().context("checkpoint missing `spec_hash`")?,
            )?,
            entry_digest: parse_hex_u64(j.at(&["entry_digest"]).as_str().unwrap_or("0"))?,
            calib_model: j.at(&["calib_model"]).as_str().unwrap_or("").to_string(),
            calib_samples: j.at(&["calib_samples"]).as_usize().unwrap_or(0),
            calib_seed: parse_hex_u64(j.at(&["calib_seed"]).as_str().unwrap_or("0"))?,
            layers,
        })
    }
}

/// Per-spec checkpoint directory under the journal root.
pub struct CheckpointStore {
    dir: PathBuf,
    hash: u64,
}

const CKPT_SEED: u64 = 0x636b7074; // "ckpt"

impl CheckpointStore {
    /// Open (creating if needed) the checkpoint dir for `spec` under
    /// `root` — `<root>/ckpt-<spec_hash>/`.
    pub fn for_spec(root: &Path, spec: &JobSpec) -> Result<CheckpointStore> {
        let hash = spec_hash(spec);
        let dir = root.join(format!("ckpt-{}", u64_hex(hash)));
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir, hash })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// Persist the spec itself so `sparsefw resume` can re-execute an
    /// interrupted CLI run without the original command line.
    pub fn save_spec(&self, spec: &JobSpec) -> Result<()> {
        write_atomic(
            &self.dir.join("spec.json"),
            &json::to_string_pretty(&spec.to_json()),
        )
    }

    fn unit_path(&self, unit: usize) -> PathBuf {
        self.dir.join(format!("unit-{unit:04}.json"))
    }

    /// Write one completed unit (tmp + rename, checksummed).  Fault
    /// site: `io.write.checkpoint`.
    pub fn save_unit(&self, ck: &BlockCheckpoint) -> Result<()> {
        crate::util::fault::hit("io.write.checkpoint")?;
        ensure!(
            ck.spec_hash == self.hash,
            "checkpoint unit carries spec hash {:016x}, store is {:016x}",
            ck.spec_hash,
            self.hash
        );
        let body = ck.to_json();
        let body_s = json::to_string(&body);
        let sum = fold_bytes(CKPT_SEED, body_s.as_bytes());
        let wrapped = Json::obj(vec![
            ("body", body),
            ("checksum", Json::from(u64_hex(sum))),
        ]);
        write_atomic(&self.unit_path(ck.unit), &json::to_string(&wrapped))
    }

    /// Load and verify one unit: checksum over the canonical body,
    /// spec-hash match, unit-index match.  `Ok(None)` when the file
    /// doesn't exist.  Fault site: `io.read`.
    fn load_unit(&self, unit: usize) -> Result<Option<BlockCheckpoint>> {
        crate::util::fault::hit("io.read")?;
        let path = self.unit_path(unit);
        let src = match fs::read_to_string(&path) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading checkpoint {}", path.display()))
            }
        };
        let v = json::parse(&src)
            .with_context(|| format!("parsing checkpoint {}", path.display()))?;
        let body = v.at(&["body"]);
        ensure!(!body.is_null(), "checkpoint {}: missing body", path.display());
        let stored = parse_hex_u64(
            v.at(&["checksum"])
                .as_str()
                .with_context(|| format!("checkpoint {}: missing checksum", path.display()))?,
        )?;
        let sum = fold_bytes(CKPT_SEED, json::to_string(body).as_bytes());
        ensure!(
            sum == stored,
            "checkpoint {}: checksum mismatch (stored {:016x}, computed {:016x})",
            path.display(),
            stored,
            sum
        );
        let ck = BlockCheckpoint::from_json(body)?;
        ensure!(
            ck.spec_hash == self.hash,
            "checkpoint {}: spec hash mismatch",
            path.display()
        );
        ensure!(ck.unit == unit, "checkpoint {}: unit index mismatch", path.display());
        Ok(Some(ck))
    }

    /// Verified contiguous prefix `0..k` — what the sequential staged
    /// path resumes from.  Stops at the first missing unit; a unit that
    /// fails verification truncates the prefix there (it and everything
    /// after simply recompute), so corruption degrades to recomputation
    /// rather than failure.
    pub fn load_prefix(&self, n_units: usize) -> Vec<BlockCheckpoint> {
        let mut out = Vec::new();
        for unit in 0..n_units {
            match self.load_unit(unit) {
                Ok(Some(ck)) if ck.n_units == n_units => out.push(ck),
                Ok(Some(ck)) => {
                    crate::warnlog!(
                        "checkpoint unit {unit} is from a {}-unit run (this run has {n_units}); ignoring it and the rest",
                        ck.n_units
                    );
                    break;
                }
                Ok(None) => break,
                Err(e) => {
                    crate::warnlog!(
                        "checkpoint unit {unit} unusable ({e:#}); recomputing from it onward"
                    );
                    break;
                }
            }
        }
        out
    }

    /// Every verified unit present, keyed by unit index — what the
    /// dense path resumes from (layers complete in LPT order, so the
    /// completed set need not be contiguous).
    pub fn load_present(&self, n_units: usize) -> BTreeMap<usize, BlockCheckpoint> {
        let mut out = BTreeMap::new();
        for unit in 0..n_units {
            match self.load_unit(unit) {
                Ok(Some(ck)) if ck.n_units == n_units => {
                    out.insert(unit, ck);
                }
                Ok(Some(_)) | Ok(None) => {}
                Err(e) => {
                    crate::warnlog!("checkpoint unit {unit} unusable ({e:#}); recomputing it");
                }
            }
        }
        out
    }

    /// Drop the whole checkpoint dir — the run completed, its
    /// artifacts are dead weight.
    pub fn clear(&self) -> Result<()> {
        fs::remove_dir_all(&self.dir)
            .with_context(|| format!("clearing checkpoint dir {}", self.dir.display()))
    }
}

/// Specs of interrupted CLI runs: every `ckpt-*/spec.json` under
/// `root`.  `sparsefw resume --journal DIR` re-executes these.
pub fn saved_specs(root: &Path) -> Result<Vec<(PathBuf, JobSpec)>> {
    let rd = match fs::read_dir(root) {
        Ok(r) => r,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", root.display())),
    };
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.context("reading journal dir entry")?;
        if !entry.file_name().to_string_lossy().starts_with("ckpt-") {
            continue;
        }
        let spec_path = entry.path().join("spec.json");
        let src = match fs::read_to_string(&spec_path) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let parsed = json::parse(&src)
            .map_err(anyhow::Error::from)
            .and_then(|v| JobSpec::from_json(&v));
        match parsed {
            Ok(spec) => out.push((entry.path(), spec)),
            Err(e) => crate::warnlog!(
                "unreadable saved spec {} ({e:#}); skipping",
                spec_path.display()
            ),
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

fn write_atomic(path: &Path, contents: &str) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(contents.as_bytes())
            .with_context(|| format!("writing {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("syncing {}", tmp.display()))?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", path.display()))
}

// ---------------------------------------------------------------------------
// The job journal
// ---------------------------------------------------------------------------

/// A job recovered from the journal whose last recorded state was not
/// terminal — it re-enters the queue on restart.
#[derive(Clone, Debug)]
pub struct ReplayJob {
    pub id: u64,
    pub corr_id: String,
    pub priority: i64,
    pub spec: JobSpec,
}

/// Append-only NDJSON journal of job lifecycle events.  Appends are
/// serialized by an internal lock and synced per record; a torn final
/// line (the crash window) is skipped on replay.
pub struct Journal {
    path: PathBuf,
    file: Mutex<File>,
}

impl Journal {
    /// Open (creating dir + file if needed) `<dir>/jobs.ndjson`.
    pub fn open(dir: &Path) -> Result<Journal> {
        fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        let path = dir.join(JOURNAL_FILE);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        Ok(Journal { path, file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    fn append(&self, line: &Json) {
        let s = json::to_string(line);
        let mut f = lock_recover(&self.file);
        // analyze: allow(lock-across-blocking, "the file lock IS the journal's append serializer")
        let r = writeln!(&mut *f, "{s}").and_then(|()| f.sync_data());
        drop(f);
        if let Err(e) = r {
            crate::warnlog!("journal append failed ({e}); durability degraded");
        }
    }

    /// Record a submission (spec + identity).  Job ids fit f64 exactly
    /// (they are small sequence numbers, far below 2^53).
    pub fn record_submit(&self, id: u64, corr_id: &str, priority: i64, spec: &JobSpec) {
        self.append(&Json::obj(vec![
            ("ev", Json::from("submit")),
            ("id", Json::from(id as usize)),
            ("corr", Json::from(corr_id)),
            ("priority", Json::Num(priority as f64)),
            ("ts_ms", Json::Num(now_ms() as f64)),
            ("spec", spec.to_json()),
        ]));
    }

    /// Record a fleet shard transition (`dispatched`, `done`,
    /// `requeued`, `failed`) with the worker it was leased to.  Replay
    /// ignores these lines (job-level state drives requeueing); they
    /// exist so a restarted coordinator — and an operator reading the
    /// journal — can reconstruct which worker held which blocks when.
    pub fn record_shard(&self, id: u64, shard: usize, state: &str, worker: u64) {
        self.append(&Json::obj(vec![
            ("ev", Json::from("shard")),
            ("id", Json::from(id as usize)),
            ("shard", Json::from(shard)),
            ("state", Json::from(state)),
            ("worker", Json::from(worker as usize)),
            ("ts_ms", Json::Num(now_ms() as f64)),
        ]));
    }

    /// Record a state transition (`running`, `done`, `failed`,
    /// `cancelled`).
    pub fn record_state(&self, id: u64, state: &str) {
        self.append(&Json::obj(vec![
            ("ev", Json::from("state")),
            ("id", Json::from(id as usize)),
            ("state", Json::from(state)),
            ("ts_ms", Json::Num(now_ms() as f64)),
        ]));
    }

    /// Fold the journal at `dir`: jobs whose last recorded state is
    /// non-terminal (queued or running at crash time) come back, in id
    /// order.  Unparseable lines — including a torn final line — are
    /// skipped with a warning.  Fault site: `io.read`.
    pub fn replay(dir: &Path) -> Result<Vec<ReplayJob>> {
        crate::util::fault::hit("io.read")?;
        let path = dir.join(JOURNAL_FILE);
        let file = match File::open(&path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => {
                return Err(e).with_context(|| format!("opening journal {}", path.display()))
            }
        };
        let mut jobs: BTreeMap<u64, ReplayJob> = BTreeMap::new();
        for (ln, line) in BufReader::new(file).lines().enumerate() {
            let line = match line {
                Ok(l) => l,
                Err(e) => {
                    crate::warnlog!("journal read stopped at line {} ({e})", ln + 1);
                    break;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            let v = match json::parse(&line) {
                Ok(v) => v,
                Err(e) => {
                    crate::warnlog!("journal line {} unparseable ({e}); skipping", ln + 1);
                    continue;
                }
            };
            let Some(id) = v.at(&["id"]).as_usize() else { continue };
            let id = id as u64;
            match v.at(&["ev"]).as_str() {
                Some("submit") => match JobSpec::from_json(v.at(&["spec"])) {
                    Ok(spec) => {
                        jobs.insert(
                            id,
                            ReplayJob {
                                id,
                                corr_id: v.at(&["corr"]).as_str().unwrap_or("").to_string(),
                                priority: v.at(&["priority"]).as_f64().unwrap_or(0.0) as i64,
                                spec,
                            },
                        );
                    }
                    Err(e) => {
                        crate::warnlog!("journal line {}: bad spec ({e:#}); skipping", ln + 1);
                    }
                },
                Some("state") => {
                    if matches!(
                        v.at(&["state"]).as_str(),
                        Some("done") | Some("failed") | Some("cancelled")
                    ) {
                        jobs.remove(&id);
                    }
                }
                _ => {}
            }
        }
        Ok(jobs.into_values().collect())
    }
}

fn now_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("sfw-journal-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn demo_output(rows: usize, cols: usize, with_weights: bool) -> LayerPruneOutput {
        let mask = Mat::from_fn(rows, cols, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let new_weights = with_weights
            .then(|| Mat::from_fn(rows, cols, |i, j| (i as f32 * 0.37 - j as f32 * 1.61).sin()));
        LayerPruneOutput {
            mask,
            obj: 1.25,
            warm_obj: Some(2.5),
            new_weights,
            trace: None,
            convergence: None,
            fw_iters: 17,
            refine_obj_delta: Some(0.125),
        }
    }

    #[test]
    fn hex_and_mask_round_trip() {
        let bytes = vec![0u8, 1, 0xab, 0xff, 0x10];
        assert_eq!(hex_to_bytes(&bytes_to_hex(&bytes)).unwrap(), bytes);
        let xs = vec![0.0f32, -1.5, f32::MIN_POSITIVE, 1234.5678];
        assert_eq!(hex_to_f32s(&f32s_to_hex(&xs)).unwrap(), xs);

        let m = Mat::from_fn(5, 7, |i, j| if (i * 7 + j) % 3 == 0 { 1.0 } else { 0.0 });
        let back = unpack_mask(&pack_mask(&m), 5, 7).unwrap();
        assert_eq!(m.data, back.data);
        assert!(unpack_mask(&pack_mask(&m), 6, 7).is_err(), "length checked");
    }

    #[test]
    fn layer_checkpoint_is_bit_identical() {
        let out = demo_output(6, 9, true);
        let ck = LayerCheckpoint::from_output(3, "blocks.0.wo", &out);
        let j = ck.to_json();
        let back = LayerCheckpoint::from_json(&json::parse(&json::to_string(&j)).unwrap()).unwrap();
        let rt = back.to_output().unwrap();
        assert_eq!(rt.mask.data, out.mask.data);
        assert_eq!(
            rt.new_weights.as_ref().map(|m| m.data.clone()),
            out.new_weights.as_ref().map(|m| m.data.clone())
        );
        assert_eq!(rt.obj, out.obj);
        assert_eq!(rt.warm_obj, out.warm_obj);
        assert_eq!(rt.fw_iters, out.fw_iters);
        assert_eq!(rt.refine_obj_delta, out.refine_obj_delta);
    }

    #[test]
    fn checkpoint_store_verifies_and_truncates_on_corruption() {
        let dir = tmp("store");
        let spec = JobSpec { model: "demo".to_string(), ..Default::default() };
        let cs = CheckpointStore::for_spec(&dir, &spec).unwrap();

        for unit in 0..3usize {
            let out = demo_output(4, 8, unit == 1);
            let ck = BlockCheckpoint {
                unit,
                n_units: 4,
                policy: "block".to_string(),
                spec_hash: cs.hash(),
                entry_digest: 0xdead_beef + unit as u64,
                calib_model: "demo".to_string(),
                calib_samples: 6,
                calib_seed: 1,
                layers: vec![LayerCheckpoint::from_output(unit, "blocks.0.wqkv", &out)],
            };
            cs.save_unit(&ck).unwrap();
        }
        let prefix = cs.load_prefix(4);
        assert_eq!(prefix.len(), 3);
        assert_eq!(prefix[1].entry_digest, 0xdead_beef + 1);

        // corrupt unit 1: the prefix truncates there
        let p = cs.dir().join("unit-0001.json");
        let mut s = fs::read_to_string(&p).unwrap();
        s = s.replace("\"obj\":", "\"obj_x\":");
        fs::write(&p, s).unwrap();
        assert_eq!(cs.load_prefix(4).len(), 1);
        // the non-contiguous loader drops only the corrupt unit
        let present = cs.load_present(4);
        assert_eq!(present.keys().copied().collect::<Vec<_>>(), vec![0, 2]);

        // a store for a different spec sees nothing
        let other = JobSpec { model: "other".to_string(), ..Default::default() };
        assert_ne!(spec_hash(&spec), spec_hash(&other));
        let cs2 = CheckpointStore::for_spec(&dir, &other).unwrap();
        assert!(cs2.load_prefix(4).is_empty());

        cs.clear().unwrap();
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn journal_replay_returns_unfinished_jobs() {
        let dir = tmp("replay");
        let spec = JobSpec { model: "demo".to_string(), ..Default::default() };
        {
            let j = Journal::open(&dir).unwrap();
            j.record_submit(1, "corr-a", 0, &spec);
            j.record_submit(2, "corr-b", 5, &spec);
            j.record_submit(3, "corr-c", 0, &spec);
            j.record_state(1, "running");
            j.record_state(1, "done");
            j.record_state(2, "running"); // crashed mid-run
            // fleet shard lines are observability, not job state: they
            // must not resurrect job 1 or finish job 2
            j.record_shard(1, 0, "done", 7);
            j.record_shard(2, 1, "dispatched", 9);
        }
        // a torn final line must not break replay
        {
            use std::io::Write as _;
            let mut f = OpenOptions::new()
                .append(true)
                .open(dir.join(JOURNAL_FILE))
                .unwrap();
            write!(f, "{{\"ev\": \"state\", \"id\": 3, \"sta").unwrap();
        }
        let jobs = Journal::replay(&dir).unwrap();
        let ids: Vec<u64> = jobs.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![2, 3], "done job dropped, queued+running survive");
        assert_eq!(jobs[0].corr_id, "corr-b");
        assert_eq!(jobs[0].priority, 5);
        assert_eq!(jobs[0].spec.model, "demo");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn saved_specs_lists_interrupted_runs() {
        let dir = tmp("specs");
        let spec = JobSpec { model: "demo".to_string(), ..Default::default() };
        let cs = CheckpointStore::for_spec(&dir, &spec).unwrap();
        assert!(saved_specs(&dir).unwrap().is_empty(), "no spec.json yet");
        cs.save_spec(&spec).unwrap();
        let found = saved_specs(&dir).unwrap();
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].1.model, "demo");
        assert_eq!(spec_hash(&found[0].1), cs.hash(), "round-trip preserves the hash");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn mask_digest_is_order_independent_and_bit_sensitive() {
        let mut a = BTreeMap::new();
        a.insert("x".to_string(), Mat::from_fn(2, 2, |i, _| i as f32));
        a.insert("y".to_string(), Mat::from_fn(2, 2, |_, j| j as f32));
        let d1 = mask_digest(&a);
        let mut b = BTreeMap::new();
        b.insert("y".to_string(), Mat::from_fn(2, 2, |_, j| j as f32));
        b.insert("x".to_string(), Mat::from_fn(2, 2, |i, _| i as f32));
        assert_eq!(d1, mask_digest(&b));
        if let Some(m) = b.get_mut("x") {
            m.data[0] = 1.0 - m.data[0];
        }
        assert_ne!(d1, mask_digest(&b));
    }
}
