//! `sparsefw serve` — a multi-client pruning job server.
//!
//! PR 1 made a pruning run pure data ([`JobSpec`]) executed by a
//! memoizing [`PruneSession`]; this subsystem puts a long-lived daemon
//! in front of that substrate so many clients amortize workspace, model
//! and calibration setup across jobs:
//!
//! * [`http`] — minimal HTTP/1.1 on blocking `std::net` (no tokio
//!   offline): parsing, plain + chunked responses, keep-alive, with
//!   connections fanned over a [`crate::util::pool::TaskPool`].
//! * [`queue`] — bounded priority-FIFO [`queue::JobQueue`] + job
//!   registry: `Queued → Running → Done/Failed`, queued-job
//!   cancellation, graceful shutdown (in-flight jobs always complete).
//! * [`api`] — the JSON API over [`crate::util::json`]: `POST /jobs`,
//!   `GET /jobs[/:id[/events|/trace]]`, `DELETE /jobs/:id`,
//!   `POST /jobs/:id/eval`, `POST /jobs/:id/generate`,
//!   `GET /healthz`, `GET /metrics[?format=prometheus]`,
//!   `POST /shutdown`.
//! * [`client`] — a small blocking [`client::Client`] used by the CLI
//!   (`sparsefw submit/status/shutdown`), examples, and tests.
//! * [`fleet`] — the distributed tier: `serve --coordinator` shards
//!   each job across `serve --worker` processes at block granularity
//!   with staged hidden-state hand-off (same public job API, same
//!   bit-exact results).
//!
//! Each worker thread owns one [`PruneSession`] over the shared
//! workspace, so repeated jobs hit the session's model cache and
//! LRU-bounded calibration memo; `GET /metrics` aggregates those
//! hit/miss counters across workers.
//!
//! Observability: every submitted job carries a correlation ID
//! (client-supplied `X-Sparsefw-Corr-Id` or minted at submit), workers
//! execute under it, and [`Server::bind`] installs three
//! [`crate::util::telemetry`] sinks — a per-correlation ring buffer
//! behind `GET /jobs/:id/trace`, a [`PhaseSink`] feeding the per-phase
//! latency [`Histogram`]s, and (with [`ServerConfig::trace_out`]) an
//! NDJSON file sink.  The [`METRIC_CATALOG`] is the single list behind
//! the Prometheus text exposition and the `sparsefw analyze`
//! metrics-coverage lint.
//!
//! Serving: when a job completes, its worker compiles the pruned model
//! once into packed sparse formats
//! ([`crate::model::compiled::CompiledModel`]) and parks it in the
//! LRU-bounded [`CompiledCache`]; `POST /jobs/:id/eval` (perplexity)
//! and `POST /jobs/:id/generate` (KV-cached sampling) then serve
//! inference straight from the cache — each expensive prune becomes an
//! amortizable read-heavy serving artifact.

pub mod api;
pub mod client;
pub mod fleet;
pub mod http;
pub mod journal;
pub mod queue;
pub mod ratelimit;

pub use client::Client;
pub use queue::{JobBrief, JobId, JobQueue, JobRecord, JobState, JobSummary};

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use crate::coordinator::job::DEFAULT_CALIB_CACHE_CAP;
use crate::coordinator::{JobSpec, PruneSession};
use crate::data::TokenBin;
use crate::model::GptConfig;
use crate::util::json::Json;
use crate::util::pool::TaskPool;
use crate::util::telemetry::{self, NdjsonSink, RingSink, TraceEvent, TraceSink};

// ---------------------------------------------------------------------------
// Config / state / metrics
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`ServerHandle::addr`] for the resolved one).
    pub addr: String,
    /// Pruning worker threads (one [`PruneSession`] each).
    pub workers: usize,
    /// Bound on *pending* jobs; submissions beyond it are shed with
    /// `429 Too Many Requests` + `Retry-After`.
    pub queue_capacity: usize,
    /// Per-worker calibration LRU capacity
    /// ([`PruneSession::set_calib_cache_capacity`]).
    pub calib_cache_cap: usize,
    /// Connection-handling threads (HTTP, not pruning; event streams
    /// run on their own threads and do not occupy this pool).
    pub conn_threads: usize,
    /// Retained terminal job records ([`JobQueue::with_history_cap`]).
    pub job_history_cap: usize,
    /// Mirror every trace span to an NDJSON file (`serve --trace-out`);
    /// `None` = ring buffer (+ any globally installed sinks) only.
    pub trace_out: Option<String>,
    /// Durability directory (`serve --journal DIR`): an append-only job
    /// journal (`jobs.ndjson`) plus per-spec checkpoint subdirectories.
    /// On startup the journal is replayed, re-queueing every job that
    /// was Queued or Running when the previous process died — workers
    /// then resume those jobs from their verified checkpoints.
    pub journal: Option<String>,
    /// Wall-clock budget per job (`serve --job-timeout SECS`); crossing
    /// it fails the job cleanly between units (`None` = unbounded).
    pub job_timeout_secs: Option<f64>,
    /// Compiled serving models retained in the LRU [`CompiledCache`]
    /// (`serve --compiled-cache N`).
    pub compiled_cache_cap: usize,
    /// Bearer token required on every mutating route (`serve
    /// --auth-token` / `SPARSEFW_AUTH_TOKEN`); `None` = open server.
    pub auth_token: Option<String>,
    /// Run as a fleet coordinator (`serve --coordinator`): jobs are
    /// sharded across registered worker processes instead of local
    /// worker threads (see [`fleet`]).
    pub coordinator: bool,
    /// Fleet heartbeat window in seconds: a worker silent for longer is
    /// presumed dead and its leased shards requeue; also how long a
    /// job waits for a first worker before falling back to local
    /// execution.
    pub fleet_timeout_secs: f64,
}

/// Default [`ServerConfig::compiled_cache_cap`].
pub const DEFAULT_COMPILED_CACHE_CAP: usize = 4;

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".into(),
            workers: 2,
            queue_capacity: 256,
            calib_cache_cap: DEFAULT_CALIB_CACHE_CAP,
            conn_threads: 8,
            job_history_cap: queue::DEFAULT_HISTORY_CAP,
            trace_out: None,
            journal: None,
            job_timeout_secs: None,
            compiled_cache_cap: DEFAULT_COMPILED_CACHE_CAP,
            auth_token: None,
            coordinator: false,
            fleet_timeout_secs: 10.0,
        }
    }
}

// ---------------------------------------------------------------------------
// Histograms + the metric catalog
// ---------------------------------------------------------------------------

/// Prometheus-style upper bucket bounds (seconds) shared by every
/// latency histogram: log-scale from 1ms to 2min.
pub const HISTOGRAM_BOUNDS: &[f64] = &[
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
    120.0,
];

/// Lock-free fixed-bucket latency histogram (seconds).
///
/// One atomic counter per [`HISTOGRAM_BOUNDS`] bound plus an overflow
/// bucket; [`Histogram::observe`] costs two relaxed `fetch_add`s, so it
/// is safe on worker hot paths and inside trace sinks.  Quantiles are
/// bucket upper bounds — the usual Prometheus-grade approximation.
pub struct Histogram {
    counts: Vec<AtomicU64>,
    sum_micros: AtomicU64,
}

impl Histogram {
    fn new() -> Self {
        Self {
            counts: (0..=HISTOGRAM_BOUNDS.len()).map(|_| AtomicU64::new(0)).collect(),
            sum_micros: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, secs: f64) {
        let s = if secs.is_finite() { secs.max(0.0) } else { 0.0 };
        let idx = HISTOGRAM_BOUNDS
            .iter()
            .position(|&b| s <= b)
            .unwrap_or(HISTOGRAM_BOUNDS.len());
        if let Some(c) = self.counts.get(idx) {
            c.fetch_add(1, Ordering::Relaxed);
        }
        self.sum_micros.fetch_add((s * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    pub fn sum_secs(&self) -> f64 {
        self.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Approximate `q`-quantile: the upper bound of the bucket holding
    /// the q-th observation (the overflow bucket reports the largest
    /// finite bound).  `None` when nothing was observed.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let total = self.count();
        if total == 0 {
            return None;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= target {
                let bound = HISTOGRAM_BOUNDS
                    .get(i)
                    .or_else(|| HISTOGRAM_BOUNDS.last())
                    .copied()
                    .unwrap_or(0.0);
                return Some(bound);
            }
        }
        None
    }

    /// `{count, sum_secs, p50, p95, p99}` for the JSON `/metrics` form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", (self.count() as usize).into()),
            ("sum_secs", self.sum_secs().into()),
            ("p50", self.quantile(0.50).unwrap_or(0.0).into()),
            ("p95", self.quantile(0.95).unwrap_or(0.0).into()),
            ("p99", self.quantile(0.99).unwrap_or(0.0).into()),
        ])
    }

    /// Text exposition: `HELP`/`TYPE` header, cumulative `_bucket`
    /// lines (closing with `le="+Inf"`), `_sum` and `_count`.
    fn render_prometheus(&self, name: &str, help: &str, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            match HISTOGRAM_BOUNDS.get(i) {
                Some(b) => {
                    let _ = writeln!(out, "{name}_bucket{{le=\"{b}\"}} {cum}");
                }
                None => {
                    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
                }
            }
        }
        let _ = writeln!(out, "{name}_sum {}", self.sum_secs());
        let _ = writeln!(out, "{name}_count {cum}");
    }
}

/// Every metric exposed by `GET /metrics?format=prometheus`:
/// `(name, type, help)`.
///
/// This list is load-bearing twice over: [`render_prometheus`] renders
/// exactly these metrics, and the `sparsefw analyze` metrics-coverage
/// lint checks that every name here is documented in the USAGE metric
/// catalog in `main.rs`.
pub const METRIC_CATALOG: &[(&str, &str, &str)] = &[
    ("sparsefw_jobs_submitted_total", "counter", "Jobs accepted by POST /jobs"),
    ("sparsefw_jobs_done_total", "counter", "Jobs finished successfully"),
    ("sparsefw_jobs_failed_total", "counter", "Jobs that errored or panicked"),
    (
        "sparsefw_jobs_propagated_total",
        "counter",
        "Completed jobs that ran staged (propagated) calibration",
    ),
    ("sparsefw_calib_cache_hits_total", "counter", "Calibration memo hits across workers"),
    ("sparsefw_calib_cache_misses_total", "counter", "Calibration memo misses across workers"),
    ("sparsefw_fw_iters_total", "counter", "Frank-Wolfe iterations executed by completed jobs"),
    ("sparsefw_workers", "gauge", "Pruning worker threads"),
    ("sparsefw_busy_workers", "gauge", "Workers currently executing a job"),
    ("sparsefw_queue_depth", "gauge", "Jobs waiting in the pending queue"),
    ("sparsefw_uptime_seconds", "gauge", "Seconds since the server started"),
    (
        "sparsefw_peak_gram_bytes",
        "gauge",
        "High-water mark of per-job peak calibration-gram bytes (staged jobs)",
    ),
    ("sparsefw_queue_wait_seconds", "histogram", "Submit-to-start latency"),
    ("sparsefw_job_wall_seconds", "histogram", "Per-job pruning wall time"),
    (
        "sparsefw_phase_calib_seconds",
        "histogram",
        "Calibration phase duration, from trace spans",
    ),
    (
        "sparsefw_phase_gram_seconds",
        "histogram",
        "Gram assembly phase duration, from trace spans",
    ),
    (
        "sparsefw_phase_fw_seconds",
        "histogram",
        "Per-layer mask optimization duration, from trace spans",
    ),
    (
        "sparsefw_phase_refine_seconds",
        "histogram",
        "Refine post-pass duration, from trace spans",
    ),
    (
        "sparsefw_phase_io_seconds",
        "histogram",
        "Result materialization and eval duration, from trace spans",
    ),
    (
        "sparsefw_jobs_replayed_total",
        "counter",
        "Jobs re-queued from the durable journal at startup",
    ),
    (
        "sparsefw_jobs_shed_total",
        "counter",
        "Submissions shed with 429 (queue saturation)",
    ),
    (
        "sparsefw_faults_injected_total",
        "counter",
        "Faults fired by the deterministic injection harness",
    ),
    (
        "sparsefw_models_compiled_total",
        "counter",
        "Pruned models compiled into packed sparse serving formats",
    ),
    (
        "sparsefw_compiled_cache_hits_total",
        "counter",
        "eval/generate requests served from the compiled-model cache",
    ),
    (
        "sparsefw_compiled_cache_misses_total",
        "counter",
        "eval/generate requests whose compiled model was evicted or never compiled",
    ),
    ("sparsefw_compiled_cache_models", "gauge", "Compiled models currently cached"),
    (
        "sparsefw_eval_request_seconds",
        "histogram",
        "POST /jobs/:id/eval latency (sparse perplexity over the compiled model)",
    ),
    (
        "sparsefw_generate_request_seconds",
        "histogram",
        "POST /jobs/:id/generate latency (KV-cached batch=1 decode)",
    ),
    (
        "sparsefw_fleet_workers_registered_total",
        "counter",
        "Fleet workers ever registered via POST /fleet/workers",
    ),
    (
        "sparsefw_fleet_workers_live",
        "gauge",
        "Fleet workers currently within the heartbeat window",
    ),
    (
        "sparsefw_fleet_shards_dispatched_total",
        "counter",
        "Shard leases handed to fleet workers",
    ),
    (
        "sparsefw_fleet_shards_requeued_total",
        "counter",
        "Shards requeued after a worker death or failed result",
    ),
    (
        "sparsefw_fleet_handoff_bytes_total",
        "counter",
        "Staged hidden-state hand-off bytes shipped to workers",
    ),
];

/// Render the full [`METRIC_CATALOG`] in the Prometheus text
/// exposition format (one `HELP`/`TYPE` header per metric).
pub fn render_prometheus(state: &ServerState) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for &(name, kind, help) in METRIC_CATALOG {
        if kind == "histogram" {
            if let Some(h) = histogram_for(state, name) {
                h.render_prometheus(name, help, &mut out);
            }
            continue;
        }
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {}", scalar_for(state, name));
    }
    out
}

fn histogram_for<'a>(state: &'a ServerState, name: &str) -> Option<&'a Histogram> {
    let m = &state.metrics;
    match name {
        "sparsefw_queue_wait_seconds" => Some(&m.queue_wait),
        "sparsefw_job_wall_seconds" => Some(&m.job_wall),
        "sparsefw_phase_calib_seconds" => Some(&m.phase_calib),
        "sparsefw_phase_gram_seconds" => Some(&m.phase_gram),
        "sparsefw_phase_fw_seconds" => Some(&m.phase_fw),
        "sparsefw_phase_refine_seconds" => Some(&m.phase_refine),
        "sparsefw_phase_io_seconds" => Some(&m.phase_io),
        "sparsefw_eval_request_seconds" => Some(&m.infer_eval),
        "sparsefw_generate_request_seconds" => Some(&m.infer_generate),
        _ => None,
    }
}

fn scalar_for(state: &ServerState, name: &str) -> f64 {
    let m = &state.metrics;
    match name {
        "sparsefw_jobs_submitted_total" => m.jobs_submitted.load(Ordering::Relaxed) as f64,
        "sparsefw_jobs_done_total" => m.jobs_done.load(Ordering::Relaxed) as f64,
        "sparsefw_jobs_failed_total" => m.jobs_failed.load(Ordering::Relaxed) as f64,
        "sparsefw_jobs_propagated_total" => m.jobs_propagated.load(Ordering::Relaxed) as f64,
        "sparsefw_calib_cache_hits_total" => m.calib_hits.load(Ordering::Relaxed) as f64,
        "sparsefw_calib_cache_misses_total" => m.calib_misses.load(Ordering::Relaxed) as f64,
        "sparsefw_fw_iters_total" => m.fw_iters.load(Ordering::Relaxed) as f64,
        "sparsefw_workers" => m.workers as f64,
        "sparsefw_busy_workers" => m.busy_workers.load(Ordering::Relaxed) as f64,
        "sparsefw_queue_depth" => state.queue.depth() as f64,
        "sparsefw_uptime_seconds" => state.started.elapsed().as_secs_f64(),
        "sparsefw_peak_gram_bytes" => m.peak_gram_bytes.load(Ordering::Relaxed) as f64,
        "sparsefw_jobs_replayed_total" => m.jobs_replayed.load(Ordering::Relaxed) as f64,
        "sparsefw_jobs_shed_total" => m.jobs_shed.load(Ordering::Relaxed) as f64,
        "sparsefw_faults_injected_total" => crate::util::fault::injected_total() as f64,
        "sparsefw_models_compiled_total" => {
            state.compiled.compiled_total.load(Ordering::Relaxed) as f64
        }
        "sparsefw_compiled_cache_hits_total" => {
            state.compiled.hits.load(Ordering::Relaxed) as f64
        }
        "sparsefw_compiled_cache_misses_total" => {
            state.compiled.misses.load(Ordering::Relaxed) as f64
        }
        "sparsefw_compiled_cache_models" => state.compiled.len() as f64,
        "sparsefw_fleet_workers_registered_total" => state
            .fleet
            .as_ref()
            .map(|f| f.workers_registered.load(Ordering::Relaxed) as f64)
            .unwrap_or(0.0),
        "sparsefw_fleet_workers_live" => {
            state.fleet.as_ref().map(|f| f.live_workers() as f64).unwrap_or(0.0)
        }
        "sparsefw_fleet_shards_dispatched_total" => state
            .fleet
            .as_ref()
            .map(|f| f.shards_dispatched.load(Ordering::Relaxed) as f64)
            .unwrap_or(0.0),
        "sparsefw_fleet_shards_requeued_total" => state
            .fleet
            .as_ref()
            .map(|f| f.shards_requeued.load(Ordering::Relaxed) as f64)
            .unwrap_or(0.0),
        "sparsefw_fleet_handoff_bytes_total" => state
            .fleet
            .as_ref()
            .map(|f| f.handoff_bytes.load(Ordering::Relaxed) as f64)
            .unwrap_or(0.0),
        _ => 0.0,
    }
}

/// Monotonic server-wide counters (lock-free; read by `GET /metrics`).
pub struct Metrics {
    pub jobs_submitted: AtomicUsize,
    pub jobs_done: AtomicUsize,
    pub jobs_failed: AtomicUsize,
    pub calib_hits: AtomicUsize,
    pub calib_misses: AtomicUsize,
    pub busy_workers: AtomicUsize,
    /// Σ pruning wall time of completed jobs, in milliseconds (an
    /// integer so the accumulator stays a lock-free atomic).
    pub job_wall_ms: AtomicU64,
    /// Σ FW iterations executed by completed jobs — together with
    /// `job_wall_ms` this is the fleet-visible iterations/sec, the
    /// number the incremental FW engine moves.
    pub fw_iters: AtomicUsize,
    /// Completed jobs that ran staged (propagated) calibration
    /// (`--propagate block|layer`).
    pub jobs_propagated: AtomicUsize,
    /// High-water mark of per-job peak calibration-gram bytes across
    /// completed staged jobs.
    pub peak_gram_bytes: AtomicUsize,
    /// Jobs re-queued from the durable journal at startup.
    pub jobs_replayed: AtomicUsize,
    /// Submissions shed with 429 because the pending queue was full.
    pub jobs_shed: AtomicUsize,
    pub workers: usize,
    /// Submit→start latency distribution (seconds).
    pub queue_wait: Histogram,
    /// Per-job pruning wall-time distribution (seconds).
    pub job_wall: Histogram,
    /// Per-phase durations derived from trace spans via [`PhaseSink`]:
    /// calibration collection.
    pub phase_calib: Histogram,
    /// Gram assembly (staged pipeline).
    pub phase_gram: Histogram,
    /// Per-layer mask optimization (any method; one span per layer).
    pub phase_fw: Histogram,
    /// Refine post-pass stack (omitted when the stack is empty).
    pub phase_refine: Histogram,
    /// Result materialization + eval.
    pub phase_io: Histogram,
    /// `POST /jobs/:id/eval` request latency (seconds).
    pub infer_eval: Histogram,
    /// `POST /jobs/:id/generate` request latency (seconds).
    pub infer_generate: Histogram,
}

impl Metrics {
    fn new(workers: usize) -> Self {
        Self {
            jobs_submitted: AtomicUsize::new(0),
            jobs_done: AtomicUsize::new(0),
            jobs_failed: AtomicUsize::new(0),
            calib_hits: AtomicUsize::new(0),
            calib_misses: AtomicUsize::new(0),
            busy_workers: AtomicUsize::new(0),
            job_wall_ms: AtomicU64::new(0),
            fw_iters: AtomicUsize::new(0),
            jobs_propagated: AtomicUsize::new(0),
            peak_gram_bytes: AtomicUsize::new(0),
            jobs_replayed: AtomicUsize::new(0),
            jobs_shed: AtomicUsize::new(0),
            workers,
            queue_wait: Histogram::new(),
            job_wall: Histogram::new(),
            phase_calib: Histogram::new(),
            phase_gram: Histogram::new(),
            phase_fw: Histogram::new(),
            phase_refine: Histogram::new(),
            phase_io: Histogram::new(),
            infer_eval: Histogram::new(),
            infer_generate: Histogram::new(),
        }
    }

    /// The per-phase histogram a trace span named `name` feeds — the
    /// span names the pipeline emits (`calib`/`gram`/`fw`/`refine`/`io`).
    pub fn phase(&self, name: &str) -> Option<&Histogram> {
        match name {
            "calib" => Some(&self.phase_calib),
            "gram" => Some(&self.phase_gram),
            "fw" => Some(&self.phase_fw),
            "refine" => Some(&self.phase_refine),
            "io" => Some(&self.phase_io),
            _ => None,
        }
    }

    /// Fraction of pruning workers currently executing a job.
    pub fn utilization(&self) -> f64 {
        self.busy_workers.load(Ordering::Relaxed) as f64 / self.workers.max(1) as f64
    }

    /// Σ wall seconds of completed jobs.
    pub fn job_wall_secs(&self) -> f64 {
        self.job_wall_ms.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Aggregate FW iterations per second across completed jobs.
    pub fn fw_iters_per_sec(&self) -> f64 {
        let secs = self.job_wall_secs();
        if secs > 0.0 {
            self.fw_iters.load(Ordering::Relaxed) as f64 / secs
        } else {
            0.0
        }
    }
}

/// A completed job's serving artifact: the compiled sparse model plus
/// the held-out bin its `eval` requests score against.
#[derive(Clone)]
pub struct CompiledEntry {
    pub model: Arc<crate::model::compiled::CompiledModel>,
    pub test_bin: Arc<TokenBin>,
}

/// LRU cache of compiled serving models, keyed by job ID — the
/// inference sibling of the per-worker calibration memo.  Workers
/// compile once at job completion ([`worker_loop`]); `eval`/`generate`
/// handlers only ever read.  Hit/miss/compile counters feed
/// `GET /metrics`.
pub struct CompiledCache {
    cap: usize,
    /// Most-recently-used last.  A `Vec` scan is fine: `cap` is small
    /// (a handful of models dominate serving traffic).
    entries: std::sync::Mutex<Vec<(JobId, CompiledEntry)>>,
    pub compiled_total: AtomicUsize,
    pub hits: AtomicUsize,
    pub misses: AtomicUsize,
}

impl CompiledCache {
    fn new(cap: usize) -> Self {
        Self {
            cap: cap.max(1),
            entries: std::sync::Mutex::new(Vec::new()),
            compiled_total: AtomicUsize::new(0),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Park a freshly compiled model, evicting the least-recently-used
    /// entry beyond capacity.
    pub fn insert(&self, id: JobId, entry: CompiledEntry) {
        let mut entries = crate::util::sync::lock_recover(&self.entries);
        entries.retain(|(eid, _)| *eid != id);
        entries.push((id, entry));
        while entries.len() > self.cap {
            entries.remove(0);
        }
        self.compiled_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Look up a job's compiled model, refreshing its LRU position.
    pub fn get(&self, id: JobId) -> Option<CompiledEntry> {
        let mut entries = crate::util::sync::lock_recover(&self.entries);
        let Some(pos) = entries.iter().position(|(eid, _)| *eid == id) else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        };
        let (eid, entry) = entries.remove(pos);
        entries.push((eid, entry.clone()));
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(entry)
    }

    pub fn len(&self) -> usize {
        crate::util::sync::lock_recover(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Shared server state: the queue/registry plus metrics.
pub struct ServerState {
    pub queue: JobQueue,
    pub metrics: Metrics,
    pub started: Instant,
    /// Compiled serving models of completed jobs, LRU-bounded
    /// (`POST /jobs/:id/{eval,generate}` read from here).
    pub compiled: CompiledCache,
    /// Recent trace events keyed by correlation ID, for
    /// `GET /jobs/:id/trace` (bounded per correlation and overall).
    pub trace_ring: Arc<RingSink>,
    /// Durable job journal (`serve --journal DIR`); submissions and
    /// state transitions are appended here so a killed server replays
    /// its queue on restart.  `None` = in-memory only.
    pub journal: Option<Arc<journal::Journal>>,
    /// Token-bucket limiter shedding abusive `POST /jobs` rates with
    /// 429 before they reach the queue.
    pub limiter: ratelimit::RateLimiter,
    /// Fleet registry + shard table when this server is a coordinator
    /// (`serve --coordinator`); `None` on plain servers (fleet routes
    /// answer 409).
    pub fleet: Option<Arc<fleet::FleetState>>,
    /// Bearer token every mutating request must present (`None` = open).
    pub auth_token: Option<String>,
    stopping: AtomicBool,
}

impl ServerState {
    /// Shutdown initiated (accept loop and streamers should wind down).
    pub fn stopping(&self) -> bool {
        self.stopping.load(Ordering::Relaxed)
    }

    /// Stop intake and wake workers; see [`JobQueue::shutdown`] for the
    /// `drain_queued` semantics.
    pub fn begin_shutdown(&self, drain_queued: bool) {
        self.stopping.store(true, Ordering::Relaxed);
        self.queue.shutdown(drain_queued);
    }
}

/// Trace sink feeding the per-phase latency histograms: every closed
/// span named after a pipeline phase (`calib`/`gram`/`fw`/`refine`/`io`)
/// lands in the matching [`Histogram`].  Note the global tracer fans
/// out to every installed sink, so in a process hosting several servers
/// (tests) each `PhaseSink` sees spans from all of them.
struct PhaseSink {
    state: Arc<ServerState>,
}

impl TraceSink for PhaseSink {
    fn record(&self, ev: &TraceEvent) {
        if let Some(h) = self.state.metrics.phase(ev.name) {
            h.observe(ev.dur_us as f64 / 1e6);
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// A running server: resolved address + the threads behind it.  Dropping
/// the handle without [`ServerHandle::shutdown`] detaches the threads
/// (and leaves the trace sinks installed until process exit).
pub struct ServerHandle {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    sinks: Vec<Arc<dyn TraceSink>>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<ServerState> {
        &self.state
    }

    /// Block until the server shuts down (via `POST /shutdown` or
    /// [`ServerHandle::shutdown`] from another thread).
    pub fn join(mut self) {
        self.join_threads();
    }

    /// Initiate shutdown (cancelling queued jobs, finishing in-flight
    /// ones) and wait for every thread to exit.
    pub fn shutdown(mut self) {
        self.state.begin_shutdown(false);
        self.join_threads();
    }

    fn join_threads(&mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        // uninstall this server's trace sinks so later servers in the
        // same process (tests) don't keep feeding a dead ring/file
        for s in self.sinks.drain(..) {
            telemetry::remove_sink(&s);
        }
    }
}

pub struct Server;

impl Server {
    /// Bind `cfg.addr` and start one pruning worker per session plus the
    /// HTTP accept loop.  `sessions` must all serve the same underlying
    /// models — one per worker thread, each with its own memo.
    ///
    /// With [`ServerConfig::coordinator`] the local pool is replaced by
    /// one fleet dispatcher thread: jobs shard across worker processes
    /// registered over HTTP (see [`fleet`]), falling back to local
    /// execution when none are live.
    pub fn bind(cfg: &ServerConfig, mut sessions: Vec<PruneSession>) -> Result<ServerHandle> {
        ensure!(!sessions.is_empty(), "server needs at least one worker session");
        if cfg.coordinator {
            // the dispatcher is single-threaded (one fleet job at a
            // time; parallelism lives across worker processes)
            sessions.truncate(1);
        }
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?; // the accept loop polls the stop flag

        let trace_ring = Arc::new(RingSink::new(2048, 64));

        // durability: open the journal (creating the directory), then
        // replay it — every job that was Queued or Running when the
        // previous process died is re-queued before workers start, so
        // `kill -9` loses no accepted work
        let mut journal_arc = None;
        let mut replayed: Vec<journal::ReplayJob> = Vec::new();
        if let Some(dir) = &cfg.journal {
            let dir = std::path::Path::new(dir);
            replayed = journal::Journal::replay(dir)
                .with_context(|| format!("replaying job journal in {dir:?}"))?;
            journal_arc = Some(Arc::new(journal::Journal::open(dir)?));
        }

        let state = Arc::new(ServerState {
            queue: JobQueue::new(cfg.queue_capacity).with_history_cap(cfg.job_history_cap),
            metrics: Metrics::new(sessions.len()),
            started: Instant::now(),
            compiled: CompiledCache::new(cfg.compiled_cache_cap),
            trace_ring: trace_ring.clone(),
            journal: journal_arc,
            limiter: ratelimit::RateLimiter::for_submit(),
            fleet: cfg.coordinator.then(|| {
                Arc::new(fleet::FleetState::new(Duration::from_secs_f64(
                    cfg.fleet_timeout_secs.max(0.1),
                )))
            }),
            auth_token: cfg.auth_token.clone(),
            stopping: AtomicBool::new(false),
        });
        for job in replayed {
            state.queue.restore(job.id, job.spec, job.priority, &job.corr_id);
            state.metrics.jobs_replayed.fetch_add(1, Ordering::Relaxed);
        }
        let n_replayed = state.metrics.jobs_replayed.load(Ordering::Relaxed);
        if n_replayed > 0 {
            crate::info!("journal replay: re-queued {n_replayed} unfinished job(s)");
        }

        // install this server's trace sinks (removed in join_threads):
        // the ring behind GET /jobs/:id/trace, the phase-histogram
        // feeder, and optionally an NDJSON file (--trace-out)
        let mut sinks: Vec<Arc<dyn TraceSink>> = vec![trace_ring];
        sinks.push(Arc::new(PhaseSink { state: state.clone() }));
        if let Some(path) = &cfg.trace_out {
            let nd = NdjsonSink::create(std::path::Path::new(path))
                .with_context(|| format!("opening --trace-out {path}"))?;
            sinks.push(Arc::new(nd));
        }
        for s in &sinks {
            telemetry::add_sink(s.clone());
        }

        let workers = sessions
            .into_iter()
            .enumerate()
            .map(|(i, mut session)| {
                session.set_calib_cache_capacity(cfg.calib_cache_cap);
                // the journal directory doubles as the checkpoint root:
                // replayed jobs resume from their verified units
                if let Some(dir) = &cfg.journal {
                    session.set_checkpoint_root(dir);
                }
                session.set_job_timeout(cfg.job_timeout_secs);
                let state = state.clone();
                if cfg.coordinator {
                    std::thread::Builder::new()
                        .name("sparsefw-dispatcher".into())
                        .spawn(move || fleet::coordinator::dispatcher_loop(state, session))
                        .context("spawning fleet dispatcher thread")
                } else {
                    std::thread::Builder::new()
                        .name(format!("sparsefw-worker-{i}"))
                        .spawn(move || worker_loop(state, session, i))
                        .with_context(|| format!("spawning worker thread {i}"))
                }
            })
            .collect::<Result<Vec<_>>>()?;

        let accept = {
            let state = state.clone();
            let conn_threads = cfg.conn_threads;
            std::thread::Builder::new()
                .name("sparsefw-accept".into())
                .spawn(move || accept_loop(listener, state, conn_threads))
                .context("spawning accept thread")?
        };

        if cfg.coordinator {
            crate::info!(
                "sparsefw serve: coordinator mode (jobs shard across registered fleet workers)"
            );
        }
        crate::info!("sparsefw serve: listening on {addr} ({} workers)", state.metrics.workers);
        Ok(ServerHandle { addr, state, accept: Some(accept), workers, sinks })
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>, conn_threads: usize) {
    let pool = TaskPool::new(conn_threads);
    // keep serving HTTP through a shutdown until the backlog and every
    // in-flight job are finished — clients draining `--wait`ed jobs must
    // still be able to fetch their results — then linger briefly so the
    // final poll after the last job lands.
    let mut drained_at: Option<Instant> = None;
    loop {
        if state.stopping() {
            let (queued, running, ..) = state.queue.state_counts();
            if queued == 0 && running == 0 {
                let t = *drained_at.get_or_insert_with(Instant::now);
                if t.elapsed() > Duration::from_millis(750) {
                    break;
                }
            } else {
                drained_at = None;
            }
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                // fault site: a faulty accept path must shed the one
                // connection, never the accept thread (contained so an
                // injected panic can't make the server unreachable)
                match catch_unwind(|| crate::util::fault::hit("net.accept")) {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        crate::warnlog!("dropping connection: {e:#}");
                        continue;
                    }
                    Err(_) => {
                        crate::warnlog!("injected panic at net.accept contained");
                        continue;
                    }
                }
                let state = state.clone();
                pool.execute(move || api::handle_connection(stream, state));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(15));
            }
            Err(e) => {
                crate::warnlog!("accept error: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
    // dropping the pool drains in-flight connection handlers
}

/// One pruning worker: pop → execute (streaming per-layer progress into
/// the job record) → report, until the queue shuts down and drains.
fn worker_loop(state: Arc<ServerState>, mut session: PruneSession, worker: usize) {
    let (mut hits_seen, mut misses_seen) = session.calib_stats();
    while let Some((id, spec)) = state.queue.pop_blocking(worker) {
        state.metrics.busy_workers.fetch_add(1, Ordering::Relaxed);
        // the freshly-popped record carries the correlation ID and the
        // submit timestamp (queue-wait latency)
        let rec = state.queue.get(id);
        let corr = rec.as_ref().map(|r| r.corr_id.clone()).unwrap_or_default();
        if let Some(r) = &rec {
            state.metrics.queue_wait.observe(r.queued_secs());
        }
        let _corr_guard = telemetry::with_correlation(&corr);
        crate::info!("worker {worker}: job {id} starting ({})", spec.label());
        if let Some(j) = &state.journal {
            j.record_state(id, "running");
        }
        let progress_state = state.clone();
        session.on_progress(move |e| progress_state.queue.push_event(id, e.clone()));
        // a panicking method (registered pruners are open code) must
        // fail THIS job, not unwind the worker thread: an unwound
        // worker would leave the job wedged in Running forever and
        // poison every registry lock it held.  The `worker.panic` fault
        // site fires inside the contained region for exactly that
        // reason — injected panics prove the containment.
        let outcome = {
            let _sp = crate::span!("job", id = id, worker = worker);
            match catch_unwind(AssertUnwindSafe(|| {
                crate::util::fault::hit("worker.panic")?;
                session.execute(&spec)
            })) {
                Ok(res) => res,
                Err(payload) => Err(anyhow::anyhow!(
                    "worker panicked: {}",
                    panic_message(payload.as_ref())
                )),
            }
        };
        session.clear_progress();

        let (hits, misses) = session.calib_stats();
        state
            .metrics
            .calib_hits
            .fetch_add(hits - hits_seen, Ordering::Relaxed);
        state
            .metrics
            .calib_misses
            .fetch_add(misses - misses_seen, Ordering::Relaxed);
        (hits_seen, misses_seen) = (hits, misses);

        match outcome {
            Ok(res) => {
                let summary = JobSummary::from_result(&res);
                crate::info!(
                    "worker {worker}: job {id} done in {:.2}s (Σ err {:.4e}{})",
                    summary.wall_seconds,
                    summary.total_err,
                    summary
                        .iters_per_sec()
                        .map(|r| format!(", {r:.0} FW iters/s"))
                        .unwrap_or_default()
                );
                state.metrics.jobs_done.fetch_add(1, Ordering::Relaxed);
                state.metrics.job_wall.observe(summary.wall_seconds);
                state
                    .metrics
                    .job_wall_ms
                    .fetch_add((summary.wall_seconds * 1e3) as u64, Ordering::Relaxed);
                state.metrics.fw_iters.fetch_add(summary.fw_iters, Ordering::Relaxed);
                if summary.calib_policy.is_some() {
                    state.metrics.jobs_propagated.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(b) = summary.peak_gram_bytes {
                    state.metrics.peak_gram_bytes.fetch_max(b, Ordering::Relaxed);
                }
                // compile the pruned model once into packed sparse
                // formats so eval/generate requests serve straight
                // from the cache — before finish() so a client that
                // `--wait`ed on the job never races the compile
                match compile_for_serving(&mut session, &res) {
                    Ok(entry) => {
                        crate::info!(
                            "worker {worker}: job {id} compiled for serving ({})",
                            entry.model.summary()
                        );
                        state.compiled.insert(id, entry);
                    }
                    Err(e) => {
                        crate::warnlog!(
                            "worker {worker}: job {id}: serving compile failed: {e:#}"
                        );
                    }
                }
                state.queue.finish(id, Ok(summary));
                if let Some(j) = &state.journal {
                    j.record_state(id, "done");
                }
            }
            Err(e) => {
                crate::warnlog!("worker {worker}: job {id} failed: {e:#}");
                state.metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                state.queue.finish(id, Err(format!("{e:#}")));
                if let Some(j) = &state.journal {
                    j.record_state(id, "failed");
                }
            }
        }
        state.metrics.busy_workers.fetch_sub(1, Ordering::Relaxed);
    }
    crate::debuglog!("worker {worker}: exiting");
}

/// Build a completed job's serving artifact: compile the pruned model
/// into per-layer packed formats (auto choice) and capture the
/// held-out test bin its `eval` requests score against.
fn compile_for_serving(
    session: &mut PruneSession,
    res: &crate::coordinator::JobResult,
) -> Result<CompiledEntry> {
    let model = session.model(&res.spec.model)?;
    let compiled = res.prune.compile(model, crate::model::compiled::SparseFormat::Auto)?;
    let test_bin = session.test_bin()?.clone();
    Ok(CompiledEntry { model: Arc::new(compiled), test_bin: Arc::new(test_bin) })
}

/// Best-effort human-readable panic payload (`panic!("..")` produces a
/// `&str` or `String`; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Workspace-free demo sessions
// ---------------------------------------------------------------------------

/// In-memory sessions over one shared randomly-initialized tiny model
/// (`"demo"`), one per worker — lets `sparsefw serve --demo`, the smoke
/// test, and the example run with no artifacts workspace.
pub fn demo_sessions(workers: usize) -> Vec<PruneSession> {
    let cfg = GptConfig {
        name: "demo".into(),
        vocab_size: 256,
        seq_len: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
    };
    let model = crate::model::testutil::random_model(&cfg, 1);
    let bin = TokenBin::from_tokens(crate::data::corpus::generate(6, 8192));
    (0..workers.max(1))
        .map(|_| {
            let mut models = BTreeMap::new();
            models.insert("demo".to_string(), model.clone());
            PruneSession::in_memory(models, bin.clone(), bin.clone())
        })
        .collect()
}

/// One [`PruneSession`] per worker over the same artifacts workspace.
pub fn workspace_sessions(dir: Option<&str>, workers: usize) -> Result<Vec<PruneSession>> {
    (0..workers.max(1))
        .map(|_| match dir {
            Some(d) => PruneSession::open(d),
            None => PruneSession::open_default(),
        })
        .collect()
}

/// Validate that a submitted spec can run on this server's sessions —
/// callers get a 400 instead of a deferred `Failed` job for the obvious
/// mistakes (unknown model names are caught at execute time instead,
/// since sessions live on the worker threads).
pub(crate) fn validate_spec(spec: &JobSpec) -> Result<()> {
    ensure!(spec.calib_samples > 0, "calib_samples must be positive");
    ensure!(!spec.model.is_empty(), "model name must be non-empty");
    // reject the combination eagerly (400) instead of a deferred Failed
    // job: OWL needs model-wide dense grams, staged runs stream O(block)
    ensure!(
        !(spec.calib_policy.is_propagated()
            && matches!(spec.allocation, crate::coordinator::Allocation::Owl { .. })),
        "OWL allocation requires dense calibration (--propagate off)"
    );
    Ok(())
}
