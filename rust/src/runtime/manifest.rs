//! Typed view over `artifacts/manifest.json` — the contract between the
//! python compile path (`python/compile/aot.py`) and the rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::model::GptConfig;
use crate::util::json::{self, Json};

#[derive(Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub json: Json,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let json = json::parse(&src).context("parsing manifest.json")?;
        ensure!(
            json.at(&["version"]).as_usize() == Some(1),
            "unsupported manifest version"
        );
        Ok(Self { root: artifacts_dir.to_path_buf(), json })
    }

    fn path_of(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    // ---- models -----------------------------------------------------------

    pub fn model_names(&self) -> Vec<String> {
        self.json
            .at(&["models"])
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    fn model_entry(&self, name: &str) -> Result<&Json> {
        let e = self.json.at(&["models", name]);
        if e.is_null() {
            bail!(
                "model {name:?} not in manifest (available: {:?})",
                self.model_names()
            );
        }
        Ok(e)
    }

    pub fn model_config(&self, name: &str) -> Result<GptConfig> {
        GptConfig::from_json(self.model_entry(name)?.at(&["config"]))
    }

    pub fn checkpoint_path(&self, name: &str) -> Result<PathBuf> {
        let rel = self
            .model_entry(name)?
            .at(&["checkpoint"])
            .as_str()
            .context("manifest: missing checkpoint")?;
        Ok(self.path_of(rel))
    }

    pub fn model_fwd_hlo(&self, name: &str) -> Result<PathBuf> {
        let rel = self
            .model_entry(name)?
            .at(&["fwd_hlo"])
            .as_str()
            .context("manifest: missing fwd_hlo")?;
        Ok(self.path_of(rel))
    }

    pub fn eval_batch(&self, name: &str) -> Result<usize> {
        self.model_entry(name)?
            .at(&["eval_batch"])
            .as_usize()
            .context("manifest: missing eval_batch")
    }

    /// Dense test perplexity recorded at build time (python side) —
    /// cross-checked against the rust evaluator in integration tests.
    pub fn dense_test_ppl(&self, name: &str) -> Option<f64> {
        self.model_entry(name).ok()?.at(&["dense_test_ppl"]).as_f64()
    }

    // ---- kernels ----------------------------------------------------------

    fn kernel_path(&self, group: &[&str], key: &str) -> Result<PathBuf> {
        let mut path = vec!["kernels"];
        path.extend_from_slice(group);
        path.push(key);
        let rel = self
            .json
            .at(&path)
            .as_str()
            .with_context(|| format!("manifest: missing kernel {group:?}/{key}"))?;
        Ok(self.path_of(rel))
    }

    pub fn fw_grad_hlo(&self, d_out: usize, d_in: usize) -> Result<PathBuf> {
        self.kernel_path(&["fw_grad"], &format!("{d_out}x{d_in}"))
    }

    pub fn objective_hlo(&self, d_out: usize, d_in: usize) -> Result<PathBuf> {
        self.kernel_path(&["objective"], &format!("{d_out}x{d_in}"))
    }

    pub fn fw_chunk_hlo(&self, d_out: usize, d_in: usize) -> Result<(PathBuf, usize)> {
        let iters = self
            .json
            .at(&["kernels", "fw_chunk", "iters"])
            .as_usize()
            .context("manifest: missing fw_chunk.iters")?;
        let p = self.kernel_path(&["fw_chunk", "paths"], &format!("{d_out}x{d_in}"))?;
        Ok((p, iters))
    }

    pub fn gram_hlo(&self, d_in: usize) -> Result<(PathBuf, usize)> {
        let chunk = self
            .json
            .at(&["kernels", "gram", "chunk"])
            .as_usize()
            .context("manifest: missing gram.chunk")?;
        let p = self.kernel_path(&["gram", "paths"], &format!("{d_in}"))?;
        Ok((p, chunk))
    }

    // ---- data -------------------------------------------------------------

    pub fn data_bin(&self, split: &str) -> Result<PathBuf> {
        let rel = self
            .json
            .at(&["data", split])
            .as_str()
            .with_context(|| format!("manifest: missing data split {split}"))?;
        Ok(self.path_of(rel))
    }

    pub fn seq_len(&self) -> usize {
        self.json.at(&["data", "seq_len"]).as_usize().unwrap_or(128)
    }

    pub fn vocab(&self) -> usize {
        self.json.at(&["data", "vocab"]).as_usize().unwrap_or(256)
    }

    /// Golden corpus tokens (seed → first-64 tokens) for the python/rust
    /// generator parity test.
    pub fn golden_corpus(&self) -> Vec<(u64, Vec<u8>)> {
        let Some(obj) = self.json.at(&["golden", "corpus"]).as_obj() else {
            return Vec::new();
        };
        obj.iter()
            .filter_map(|(seed, toks)| {
                let seed: u64 = seed.parse().ok()?;
                let toks = toks
                    .as_arr()?
                    .iter()
                    .map(|t| t.as_usize().unwrap_or(0) as u8)
                    .collect();
                Some((seed, toks))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let src = r#"{
  "version": 1,
  "models": {"m": {
    "config": {"name": "m", "vocab_size": 64, "seq_len": 32, "d_model": 16,
               "n_layers": 1, "n_heads": 2, "d_ff": 32},
    "checkpoint": "m.safetensors", "fwd_hlo": "model_fwd_m.hlo.txt",
    "eval_batch": 4, "dense_test_ppl": 12.5}},
  "kernels": {
    "fw_grad": {"48x16": "fw_grad_48x16.hlo.txt"},
    "objective": {"48x16": "objective_48x16.hlo.txt"},
    "fw_chunk": {"iters": 20, "paths": {"48x16": "fw_chunk_48x16_c20.hlo.txt"}},
    "gram": {"chunk": 1024, "paths": {"16": "gram_16x1024.hlo.txt"}}},
  "data": {"train": "train.bin", "seq_len": 32, "vocab": 64},
  "golden": {"corpus": {"1": [3, 1, 2]}}
}"#;
        std::fs::write(dir.join("manifest.json"), src).unwrap();
    }

    #[test]
    fn parses_all_sections() {
        let dir = std::env::temp_dir().join("sparsefw_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.model_names(), vec!["m".to_string()]);
        let cfg = m.model_config("m").unwrap();
        assert_eq!(cfg.d_model, 16);
        assert!(m.checkpoint_path("m").unwrap().ends_with("m.safetensors"));
        assert_eq!(m.eval_batch("m").unwrap(), 4);
        assert_eq!(m.dense_test_ppl("m"), Some(12.5));
        assert!(m.fw_grad_hlo(48, 16).is_ok());
        assert!(m.fw_grad_hlo(99, 16).is_err());
        let (p, iters) = m.fw_chunk_hlo(48, 16).unwrap();
        assert!(p.ends_with("fw_chunk_48x16_c20.hlo.txt"));
        assert_eq!(iters, 20);
        let (_, chunk) = m.gram_hlo(16).unwrap();
        assert_eq!(chunk, 1024);
        assert_eq!(m.golden_corpus(), vec![(1u64, vec![3u8, 1, 2])]);
        assert!(m.model_config("nope").is_err());
    }
}
