//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! from the rust hot path — the only place the Layer-1/Layer-2 compute
//! runs at request time (python is never invoked).
//!
//! Pattern (see /opt/xla-example/load_hlo):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`.  Compiled executables are cached
//! per artifact file; all artifacts return 1-tuples (lowered with
//! `return_tuple=True`).

pub mod manifest;
/// PJRT/XLA binding surface.  The offline build ships the [`xla`] stub
/// (see its module docs); with real bindings available this declaration
/// is the only line that changes.
pub mod xla;

pub use manifest::Manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{ensure, Context, Result};

use crate::pruner::sparsefw::FwKernels;
use crate::tensor::Mat;

pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
}

/// Mat (row-major f32) → XLA literal of shape (rows, cols).
pub fn mat_to_literal(m: &Mat) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// Rank-2 f32 literal → Mat.
pub fn literal_to_mat(l: &xla::Literal) -> Result<Mat> {
    let shape = l.array_shape()?;
    let dims = shape.dims();
    ensure!(dims.len() == 2, "expected rank-2 literal, got {:?}", dims);
    let data = l.to_vec::<f32>()?;
    Ok(Mat::from_vec(dims[0] as usize, dims[1] as usize, data))
}

impl PjrtRuntime {
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::debuglog!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn executable(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        crate::debuglog!("compiled {:?} in {:.2}s", path.file_name().unwrap(), t0.elapsed().as_secs_f64());
        self.cache.lock().unwrap().insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    fn run1(&self, path: &Path, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self.executable(path)?;
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }

    // ---- kernel entry points ------------------------------------------

    /// ∇L(M) via the AOT Pallas `fw_grad` kernel.
    pub fn fw_grad(&self, w: &Mat, m: &Mat, g: &Mat, h: &Mat) -> Result<Mat> {
        let path = self.manifest.fw_grad_hlo(w.rows, w.cols)?;
        let out = self.run1(
            &path,
            &[
                mat_to_literal(w)?,
                mat_to_literal(m)?,
                mat_to_literal(g)?,
                mat_to_literal(h)?,
            ],
        )?;
        literal_to_mat(&out)
    }

    /// L(M) via the AOT Pallas `objective` kernel.
    pub fn objective(&self, w: &Mat, m: &Mat, g: &Mat) -> Result<f64> {
        let path = self.manifest.objective_hlo(w.rows, w.cols)?;
        let out = self.run1(
            &path,
            &[mat_to_literal(w)?, mat_to_literal(m)?, mat_to_literal(g)?],
        )?;
        Ok(out.to_vec::<f32>()?[0] as f64)
    }

    /// G ← G + X·Xᵀ via the AOT Pallas `gram` kernel.  `x` is
    /// (d_in, B≤chunk); the chunk is zero-padded (zero columns contribute
    /// nothing to XXᵀ).
    pub fn gram_acc(&self, g: &Mat, x: &Mat) -> Result<Mat> {
        let (path, chunk) = self.manifest.gram_hlo(x.rows)?;
        ensure!(x.cols <= chunk, "gram chunk too large: {} > {chunk}", x.cols);
        let xp = if x.cols == chunk {
            x.clone()
        } else {
            let mut xp = Mat::zeros(x.rows, chunk);
            for i in 0..x.rows {
                xp.row_mut(i)[..x.cols].copy_from_slice(x.row(i));
            }
            xp
        };
        let out = self.run1(&path, &[mat_to_literal(g)?, mat_to_literal(&xp)?])?;
        literal_to_mat(&out)
    }

    /// Fused FW chunk (see `python/compile/fw_step.py::fw_chunk_fn`).
    /// Returns the updated free-coordinate relaxed mask and the chunk
    /// length executed.
    pub fn fw_chunk(
        &self,
        w: &Mat,
        m: &Mat,
        g: &Mat,
        h: &Mat,
        fixed: &Mat,
        k_new: usize,
        t0: usize,
    ) -> Result<(Mat, usize)> {
        let (path, iters) = self.manifest.fw_chunk_hlo(w.rows, w.cols)?;
        let out = self.run1(
            &path,
            &[
                mat_to_literal(w)?,
                mat_to_literal(m)?,
                mat_to_literal(g)?,
                mat_to_literal(h)?,
                mat_to_literal(fixed)?,
                xla::Literal::scalar(k_new as f32),
                xla::Literal::scalar(t0 as f32),
            ],
        )?;
        Ok((literal_to_mat(&out)?, iters))
    }

    // ---- model forward --------------------------------------------------

    /// Parameter literals in the canonical AOT order for a model.
    pub fn param_literals(&self, model: &crate::model::Gpt) -> Result<Vec<xla::Literal>> {
        model
            .cfg
            .param_names()
            .iter()
            .map(|n| {
                let m = model.mat(n);
                // rank-1 params were stored as (1, d) mats; the AOT
                // signature wants their original (d,) shape.
                if n.ends_with("_g") || n.ends_with("_b") {
                    Ok(xla::Literal::vec1(&m.data))
                } else {
                    mat_to_literal(m)
                }
            })
            .collect()
    }

    /// Run the AOT `model_fwd` executable on one batch of token ids.
    /// `tokens` must have exactly `eval_batch` rows (pad externally);
    /// returns logits as (batch·seq_len, vocab).
    pub fn model_fwd(
        &self,
        model_name: &str,
        tokens: &[Vec<u8>],
        params: &[xla::Literal],
    ) -> Result<Mat> {
        let path = self.manifest.model_fwd_hlo(model_name)?;
        let batch = self.manifest.eval_batch(model_name)?;
        let seq = self.manifest.seq_len();
        ensure!(tokens.len() == batch, "expected {batch} sequences, got {}", tokens.len());
        let mut flat = Vec::with_capacity(batch * seq);
        for t in tokens {
            ensure!(t.len() == seq, "sequence length {} != {seq}", t.len());
            flat.extend(t.iter().map(|&b| b as i32));
        }
        let tok_lit = xla::Literal::vec1(&flat).reshape(&[batch as i64, seq as i64])?;

        let mut args = Vec::with_capacity(1 + params.len());
        args.push(tok_lit);
        // cheap literal clones are not exposed; re-borrow via Borrow impl
        let exe = self.executable(&path)?;
        let arg_refs: Vec<&xla::Literal> = std::iter::once(&args[0]).chain(params.iter()).collect();
        let result = exe.execute::<&xla::Literal>(&arg_refs)?[0][0].to_literal_sync()?;
        let logits = result.to_tuple1()?;
        let shape = logits.array_shape()?;
        let dims = shape.dims();
        ensure!(dims.len() == 3, "logits must be rank-3");
        let (b, l, v) = (dims[0] as usize, dims[1] as usize, dims[2] as usize);
        let data = logits.to_vec::<f32>()?;
        Ok(Mat::from_vec(b * l, v, data))
    }
}

/// [`FwKernels`] backend running the AOT Pallas kernels through PJRT.
pub struct PjrtKernels<'a> {
    pub runtime: &'a PjrtRuntime,
    /// Fall back to the fused chunk executable when possible.
    pub use_chunk: bool,
}

impl<'a> PjrtKernels<'a> {
    pub fn new(runtime: &'a PjrtRuntime) -> Self {
        Self { runtime, use_chunk: true }
    }
}

impl FwKernels for PjrtKernels<'_> {
    fn fw_grad(&self, w: &Mat, m: &Mat, g: &Mat, h: &Mat) -> Result<Mat> {
        self.runtime.fw_grad(w, m, g, h)
    }

    fn objective(&self, w: &Mat, m: &Mat, g: &Mat) -> Result<f64> {
        self.runtime.objective(w, m, g)
    }

    fn fw_chunk(
        &self,
        w: &Mat,
        m: &Mat,
        g: &Mat,
        h: &Mat,
        fixed: &Mat,
        k_new: usize,
        t0: usize,
        max_iters: usize,
    ) -> Result<Option<(Mat, usize)>> {
        if !self.use_chunk {
            return Ok(None);
        }
        // Only run the fused path when a whole chunk fits in the budget.
        let Ok((_, iters)) = self.runtime.manifest().fw_chunk_hlo(w.rows, w.cols) else {
            return Ok(None);
        };
        if max_iters < iters {
            return Ok(None);
        }
        let (m_next, done) = self.runtime.fw_chunk(w, m, g, h, fixed, k_new, t0)?;
        Ok(Some((m_next, done)))
    }
}
