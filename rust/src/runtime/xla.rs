//! Offline shim for the PJRT/XLA bindings.
//!
//! The offline crate registry has no `xla` crate, so this module
//! provides the exact API surface `runtime` consumes.  Every entry
//! point that would touch a real PJRT client fails at
//! [`PjRtClient::cpu`], which means `Workspace::runtime()` returns a
//! clean error and every PJRT-gated flow (integration tests, the
//! `selfcheck` subcommand, PJRT job specs) reports "runtime
//! unavailable" instead of failing deep inside a kernel call.  Swap
//! this module for the real bindings by replacing the `pub mod xla;`
//! declaration in `runtime/mod.rs` with an external dependency; no
//! other file changes.

use std::path::Path;

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT/XLA bindings unavailable in this build (offline registry has no `xla` crate); \
     use the native backend";

/// Stand-in for a rank-N device literal.  Carries no data: nothing can
/// execute against the stub client, so the values are never read.
#[derive(Clone, Debug, Default)]
pub struct Literal;

/// Shape metadata of an array literal.
#[derive(Clone, Debug, Default)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar(_v: f32) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}

/// Parsed HLO module text (never materialized by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Result buffer of an execution (unreachable through the stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// The single failure point: creating a client reports the missing
    /// bindings, so no executable path past this can be reached.
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> &'static str {
        "stub"
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}
