//! # SparseFW — pruning LLMs via Frank-Wolfe
//!
//! Rust + JAX + Pallas reproduction of *"Don't Be Greedy, Just Relax!
//! Pruning LLMs via Frank-Wolfe"* (Roux, Zimmer, d'Aspremont, Pokutta,
//! 2025).  Layer map (DESIGN.md):
//!
//! * Layer 1 — Pallas kernels (`python/compile/kernels/`), AOT-lowered.
//! * Layer 2 — JAX model + FW step (`python/compile/`), AOT-lowered.
//! * Layer 3 — this crate: the pruning coordinator. Python never runs at
//!   request time; HLO artifacts execute through PJRT (`runtime`).
//!
//! The coordinator's public API is declarative: a
//! [`coordinator::JobSpec`] describes one pruning run as data (model,
//! method, [`coordinator::Allocation`], backend, calibration, tracing
//! and eval options; JSON round-trippable), and a
//! [`coordinator::PruneSession`] executes specs against an artifacts
//! workspace with memoized models, calibrations, and compiled PJRT
//! executables:
//!
//! ```no_run
//! use sparsefw::prelude::*;
//!
//! let mut session = PruneSession::open_default()?;
//! let spec = JobSpec {
//!     model: "tiny".into(),
//!     method: PruneMethod::Wanda,
//!     allocation: Allocation::Uniform(SparsityPattern::PerRow { sparsity: 0.6 }),
//!     eval: Some(EvalSpec::default()),
//!     ..Default::default()
//! };
//! let result = session.execute(&spec)?;
//! println!("Σ err {:.3e}", result.total_err());
//! # anyhow::Ok(())
//! ```

pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod pruner;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

pub mod prelude {
    pub use crate::calib::Calibration;
    pub use crate::config::{Backend, Workspace};
    pub use crate::coordinator::{
        Allocation, EvalSpec, JobResult, JobSpec, PrunePipeline, PruneSession,
    };
    pub use crate::model::{Gpt, GptConfig};
    pub use crate::pruner::{PruneMethod, SparseFwConfig, SparsityPattern, Warmstart};
    pub use crate::tensor::Mat;
}
