//! # SparseFW — pruning LLMs via Frank-Wolfe
//!
//! Rust + JAX + Pallas reproduction of *"Don't Be Greedy, Just Relax!
//! Pruning LLMs via Frank-Wolfe"* (Roux, Zimmer, d'Aspremont, Pokutta,
//! 2025).  Layer map (DESIGN.md):
//!
//! * Layer 1 — Pallas kernels (`python/compile/kernels/`), AOT-lowered.
//! * Layer 2 — JAX model + FW step (`python/compile/`), AOT-lowered.
//! * Layer 3 — this crate: the pruning coordinator. Python never runs at
//!   request time; HLO artifacts execute through PJRT (`runtime`).
//!
//! The coordinator's public API is declarative: a
//! [`coordinator::JobSpec`] describes one pruning run as data (model,
//! [`pruner::Method`], [`coordinator::Allocation`], backend,
//! calibration, refinement, tracing and eval options; JSON
//! round-trippable), and a [`coordinator::PruneSession`] executes specs
//! against an artifacts workspace with memoized models, calibrations,
//! and compiled PJRT executables:
//!
//! ```no_run
//! use sparsefw::prelude::*;
//!
//! let mut session = PruneSession::open_default()?;
//! let spec = JobSpec {
//!     model: "tiny".into(),
//!     method: Method::wanda(),
//!     allocation: Allocation::Uniform(SparsityPattern::PerRow { sparsity: 0.6 }),
//!     refine: vec![RefinePass::swaps()],
//!     eval: Some(EvalSpec::default()),
//!     ..Default::default()
//! };
//! let result = session.execute(&spec)?;
//! println!("Σ err {:.3e}", result.total_err());
//! # anyhow::Ok(())
//! ```
//!
//! ## The open method layer
//!
//! Methods live behind the object-safe [`pruner::LayerPruner`] trait
//! ([`pruner::LayerCtx`] in, [`pruner::LayerPruneOutput`] out) and the
//! [`pruner::MethodRegistry`] — the *single source of truth* that CLI
//! parsing, JobSpec JSON, server-side submit validation, the
//! `GET /methods` / `sparsefw methods` listings, and the
//! `table1_methods` bench all iterate.  Composable
//! [`pruner::RefinePass`]es (SparseSwaps-style 1-swaps, least-squares
//! weight update) bolt onto *any* method's output.
//!
//! ### Adding a pruning method
//!
//! 1. Implement [`pruner::LayerPruner`] — one struct, one
//!    `prune_layer(&LayerCtx) -> Result<LayerPruneOutput>`:
//!
//! ```no_run
//! use sparsefw::prelude::*;
//! use sparsefw::pruner::{FwKernels, LayerCtx, LayerPruneOutput, LayerPruner};
//! use sparsefw::pruner::registry::MethodRegistration;
//! use sparsefw::pruner::saliency::saliency_mask;
//!
//! struct RandomSaliency;
//!
//! impl LayerPruner for RandomSaliency {
//!     fn name(&self) -> &str { "random" }
//!     fn prune_layer(&self, ctx: &LayerCtx) -> anyhow::Result<LayerPruneOutput> {
//!         // any scores → greedy top-k under the requested pattern
//!         let scores = Mat::from_fn(ctx.w.rows, ctx.w.cols, |i, j| {
//!             (((i * 31 + j * 17) % 97) as f32) / 97.0
//!         });
//!         let mask = saliency_mask(&scores, ctx.pattern);
//!         let obj = ctx.kernels.objective(ctx.w, &mask, ctx.g)?;
//!         Ok(LayerPruneOutput {
//!             mask, obj, warm_obj: None, new_weights: None,
//!             trace: None, convergence: None, fw_iters: 0,
//!             refine_obj_delta: None,
//!         })
//!     }
//! }
//!
//! // 2. Register it — CLI (`--method random`), JobSpec JSON
//! //    ({"kind": "random"}), server submits, `sparsefw methods`,
//! //    and `--refine` post-passes now all work, with no further code.
//! MethodRegistry::global().register(MethodRegistration::new(
//!     "random",
//!     || Method::from_pruner(RandomSaliency),
//!     |_json| Ok(Method::from_pruner(RandomSaliency)),
//! ));
//! ```
//!
//! ## Calibration pipelines
//!
//! Two interchangeable calibration pipelines feed the per-layer
//! objective `‖WX − (W⊙M)X‖²` ([`calib::CalibPolicy`], `--propagate`):
//!
//! * **One-shot dense** (`--propagate off`, the default and the paper's
//!   protocol): one forward pass over the dense model collects all
//!   `4·n_layers` grams at once ([`calib::Calibration`]); layers then
//!   prune independently and layer-parallel ([`coordinator`]'s
//!   `run_layers`).  O(model) calibration memory.
//! * **Staged block-sequential** (`--propagate block|layer`): the
//!   forward pass is a resumable stepper ([`model::forward::forward_embed`]
//!   → [`model::forward::forward_block`] → [`model::forward::forward_head`])
//!   driven by a streaming [`calib::CalibState`]:
//!
//!   ```text
//!   embed ─▶ │ grams(b) ─▶ prune block b ─▶ re-forward masked block b │ ─▶ b+1 … ─▶ head
//!            └─────────────── one block's grams live at a time ───────────────┘
//!   ```
//!
//!   Each block's grams are computed from the *pruned-so-far* hidden
//!   states (SparseGPT-style pruned-activation propagation, so
//!   compounding error is priced into every layer's objective), and
//!   peak calibration memory drops from O(model) to O(block) —
//!   `block` keeps the 4-way intra-block layer parallelism, `layer`
//!   additionally recomputes the `wo`/`wdown` grams after `wqkv`/`wup`
//!   are pruned.  Sessions memoize only the method-independent
//!   token-sample/embed prefix ([`calib::EmbedPrefix`]).
//!
//! The native SparseFW hot loop has two interchangeable engines
//! ([`pruner::FwEngine`], `--fw-engine`): the default **incremental**
//! sparse-vertex engine ([`pruner::fw_engine`]) maintains
//! `P_t = (W⊙M_t)·G` across FW iterations — each step mixes in a
//! k-sparse binary vertex V, so `P_{t+1} = (1−η)P_t + η(W⊙V)G` costs an
//! O(nnz(V)·d_in) sparse row-gather instead of the dense
//! O(d_out·d_in²) matmul — with row-block intra-layer parallelism and a
//! periodic exact refresh bounding f32 drift; the **dense** reference
//! engine stays one flag away for A/B comparison (`BENCH_fw.json`).
//!
//! For multi-client use the [`server`] subsystem turns that substrate
//! into a long-running daemon (`sparsefw serve`): an HTTP/1.1 JSON API
//! with a bounded priority job queue, worker threads that each own a
//! memoizing `PruneSession`, live per-layer progress streaming, and a
//! blocking [`server::Client`] (`sparsefw submit/status`):
//!
//! ```no_run
//! use sparsefw::prelude::*;
//! use sparsefw::server::{self, Server, ServerConfig};
//!
//! let cfg = ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() };
//! let handle = Server::bind(&cfg, server::demo_sessions(cfg.workers))?;
//! let client = Client::new(handle.addr().to_string());
//! let spec = JobSpec { model: "demo".into(), ..Default::default() };
//! let id = client.submit(&spec, 0)?;
//! let status = client.wait(id, std::time::Duration::from_secs(60))?;
//! println!("job {id}: {}", status.at(&["state"]).as_str().unwrap_or("?"));
//! handle.shutdown();
//! # anyhow::Ok(())
//! ```
//!
//! One tier up, the [`server::fleet`] subsystem shards a single job
//! across machines: the objective is block-decomposable, so a
//! coordinator (`serve --coordinator`) partitions each job into
//! contiguous block-range shards (LPT over per-block FLOP costs) and
//! fleet workers (`serve --worker`) pull them over the same HTTP API:
//!
//! ```text
//! client ─▶ POST /jobs ─▶ coordinator (plan_shards · LPT dispatch · reap/requeue)
//!                            ├──▶ worker 0 ─┐  register / poll+heartbeat /
//!                            ├──▶ worker 1 ─┤  execute_shard / report
//!                            └──▶ worker N ─┘
//!              staged hand-off: shard i's exit hiddens (EmbedPrefix,
//!              digest-checked) are shard i+1's calibration entry
//! ```
//!
//! Workers run the ordinary `PruneSession` path on their block range
//! and ship layers back as journal checkpoints, so the assembled
//! result is bit-identical to a single-node run (same `mask_digest`
//! for every `--propagate` policy); dead workers are reaped on missed
//! heartbeats and their shards requeue on live ones.
//!
//! ## Serving pruned models: the sparse inference fast path
//!
//! Pruning's payoff is cheaper inference, so a [`coordinator`]
//! `PruneResult` compiles into a [`model::compiled::CompiledModel`]:
//! each pruned linear packed into the cheapest format its mask
//! supports ([`tensor::sparse::CsrMat`] for unstructured masks, the
//! interleaved [`tensor::nm::NmMat`] when the mask satisfies a uniform
//! n:m invariant, masked dense above the density crossover), behind
//! the same [`model::forward::ForwardModel`] seam the dense stepper
//! uses — one forward implementation scores both:
//!
//! ```text
//! PruneResult ──compile──▶ CompiledModel (dense | csr | n:m per layer)
//!      │                        ├─ eval --sparse   logit + ppl equivalence
//!      │                        ├─ generate        KV-cached decode loop
//!      ▼                        └─ CompiledCache (LRU, compile-once)
//!   worker_loop ──────────────────────▶ POST /jobs/:id/{eval,generate}
//! ```
//!
//! A serving server compiles each completed job's model once
//! (worker-side, before the job flips to `done`) and answers
//! `eval`/`generate` requests from the LRU [`server`] cache;
//! `benches/sparse_infer.rs` A/Bs dense vs CSR vs n:m on prefill and
//! decode shapes (`BENCH_infer.json`).
//!
//! ## Crash safety: journal, checkpoints, fault injection
//!
//! With `--journal DIR` the server (and `sparsefw prune`) becomes
//! durable: submissions and state transitions append to an NDJSON job
//! journal ([`server::journal::Journal`]), and every completed pruning
//! unit (block when staged, layer when dense) lands as a checksummed
//! [`server::journal::BlockCheckpoint`] keyed by the spec's hash, so a
//! `kill -9` at any instant loses at most the unit in flight:
//!
//! ```text
//! POST /jobs ─▶ journal (jobs.ndjson, append-only) ─▶ queue ─▶ worker
//!                   │                                            │ per-unit checkpoint
//!                   │ replay on restart                          ▼ (checksum · spec-hash
//!                   ▼                                             · calib entry-digest)
//!            re-queue Queued/Running ──▶ resume: verified units restore,
//!                                        only the remainder recomputes
//! ```
//!
//! Resumed masks are **bit-identical** to an uninterrupted run
//! (certified by the order-independent `mask_digest` in every job
//! summary); `sparsefw resume --journal DIR` does the same for killed
//! CLI runs.  Around that sit bounded retries with jittered exponential
//! backoff ([`util::retry::RetryPolicy`]), per-job deadlines
//! (`--job-timeout`), a reconnecting [`server::Client`] that resumes a
//! dropped `/events` stream after the last event it saw, and load
//! shedding (queue saturation and abusive submit rates answer `429` +
//! `Retry-After`).  All of it is testable deterministically: the
//! [`util::fault`] registry arms seeded fault plans (`SPARSEFW_FAULTS`)
//! at seven sites — I/O, gram computation, FW iterations, worker
//! panics, accept/stream paths — and the CI chaos lane sweeps every
//! site × {error, panic, delay}, asserting no hangs and no lost jobs.
//! The `unbounded-retry` lint ([`analyze`]) keeps every retry loop on a
//! deadline or an attempt cap.
//!
//! ## Observability: spans, certificates, metrics
//!
//! Every layer of that stack reports through one telemetry spine
//! ([`util::telemetry`]), threaded end-to-end by a per-job
//! **correlation ID** (client flag → `X-Sparsefw-Corr-Id` header →
//! queue record → worker thread-local → every span and log line):
//!
//! ```text
//! span!("job")                                 server::worker_loop
//!   ├─ span!("calib") / span!("gram")          coordinator (calibration, grams)
//!   ├─ span!("fw", layer = …)  ×N              run_layers / run_blocks, parallel
//!   ├─ span!("refine")                         refinement post-passes
//!   └─ span!("io")                             eval / artifact I/O
//!        │ TraceEvent{span, parent, corr, dur_us, …}
//!        ▼ fan-out to installed TraceSinks
//!   RingSink    → GET /jobs/:id/trace, `sparsefw trace --job ID`
//!   NdjsonSink  → --trace-out trace.ndjson (one JSON object per span)
//!   StderrSink  → SPARSEFW_TRACE=stderr pretty-printer
//!   PhaseSink   → per-phase latency histograms in /metrics
//! ```
//!
//! Span guards are ~one relaxed atomic load when no sink is installed
//! (`benches/trace_overhead.rs` holds the FW hot loop's disabled-path
//! overhead to a ≤2% budget).  The FW solver additionally records
//! per-iteration **convergence certificates** — objective, duality gap
//! (gap(Mₜ) ≥ f(Mₜ) − f(M*)), step size, refresh drift — as a
//! [`pruner::ConvergenceTrace`] per layer (`--trace-every N`), carried
//! through `PruneResult` into job summaries and rendered by `sparsefw
//! trace` as per-layer gap-decay tables.  The server exports counters,
//! gauges, and latency histograms (queue wait, job wall, per-phase)
//! from [`server::METRIC_CATALOG`] as JSON (`GET /metrics`) and
//! Prometheus text (`GET /metrics?format=prometheus`).
//!
//! ## Project invariants are linted, not assumed
//!
//! That server stack is plain `std` threads and locks, so the crate
//! carries its own static-analysis pass ([`analyze`], `sparsefw
//! analyze`): token-level lints for lock-ordering cycles, guards held
//! across blocking calls, panics on request-serving paths, and
//! registry/codec/metrics cross-surface drift (every
//! [`server::METRIC_CATALOG`] entry must appear in the USAGE metric
//! catalog), with an `// analyze: allow(<lint>, "<reason>")` escape
//! hatch whose unused entries are themselves flagged.  CI runs
//! `sparsefw analyze --deny-warnings` (scripts/ci.sh), and
//! `scripts/analyze.sh` adds ThreadSanitizer / Miri lanes where the
//! toolchain supports them.  Expensive runtime checks (FW
//! maintained-state drift, queue state-machine transitions) sit behind
//! the `debug-invariants` cargo feature, which the CI test lane
//! enables.

pub mod analyze;
pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod pruner;
pub mod report;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod util;

pub mod prelude {
    pub use crate::calib::{CalibPolicy, CalibState, Calibration};
    pub use crate::config::{Backend, Workspace};
    pub use crate::coordinator::{
        Allocation, EvalSpec, JobResult, JobSpec, PruneSession,
    };
    pub use crate::model::compiled::{CompiledModel, SparseFormat};
    pub use crate::model::{Gpt, GptConfig};
    pub use crate::pruner::{
        FwEngine, LayerPruner, Method, MethodCaps, MethodRegistry, PruneMethod, RefinePass,
        SparseFwConfig, SparsityPattern, Warmstart,
    };
    pub use crate::server::{Client, JobState, Server, ServerConfig};
    pub use crate::tensor::Mat;
}
