//! # SparseFW — pruning LLMs via Frank-Wolfe
//!
//! Rust + JAX + Pallas reproduction of *"Don't Be Greedy, Just Relax!
//! Pruning LLMs via Frank-Wolfe"* (Roux, Zimmer, d'Aspremont, Pokutta,
//! 2025).  Layer map (DESIGN.md):
//!
//! * Layer 1 — Pallas kernels (`python/compile/kernels/`), AOT-lowered.
//! * Layer 2 — JAX model + FW step (`python/compile/`), AOT-lowered.
//! * Layer 3 — this crate: the pruning coordinator. Python never runs at
//!   request time; HLO artifacts execute through PJRT (`runtime`).

pub mod bench;
pub mod calib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod model;
pub mod pruner;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

pub mod prelude {
    pub use crate::calib::Calibration;
    pub use crate::config::Workspace;
    pub use crate::coordinator::PrunePipeline;
    pub use crate::model::{Gpt, GptConfig};
    pub use crate::pruner::{PruneMethod, SparseFwConfig, SparsityPattern, Warmstart};
    pub use crate::tensor::Mat;
}
