//! Deterministic fault injection for robustness testing.
//!
//! Production code is instrumented with named **fault sites** — a call
//! to [`hit`] at the places failures actually happen (I/O, gram
//! assembly, the FW hot loop, worker execution, the network surface).
//! When no [`FaultPlan`] is armed a site costs one relaxed atomic load,
//! the same disabled-path discipline as [`crate::util::telemetry`];
//! when a plan is armed, each rule fires an error, a panic, or a delay
//! at a chosen hit count, so crash-recovery and retry behavior become
//! *reproducible* tests instead of luck.
//!
//! The canonical sites (see the USAGE fault-site catalog):
//!
//! | site                  | instrumented where                        |
//! |-----------------------|-------------------------------------------|
//! | `io.read`             | journal / checkpoint loads                |
//! | `io.write.checkpoint` | per-block checkpoint writes               |
//! | `gram.compute`        | staged gram assembly (`run_blocks`)       |
//! | `fw.iter`             | per-layer mask optimization (retryable)   |
//! | `worker.panic`        | server worker job execution               |
//! | `net.accept`          | the HTTP accept loop                      |
//! | `net.mid-response`    | `/events` streaming, between chunks       |
//!
//! Plans come from code ([`arm`]) or the `SPARSEFW_FAULTS` environment
//! variable ([`install_from_env`]), either as JSON
//! (`{"seed": 7, "rules": [{"site": "fw.iter", "kind": "error",
//! "at": 2, "times": 1}]}`) or the compact form
//! `site:kind[:at[:ms]]`, comma-separated (`fw.iter:error:2`,
//! `net.mid-response:delay:1:50`).  Every injected fault emits a
//! `fault` telemetry span tagged with the site and kind, and bumps the
//! process-wide [`injected_total`] counter exported by `/metrics`.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};
use crate::util::prng::mix64;
use crate::util::sync::lock_recover;

/// The canonical fault-site names (the chaos lane sweeps this list).
pub const SITES: &[&str] = &[
    "io.read",
    "io.write.checkpoint",
    "gram.compute",
    "fw.iter",
    "worker.panic",
    "net.accept",
    "net.mid-response",
];

/// What an armed rule does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `hit` returns an `Err` naming the site.
    Error,
    /// `hit` panics (exercises `catch_unwind` containment).
    Panic,
    /// `hit` sleeps for the given number of milliseconds, then
    /// succeeds (exercises timeouts and slow-path behavior).
    Delay(u64),
}

impl FaultKind {
    fn label(&self) -> &'static str {
        match self {
            FaultKind::Error => "error",
            FaultKind::Panic => "panic",
            FaultKind::Delay(_) => "delay",
        }
    }
}

/// One armed rule: fire `kind` at site hits `at_hit .. at_hit+times`
/// (1-based hit counts; `times == 0` means every hit from `at_hit` on).
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub site: String,
    pub kind: FaultKind,
    pub at_hit: u64,
    pub times: u64,
}

/// A seeded set of rules.  The seed perturbs injected delays
/// deterministically (so two chaos runs with the same plan observe the
/// same schedule) and is echoed in the `fault` span for provenance.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// Parse either the JSON form or the compact
    /// `site:kind[:at[:ms]]` comma list (see the module docs).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let s = s.trim();
        if s.starts_with('{') {
            Self::from_json(&json::parse(s).context("parsing SPARSEFW_FAULTS JSON")?)
        } else {
            let mut plan = FaultPlan::default();
            for entry in s.split(',').filter(|e| !e.trim().is_empty()) {
                plan.rules.push(Self::parse_compact(entry.trim())?);
            }
            Ok(plan)
        }
    }

    fn parse_compact(entry: &str) -> Result<FaultRule> {
        let parts: Vec<&str> = entry.split(':').collect();
        if parts.len() < 2 || parts.len() > 4 {
            bail!("fault rule `{entry}`: expected site:kind[:at[:ms]]");
        }
        let at_hit: u64 = match parts.get(2) {
            Some(p) => p.parse().with_context(|| format!("fault rule `{entry}`: bad hit count"))?,
            None => 1,
        };
        let ms: u64 = match parts.get(3) {
            Some(p) => p.parse().with_context(|| format!("fault rule `{entry}`: bad delay ms"))?,
            None => 25,
        };
        let kind = match parts[1] {
            "error" => FaultKind::Error,
            "panic" => FaultKind::Panic,
            "delay" => FaultKind::Delay(ms),
            other => bail!("fault rule `{entry}`: unknown kind `{other}`"),
        };
        Ok(FaultRule { site: parts[0].to_string(), kind, at_hit, times: 1 })
    }

    fn from_json(j: &Json) -> Result<FaultPlan> {
        let seed = j.at(&["seed"]).as_usize().unwrap_or(0) as u64;
        let mut rules = Vec::new();
        if let Some(arr) = j.at(&["rules"]).as_arr() {
            for r in arr {
                let site = r
                    .at(&["site"])
                    .as_str()
                    .context("fault rule missing `site`")?
                    .to_string();
                let ms = r.at(&["ms"]).as_usize().unwrap_or(25) as u64;
                let kind = match r.at(&["kind"]).as_str().unwrap_or("error") {
                    "error" => FaultKind::Error,
                    "panic" => FaultKind::Panic,
                    "delay" => FaultKind::Delay(ms),
                    other => bail!("fault rule for `{site}`: unknown kind `{other}`"),
                };
                rules.push(FaultRule {
                    site,
                    kind,
                    at_hit: (r.at(&["at"]).as_usize().unwrap_or(1) as u64).max(1),
                    times: r.at(&["times"]).as_usize().unwrap_or(1) as u64,
                });
            }
        }
        Ok(FaultPlan { seed, rules })
    }
}

// ---------------------------------------------------------------------------
// Global registry
// ---------------------------------------------------------------------------

static ARMED: AtomicBool = AtomicBool::new(false);
static INJECTED: AtomicU64 = AtomicU64::new(0);
static PLAN: Mutex<Option<PlanState>> = Mutex::new(None);

struct PlanState {
    plan: FaultPlan,
    /// Per-site hit counters (aligned with the rule list: a site shared
    /// by several rules still counts hits once).
    hits: std::collections::BTreeMap<String, u64>,
}

/// Arm a plan process-wide (replacing any previous one) and reset the
/// hit counters.
pub fn arm(plan: FaultPlan) {
    let mut g = lock_recover(&PLAN);
    *g = Some(PlanState { plan, hits: Default::default() });
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm: every site goes back to the one-atomic-load fast path.
pub fn disarm() {
    let mut g = lock_recover(&PLAN);
    *g = None;
    ARMED.store(false, Ordering::Relaxed);
}

/// Is any plan armed?  (The fast-path check `hit` performs first.)
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Faults injected since process start (exported as
/// `sparsefw_faults_injected_total`).
pub fn injected_total() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Arm from `SPARSEFW_FAULTS` when set (the CLI calls this once at
/// startup).  A malformed plan is an error — silently ignoring it
/// would turn a chaos run into a green no-op.
pub fn install_from_env() -> Result<()> {
    if let Ok(v) = std::env::var("SPARSEFW_FAULTS") {
        if !v.trim().is_empty() {
            let plan = FaultPlan::parse(&v)?;
            crate::info!("fault injection armed: {} rule(s) from SPARSEFW_FAULTS", plan.rules.len());
            arm(plan);
        }
    }
    Ok(())
}

/// A fault site.  Unarmed: one relaxed atomic load.  Armed: counts the
/// hit and, when a rule matches, injects the configured failure —
/// `Err` for [`FaultKind::Error`], an unwind for [`FaultKind::Panic`]
/// (callers on request paths already contain panics via
/// `catch_unwind`), a sleep for [`FaultKind::Delay`].
pub fn hit(site: &str) -> Result<()> {
    if !ARMED.load(Ordering::Relaxed) {
        return Ok(());
    }
    // decide under the lock, act outside it (a Delay must not hold the
    // registry lock while sleeping)
    let fired: Option<(FaultKind, u64)> = {
        let mut g = lock_recover(&PLAN);
        match g.as_mut() {
            None => None,
            Some(st) => {
                let n = st.hits.entry(site.to_string()).or_insert(0);
                *n += 1;
                let count = *n;
                let seed = st.plan.seed;
                st.plan
                    .rules
                    .iter()
                    .find(|r| {
                        r.site == site
                            && count >= r.at_hit
                            && (r.times == 0 || count < r.at_hit + r.times)
                    })
                    .map(|r| (r.kind, seed))
            }
        }
    };
    let Some((kind, seed)) = fired else { return Ok(()) };
    INJECTED.fetch_add(1, Ordering::Relaxed);
    {
        let _sp = crate::span!("fault", site = site, kind = kind.label());
    }
    match kind {
        FaultKind::Error => bail!("injected fault at {site}"),
        FaultKind::Panic => panic!("injected panic at fault site {site}"),
        FaultKind::Delay(ms) => {
            // deterministic ±25% jitter from the plan seed, so a seeded
            // chaos run observes one fixed schedule
            let jitter = mix64(seed ^ 0x6661756c74) % (ms / 2 + 1);
            std::thread::sleep(Duration::from_millis(ms - ms / 4 + jitter));
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; tests serialize on this lock so
    /// `cargo test`'s default parallelism can't interleave plans.
    pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

    struct Disarm;
    impl Drop for Disarm {
        fn drop(&mut self) {
            disarm();
        }
    }

    #[test]
    fn unarmed_sites_are_noops() {
        let _g = lock_recover(&TEST_GUARD);
        disarm();
        for s in SITES {
            assert!(hit(s).is_ok());
        }
    }

    #[test]
    fn error_fires_at_the_requested_hit_then_clears() {
        let _g = lock_recover(&TEST_GUARD);
        let _d = Disarm;
        arm(FaultPlan {
            seed: 1,
            rules: vec![FaultRule {
                site: "fw.iter".into(),
                kind: FaultKind::Error,
                at_hit: 2,
                times: 1,
            }],
        });
        assert!(hit("fw.iter").is_ok(), "hit 1 passes");
        let e = hit("fw.iter").unwrap_err();
        assert!(e.to_string().contains("injected fault at fw.iter"), "{e}");
        assert!(hit("fw.iter").is_ok(), "hit 3 passes again (times=1)");
        assert!(hit("io.read").is_ok(), "other sites unaffected");
        assert!(injected_total() >= 1);
    }

    #[test]
    fn panic_kind_unwinds() {
        let _g = lock_recover(&TEST_GUARD);
        let _d = Disarm;
        arm(FaultPlan {
            seed: 0,
            rules: vec![FaultRule {
                site: "worker.panic".into(),
                kind: FaultKind::Panic,
                at_hit: 1,
                times: 1,
            }],
        });
        let r = std::panic::catch_unwind(|| hit("worker.panic"));
        assert!(r.is_err(), "panic kind must unwind");
    }

    #[test]
    fn compact_and_json_plans_parse() {
        let p = FaultPlan::parse("fw.iter:error:2, net.mid-response:delay:1:50").unwrap();
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].at_hit, 2);
        assert_eq!(p.rules[1].kind, FaultKind::Delay(50));

        let j = FaultPlan::parse(
            r#"{"seed": 7, "rules": [{"site": "io.read", "kind": "panic", "at": 3, "times": 2}]}"#,
        )
        .unwrap();
        assert_eq!(j.seed, 7);
        assert_eq!(j.rules[0].kind, FaultKind::Panic);
        assert_eq!(j.rules[0].at_hit, 3);
        assert_eq!(j.rules[0].times, 2);

        assert!(FaultPlan::parse("fw.iter").is_err(), "missing kind");
        assert!(FaultPlan::parse("fw.iter:explode").is_err(), "unknown kind");
    }
}
