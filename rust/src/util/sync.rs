//! Poison-recovering lock helpers.
//!
//! `std::Mutex` poisons itself when a thread panics while holding the
//! guard, and every later `.lock().unwrap()` then panics too — one bad
//! job wedges the whole server.  Poisoning is only a *tripwire*: the
//! data is still there and, for every lock in this crate, still
//! consistent (guard scopes are short and state transitions are
//! single-assignment), so the right response is to take the guard and
//! keep serving.  These helpers are the crate-wide idiom; the
//! `sparsefw analyze` lock lints treat them as acquisitions.

use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait` that recovers a poisoned guard on wake.
pub fn wait_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// `Condvar::wait_timeout` that recovers a poisoned guard on wake;
/// returns the guard and whether the wait timed out.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    match cv.wait_timeout(guard, dur) {
        Ok((g, t)) => (g, t.timed_out()),
        Err(e) => {
            let (g, t) = e.into_inner();
            (g, t.timed_out())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }
}
