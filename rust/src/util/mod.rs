//! In-tree substrates (DESIGN.md §3): the offline crate registry lacks
//! serde / rayon / tokio / rand, so JSON, parallelism, PRNGs and logging
//! are implemented here and tested like any other module.

pub mod fault;
pub mod json;
pub mod log;
pub mod pool;
pub mod retry;
pub mod prng;
pub mod sync;
pub mod telemetry;
