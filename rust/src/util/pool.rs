//! Scoped data-parallel helpers and a persistent task pool.
//!
//! The offline registry has no `rayon`/`tokio`, so the coordinator's
//! parallelism substrate is built on `std::thread::scope`: an atomic
//! work-stealing counter over an index range.  Spawn cost (~tens of µs)
//! is negligible against the matmul-dominated work items scheduled here.
//!
//! [`TaskPool`] is the long-lived counterpart for the server: a fixed
//! set of worker threads draining a shared closure queue (connection
//! handling must not spawn a thread per accept).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::lock_recover;

/// Number of worker threads to use for `n` items.
pub fn default_workers(n: usize) -> usize {
    let cores = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(4);
    cores.min(n).max(1)
}

/// Run `f(i)` for every `i in 0..n`, distributing indices dynamically
/// over up to `default_workers(n)` threads. `f` must be `Sync`.
pub fn parallel_for<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_for_with(default_workers(n), n, f)
}

/// Like [`parallel_for`] with an explicit worker count.
pub fn parallel_for_with<F>(workers: usize, n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    if workers <= 1 || n == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers.min(n) {
            s.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    parallel_for(n, |i| {
        let v = f(i);
        *slots[i].lock().unwrap() = Some(v);
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("parallel_map slot unfilled"))
        .collect()
}

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size persistent thread pool: submitted closures run on the
/// first free worker, in submission order.  Dropping the pool finishes
/// queued tasks and joins the workers.
pub struct TaskPool {
    tx: Option<Sender<Task>>,
    workers: Vec<JoinHandle<()>>,
}

impl TaskPool {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Task>();
        let rx: Arc<Mutex<Receiver<Task>>> = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = rx.clone();
                std::thread::spawn(move || loop {
                    // hold the receiver lock only while dequeueing
                    // analyze: allow(lock-across-blocking, "the receiver lock IS the dequeue point; blocking recv under it is the pool design")
                    let task = match lock_recover(&rx).recv() {
                        Ok(t) => t,
                        Err(_) => break, // all senders dropped
                    };
                    // a panicking task must not kill its worker: the
                    // pool would silently lose a thread per bad task
                    // (and the receiver lock would poison for the rest)
                    if catch_unwind(AssertUnwindSafe(task)).is_err() {
                        crate::warnlog!("task pool: task panicked (worker recovered)");
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers: handles }
    }

    /// Enqueue a closure for execution on the pool.
    ///
    /// Workers survive panicking tasks (see `new`), so the channel can
    /// only close through [`Drop`]; rather than panicking the caller on
    /// that unreachable edge, a failed send logs and drops the task.
    pub fn execute(&self, f: impl FnOnce() + Send + 'static) {
        let sent = self
            .tx
            .as_ref()
            .map(|tx| tx.send(Box::new(f)).is_ok())
            .unwrap_or(false);
        if !sent {
            crate::warnlog!("task pool: execute() after shutdown; task dropped");
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel so workers exit
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Split `0..n` into `chunks` contiguous ranges of near-equal size.
pub fn chunk_ranges(n: usize, chunks: usize) -> Vec<std::ops::Range<usize>> {
    let chunks = chunks.clamp(1, n.max(1));
    let base = n / chunks;
    let rem = n % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < rem);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_in_order() {
        let v = parallel_map(257, |i| i * i);
        assert_eq!(v, (0..257).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn chunk_ranges_partition() {
        for n in [0usize, 1, 7, 100, 101] {
            for c in [1usize, 3, 8] {
                let rs = chunk_ranges(n, c);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n, "n={n} c={c}");
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
            }
        }
    }

    #[test]
    fn zero_items_is_noop() {
        parallel_for(0, |_| panic!("must not run"));
        assert!(parallel_map(0, |i| i).is_empty());
    }

    #[test]
    fn task_pool_survives_panicking_tasks() {
        let hits = Arc::new(AtomicUsize::new(0));
        {
            // 1 worker: if the panic killed it, nothing after could run
            let pool = TaskPool::new(1);
            pool.execute(|| panic!("bad task"));
            for _ in 0..10 {
                let hits = hits.clone();
                pool.execute(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
        }
        assert_eq!(hits.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn task_pool_runs_everything_and_joins() {
        let hits = Arc::new(AtomicUsize::new(0));
        {
            let pool = TaskPool::new(4);
            for _ in 0..100 {
                let hits = hits.clone();
                pool.execute(move || {
                    hits.fetch_add(1, Ordering::Relaxed);
                });
            }
            // drop joins the workers after the queue drains
        }
        assert_eq!(hits.load(Ordering::Relaxed), 100);
    }
}
