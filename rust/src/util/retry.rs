//! Retry policies: bounded attempts, jittered exponential backoff,
//! deadlines.
//!
//! Every retry loop in the crate goes through [`RetryPolicy::run`] (or
//! carries its own attempt cap / [`Deadline`]) — the `sparsefw analyze`
//! `unbounded-retry` lint flags loops that retry on error with neither.
//! Jitter is seeded ([`crate::util::prng::Xoshiro256`]), so backoff
//! schedules are reproducible under the fault-injection harness.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::util::prng::Xoshiro256;

/// An optional wall-clock budget shared across attempts (and, for jobs,
/// across pipeline stages — `--job-timeout`).
#[derive(Clone, Copy, Debug)]
pub struct Deadline {
    at: Option<Instant>,
}

impl Deadline {
    /// No budget: never expires.
    pub fn none() -> Deadline {
        Deadline { at: None }
    }

    /// Expires `d` from now.
    pub fn after(d: Duration) -> Deadline {
        Deadline { at: Some(Instant::now() + d) }
    }

    /// Expires `secs` from now; `None` means no budget.
    pub fn after_secs(secs: Option<f64>) -> Deadline {
        match secs {
            Some(s) if s > 0.0 => Deadline::after(Duration::from_secs_f64(s)),
            _ => Deadline::none(),
        }
    }

    pub fn expired(&self) -> bool {
        self.at.is_some_and(|t| Instant::now() >= t)
    }

    /// Time left; `None` when there is no budget at all.
    pub fn remaining(&self) -> Option<Duration> {
        self.at.map(|t| t.saturating_duration_since(Instant::now()))
    }

    /// `Err("deadline exceeded while <what>")` once expired — the
    /// check long pipelines call between units of work.
    pub fn check(&self, what: &str) -> Result<()> {
        if self.expired() {
            Err(anyhow!("deadline exceeded while {what}"))
        } else {
            Ok(())
        }
    }
}

/// Bounded retry with jittered exponential backoff.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    /// Total attempts (≥ 1); 1 means "no retries".
    pub max_attempts: u32,
    /// Backoff before attempt 2 (doubles per attempt, capped).
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
    /// Jitter seed — same seed, same schedule.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::from_millis(20),
            max_delay: Duration::from_secs(2),
            jitter_seed: 0x7265747279, // "retry"
        }
    }
}

impl RetryPolicy {
    /// A single attempt, no backoff.
    pub fn disabled() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, ..Default::default() }
    }

    pub fn with_attempts(attempts: u32) -> RetryPolicy {
        RetryPolicy { max_attempts: attempts.max(1), ..Default::default() }
    }

    /// Backoff before attempt `attempt` (1-based; attempt 1 never
    /// waits).  Exponential with full jitter: uniform in
    /// `(0, base · 2^(attempt-2)]`, capped at `max_delay`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        if attempt <= 1 {
            return Duration::ZERO;
        }
        let exp = (attempt - 2).min(16);
        let ceiling = self
            .base_delay
            .saturating_mul(1u32 << exp)
            .min(self.max_delay)
            .max(Duration::from_millis(1));
        let mut rng = Xoshiro256::new(self.jitter_seed ^ u64::from(attempt));
        ceiling.mul_f64(rng.next_f64().max(0.05))
    }

    /// Run `op` up to `max_attempts` times (fewer if `deadline`
    /// expires), sleeping [`RetryPolicy::backoff`] between attempts.
    /// The closure receives the 1-based attempt number.  On exhaustion
    /// the last error is returned, annotated with the attempt count.
    pub fn run<T>(
        &self,
        deadline: Deadline,
        what: &str,
        mut op: impl FnMut(u32) -> Result<T>,
    ) -> Result<T> {
        let mut attempt = 1u32;
        loop {
            deadline.check(what)?;
            match op(attempt) {
                Ok(v) => return Ok(v),
                Err(e) if attempt >= self.max_attempts => {
                    return Err(e.context(format!("{what}: failed after {attempt} attempt(s)")));
                }
                Err(e) => {
                    let mut wait = self.backoff(attempt + 1);
                    if let Some(rem) = deadline.remaining() {
                        if rem.is_zero() {
                            return Err(e.context(format!(
                                "{what}: deadline exceeded after {attempt} attempt(s)"
                            )));
                        }
                        wait = wait.min(rem);
                    }
                    crate::debuglog!(
                        "{what}: attempt {attempt}/{} failed ({e:#}); retrying in {wait:?}",
                        self.max_attempts
                    );
                    std::thread::sleep(wait);
                }
            }
            attempt += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn succeeds_after_transient_failures() {
        let calls = AtomicU32::new(0);
        let pol = RetryPolicy {
            base_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let v = pol
            .run(Deadline::none(), "transient op", |_a| {
                if calls.fetch_add(1, Ordering::SeqCst) < 2 {
                    Err(anyhow!("flaky"))
                } else {
                    Ok(42)
                }
            })
            .unwrap();
        assert_eq!(v, 42);
        assert_eq!(calls.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn exhaustion_names_the_attempt_count() {
        let pol = RetryPolicy {
            max_attempts: 2,
            base_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let e = pol
            .run(Deadline::none(), "doomed op", |_a| -> Result<()> { Err(anyhow!("nope")) })
            .unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("doomed op"), "{msg}");
        assert!(msg.contains("2 attempt"), "{msg}");
    }

    #[test]
    fn deadline_stops_retries() {
        let calls = AtomicU32::new(0);
        let pol = RetryPolicy {
            max_attempts: 1000,
            base_delay: Duration::from_millis(5),
            ..Default::default()
        };
        let e = pol
            .run(Deadline::after(Duration::from_millis(30)), "slow op", |_a| -> Result<()> {
                calls.fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("still failing"))
            })
            .unwrap_err();
        assert!(format!("{e:#}").contains("deadline exceeded"), "{e:#}");
        assert!(calls.load(Ordering::SeqCst) < 1000);
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let pol = RetryPolicy::default();
        assert_eq!(pol.backoff(1), Duration::ZERO);
        for a in 2..12 {
            let b1 = pol.backoff(a);
            let b2 = pol.backoff(a);
            assert_eq!(b1, b2, "same seed, same schedule");
            assert!(b1 <= pol.max_delay);
            assert!(b1 > Duration::ZERO);
        }
    }

    #[test]
    fn deadline_expiry_and_check() {
        let d = Deadline::after(Duration::from_millis(5));
        assert!(!d.expired());
        assert!(d.check("warmup").is_ok());
        std::thread::sleep(Duration::from_millis(10));
        assert!(d.expired());
        let e = d.check("block 3/8").unwrap_err();
        assert!(e.to_string().contains("block 3/8"));
        assert!(Deadline::none().remaining().is_none());
        assert!(!Deadline::after_secs(None).expired());
    }
}
