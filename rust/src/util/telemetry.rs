//! End-to-end tracing: spans, correlation IDs, pluggable sinks.
//!
//! The observability layer the rest of the crate reports through.  A
//! [`crate::span!`] guard times one region of work (gram build, FW
//! solve, refinement, …) and, on drop, emits a [`TraceEvent`] carrying
//! wall + monotonic timestamps, its parent span, and the current
//! correlation ID to every installed [`TraceSink`]:
//!
//! ```text
//! client ──X-Sparsefw-Corr-Id──▶ server ──▶ queue ──▶ worker
//!                                                      │ with_correlation(corr)
//!                                                      ▼
//!                                        span!("job") ⊃ span!("calib")
//!                                                     ⊃ span!("gram", block=b)
//!                                                     ⊃ span!("fw", layer=l) …
//! ```
//!
//! Sinks are registered process-wide ([`add_sink`]) and the hot-path
//! cost when *no* sink is installed is a single relaxed atomic load —
//! the `span!` macro never formats its fields unless tracing is on
//! (budgeted ≤2% on the FW hot loop; `benches/trace_overhead.rs`).
//!
//! Spans are thread-local; crossing a thread boundary (the pool in
//! [`crate::util::pool`], scoped threads) requires capturing a
//! [`TraceContext`] on the dispatching thread and `enter()`ing it
//! inside the worker closure — thread-locals do not propagate on their
//! own, and a span opened without a context would otherwise parent to
//! the root.
//!
//! Shipped sinks: [`RingSink`] (bounded per-correlation ring buffer
//! behind `GET /jobs/:id/trace`), [`NdjsonSink`] (`--trace-out FILE`,
//! one JSON object per line), [`StderrSink`] (pretty-printer,
//! `SPARSEFW_TRACE=stderr`).

use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;
use crate::util::sync::lock_recover;

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One completed span, emitted to every sink on guard drop.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Unique (process-wide) span ID; never 0.
    pub span_id: u64,
    /// Enclosing span's ID; 0 for a root span.
    pub parent_id: u64,
    /// Correlation ID active when the span opened (job-scoped).
    pub corr_id: Option<Arc<str>>,
    /// Span name (`"gram"`, `"fw"`, …) — a static literal by
    /// construction of the `span!` macro.
    pub name: &'static str,
    /// Formatted `key = value` fields from the `span!` call site.
    pub fields: Vec<(&'static str, String)>,
    /// Wall-clock at span start, milliseconds since the Unix epoch.
    pub wall_ms: u64,
    /// Monotonic offset from process start at span start, microseconds.
    pub mono_us: u64,
    /// Span duration, microseconds.
    pub dur_us: u64,
}

impl TraceEvent {
    /// NDJSON / API form.  One-way: traces are emitted, not replayed.
    pub fn to_json(&self) -> Json {
        let mut o = vec![
            ("span", Json::Num(self.span_id as f64)),
            ("parent", Json::Num(self.parent_id as f64)),
            ("name", Json::Str(self.name.to_string())),
            ("wall_ms", Json::Num(self.wall_ms as f64)),
            ("mono_us", Json::Num(self.mono_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
        ];
        if let Some(c) = &self.corr_id {
            o.push(("corr", Json::Str(c.to_string())));
        }
        if !self.fields.is_empty() {
            o.push((
                "fields",
                Json::Obj(
                    self.fields
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::Str(v.clone())))
                        .collect(),
                ),
            ));
        }
        Json::obj(o)
    }
}

// ---------------------------------------------------------------------------
// Global state: enabled flag, span counter, sink registry
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static MONO_START: OnceLock<Instant> = OnceLock::new();
static SINKS: OnceLock<Mutex<Vec<Arc<dyn TraceSink>>>> = OnceLock::new();

fn sinks() -> &'static Mutex<Vec<Arc<dyn TraceSink>>> {
    SINKS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Is any sink installed?  The only check on the disabled fast path.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Install a sink; tracing turns on for the whole process.
pub fn add_sink(s: Arc<dyn TraceSink>) {
    let mut g = lock_recover(sinks());
    g.push(s);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Remove a previously installed sink (by identity); tracing turns
/// back off when the last sink goes.
pub fn remove_sink(s: &Arc<dyn TraceSink>) {
    let mut g = lock_recover(sinks());
    g.retain(|x| !Arc::ptr_eq(x, s));
    ENABLED.store(!g.is_empty(), Ordering::Relaxed);
}

/// Install sinks requested by the environment: `SPARSEFW_TRACE=stderr`
/// turns the pretty-printer on (the CLI calls this once at startup).
pub fn install_from_env() {
    if std::env::var("SPARSEFW_TRACE").as_deref() == Ok("stderr") {
        add_sink(Arc::new(StderrSink));
    }
}

fn dispatch(ev: &TraceEvent) {
    // snapshot the registry, then record OUTSIDE the lock: sinks may
    // block (file writes) and take their own locks
    let snapshot: Vec<Arc<dyn TraceSink>> = lock_recover(sinks()).clone();
    for s in &snapshot {
        s.record(ev);
    }
}

// ---------------------------------------------------------------------------
// Thread-local span context
// ---------------------------------------------------------------------------

struct Ctx {
    corr: Option<Arc<str>>,
    stack: Vec<u64>,
}

thread_local! {
    static CTX: RefCell<Ctx> = const { RefCell::new(Ctx { corr: None, stack: Vec::new() }) };
}

/// The correlation ID active on this thread, if any (log lines carry
/// it; see [`crate::util::log`]).
pub fn current_corr() -> Option<Arc<str>> {
    CTX.with(|c| c.borrow().corr.clone())
}

/// Set the thread's correlation ID for the guard's lifetime (workers
/// wrap each job execution in one).  Nests: dropping restores the
/// previous ID.
pub fn with_correlation(corr: &str) -> CorrGuard {
    CTX.with(|c| {
        let prev = std::mem::replace(&mut c.borrow_mut().corr, Some(Arc::from(corr)));
        CorrGuard { prev }
    })
}

pub struct CorrGuard {
    prev: Option<Arc<str>>,
}

impl Drop for CorrGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CTX.with(|c| c.borrow_mut().corr = prev);
    }
}

/// A snapshot of the calling thread's span context (correlation ID +
/// innermost span), for re-entry on another thread.
#[derive(Clone)]
pub struct TraceContext {
    corr: Option<Arc<str>>,
    parent: u64,
}

impl TraceContext {
    /// Capture on the dispatching thread, before handing closures to a
    /// pool or scoped spawn.
    pub fn capture() -> TraceContext {
        CTX.with(|c| {
            let c = c.borrow();
            TraceContext { corr: c.corr.clone(), parent: c.stack.last().copied().unwrap_or(0) }
        })
    }

    /// Enter the captured context on the current (worker) thread:
    /// spans opened under the guard parent to the captured span and
    /// carry its correlation ID.
    pub fn enter(&self) -> ContextGuard {
        CTX.with(|c| {
            let mut c = c.borrow_mut();
            let prev_corr = std::mem::replace(&mut c.corr, self.corr.clone());
            let pushed = self.parent != 0;
            if pushed {
                c.stack.push(self.parent);
            }
            ContextGuard { prev_corr, pushed }
        })
    }
}

pub struct ContextGuard {
    prev_corr: Option<Arc<str>>,
    pushed: bool,
}

impl Drop for ContextGuard {
    fn drop(&mut self) {
        let prev = self.prev_corr.take();
        let pushed = self.pushed;
        CTX.with(|c| {
            let mut c = c.borrow_mut();
            if pushed {
                c.stack.pop();
            }
            c.corr = prev;
        });
    }
}

/// A process-unique correlation ID (time + pid + counter) — the client
/// mints one per submitted job when the caller didn't supply one.
pub fn gen_corr_id() -> String {
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let c = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    format!("{:08x}-{:04x}-{:04x}", t & 0xffff_ffff, std::process::id() & 0xffff, c & 0xffff)
}

// ---------------------------------------------------------------------------
// Span guards
// ---------------------------------------------------------------------------

/// RAII span: opened by [`crate::span!`], emits its [`TraceEvent`] on
/// drop.  A disabled guard (tracing off at open) is inert.
pub struct SpanGuard {
    inner: Option<SpanInner>,
}

struct SpanInner {
    span_id: u64,
    parent_id: u64,
    corr: Option<Arc<str>>,
    name: &'static str,
    fields: Vec<(&'static str, String)>,
    wall_ms: u64,
    mono_us: u64,
    started: Instant,
}

impl SpanGuard {
    /// Open a span iff tracing is enabled; `fields` is only invoked
    /// (and its formatting only paid) when it is.
    #[inline]
    pub fn enter_if_enabled(
        name: &'static str,
        fields: impl FnOnce() -> Vec<(&'static str, String)>,
    ) -> SpanGuard {
        if !enabled() {
            return SpanGuard { inner: None };
        }
        SpanGuard::enter(name, fields())
    }

    /// Open a span unconditionally (tests and sinks-off benchmarks).
    pub fn enter(name: &'static str, fields: Vec<(&'static str, String)>) -> SpanGuard {
        let span_id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        let (parent_id, corr) = CTX.with(|c| {
            let mut c = c.borrow_mut();
            let parent = c.stack.last().copied().unwrap_or(0);
            c.stack.push(span_id);
            (parent, c.corr.clone())
        });
        let started = Instant::now();
        let mono_us =
            started.saturating_duration_since(*MONO_START.get_or_init(Instant::now)).as_micros()
                as u64;
        let wall_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        SpanGuard {
            inner: Some(SpanInner {
                span_id,
                parent_id,
                corr,
                name,
                fields,
                wall_ms,
                mono_us,
                started,
            }),
        }
    }

    /// The inert guard the `span!` macro returns when tracing is off.
    pub fn disabled() -> SpanGuard {
        SpanGuard { inner: None }
    }

    /// This span's ID (None when the guard is inert).
    pub fn id(&self) -> Option<u64> {
        self.inner.as_ref().map(|i| i.span_id)
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        CTX.with(|c| {
            let mut b = c.borrow_mut();
            // normally a strict LIFO pop; under guard-drop-out-of-order
            // misuse remove wherever the ID sits so the stack can't grow
            if b.stack.last() == Some(&inner.span_id) {
                b.stack.pop();
            } else if let Some(pos) = b.stack.iter().rposition(|&x| x == inner.span_id) {
                b.stack.remove(pos);
            }
        });
        let ev = TraceEvent {
            span_id: inner.span_id,
            parent_id: inner.parent_id,
            corr_id: inner.corr,
            name: inner.name,
            fields: inner.fields,
            wall_ms: inner.wall_ms,
            mono_us: inner.mono_us,
            dur_us: inner.started.elapsed().as_micros() as u64,
        };
        dispatch(&ev);
    }
}

/// Open a timed span: `span!("fw", layer = name, rows = w.rows)`.
/// Returns a [`SpanGuard`]; bind it (`let _span = span!(…)`) so the
/// span covers the intended scope.  Fields format lazily — when no
/// sink is installed the whole call is one atomic load.
#[macro_export]
macro_rules! span {
    ($name:literal) => {
        $crate::util::telemetry::SpanGuard::enter_if_enabled($name, ::std::vec::Vec::new)
    };
    ($name:literal, $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::telemetry::SpanGuard::enter_if_enabled($name, || {
            vec![$((stringify!($k), format!("{}", $v))),+]
        })
    };
}

// ---------------------------------------------------------------------------
// Sinks
// ---------------------------------------------------------------------------

/// A destination for completed spans.  `record` runs on the thread
/// that closed the span and outside the sink-registry lock; sinks do
/// their own synchronization.
pub trait TraceSink: Send + Sync {
    fn record(&self, ev: &TraceEvent);
}

/// Bounded in-memory ring, keyed by correlation ID — the store behind
/// `GET /jobs/:id/trace`.  Uncorrelated events are dropped (they could
/// never be fetched); the oldest correlation is evicted wholesale when
/// `max_corrs` is hit.
pub struct RingSink {
    inner: Mutex<RingInner>,
    per_corr_cap: usize,
    max_corrs: usize,
}

struct RingInner {
    by_corr: BTreeMap<String, VecDeque<TraceEvent>>,
    order: VecDeque<String>,
}

impl RingSink {
    pub fn new(per_corr_cap: usize, max_corrs: usize) -> RingSink {
        RingSink {
            inner: Mutex::new(RingInner { by_corr: BTreeMap::new(), order: VecDeque::new() }),
            per_corr_cap: per_corr_cap.max(1),
            max_corrs: max_corrs.max(1),
        }
    }

    /// Every retained event for one correlation ID, oldest first.
    pub fn events_for(&self, corr: &str) -> Vec<TraceEvent> {
        let g = lock_recover(&self.inner);
        g.by_corr.get(corr).map(|q| q.iter().cloned().collect()).unwrap_or_default()
    }
}

impl TraceSink for RingSink {
    fn record(&self, ev: &TraceEvent) {
        let Some(corr) = ev.corr_id.as_deref() else { return };
        let mut g = lock_recover(&self.inner);
        if !g.by_corr.contains_key(corr) {
            if g.order.len() >= self.max_corrs {
                if let Some(old) = g.order.pop_front() {
                    g.by_corr.remove(&old);
                }
            }
            g.order.push_back(corr.to_string());
            g.by_corr.insert(corr.to_string(), VecDeque::new());
        }
        if let Some(q) = g.by_corr.get_mut(corr) {
            if q.len() >= self.per_corr_cap {
                q.pop_front();
            }
            q.push_back(ev.clone());
        }
    }
}

/// One JSON object per line, flushed per event (`--trace-out FILE`).
pub struct NdjsonSink {
    w: Mutex<BufWriter<File>>,
}

impl NdjsonSink {
    pub fn create(path: &Path) -> std::io::Result<NdjsonSink> {
        Ok(NdjsonSink { w: Mutex::new(BufWriter::new(File::create(path)?)) })
    }
}

impl TraceSink for NdjsonSink {
    fn record(&self, ev: &TraceEvent) {
        let line = crate::util::json::to_string(&ev.to_json());
        let mut w = lock_recover(&self.w);
        // analyze: allow(lock-across-blocking, "the writer lock exists to keep NDJSON lines atomic")
        let _ = writeln!(w, "{line}");
        let _ = w.flush();
    }
}

/// Human-readable stderr lines (`SPARSEFW_TRACE=stderr`) — the traced
/// replacement for ad-hoc `debuglog!` calls in the pipeline.
pub struct StderrSink;

impl TraceSink for StderrSink {
    fn record(&self, ev: &TraceEvent) {
        let mut line = format!(
            "[trace {:>10.3}ms] {}#{}",
            ev.dur_us as f64 / 1000.0,
            ev.name,
            ev.span_id
        );
        if ev.parent_id != 0 {
            line.push_str(&format!(" <#{}", ev.parent_id));
        }
        for (k, v) in &ev.fields {
            line.push_str(&format!(" {k}={v}"));
        }
        if let Some(c) = &ev.corr_id {
            line.push_str(&format!(" [{c}]"));
        }
        let mut err = std::io::stderr().lock();
        // analyze: allow(lock-across-blocking, "the stderr lock exists to make this one write atomic")
        let _ = writeln!(err, "{line}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A sink that appends into a shared Vec (tests only).
    struct VecSink(Mutex<Vec<TraceEvent>>);

    impl TraceSink for VecSink {
        fn record(&self, ev: &TraceEvent) {
            lock_recover(&self.0).push(ev.clone());
        }
    }

    fn with_vec_sink<R>(f: impl FnOnce() -> R) -> (R, Vec<TraceEvent>) {
        let sink = Arc::new(VecSink(Mutex::new(Vec::new())));
        let dyn_sink: Arc<dyn TraceSink> = sink.clone();
        add_sink(dyn_sink.clone());
        let r = f();
        remove_sink(&dyn_sink);
        let evs = lock_recover(&sink.0).clone();
        (r, evs)
    }

    #[test]
    fn spans_nest_and_parent() {
        // unique corr so concurrently running tests can't interleave
        let corr = gen_corr_id();
        let ((), evs) = with_vec_sink(|| {
            let _c = with_correlation(&corr);
            let outer = span!("outer", layer = "wqkv");
            let outer_id = outer.id().unwrap();
            {
                let inner = span!("inner");
                assert_eq!(inner.inner.as_ref().unwrap().parent_id, outer_id);
            }
            drop(outer);
        });
        let evs: Vec<_> =
            evs.into_iter().filter(|e| e.corr_id.as_deref() == Some(corr.as_str())).collect();
        assert_eq!(evs.len(), 2);
        // inner closes first
        assert_eq!(evs[0].name, "inner");
        assert_eq!(evs[1].name, "outer");
        assert_eq!(evs[0].parent_id, evs[1].span_id);
        assert_eq!(evs[1].parent_id, 0);
        assert_eq!(evs[1].fields, vec![("layer", "wqkv".to_string())]);
        assert!(evs[0].mono_us >= evs[1].mono_us);
    }

    #[test]
    fn disabled_span_emits_nothing() {
        // no sink installed by *this* test: guard must be inert even
        // if another test concurrently enables tracing (checked via a
        // corr id no other test uses)
        let corr = gen_corr_id();
        let _c = with_correlation(&corr);
        let g = SpanGuard::disabled();
        assert!(g.id().is_none());
        drop(g);
        let ((), evs) = with_vec_sink(|| {
            let _g = span!("now-on");
        });
        assert!(evs.iter().any(|e| e.name == "now-on"));
    }

    #[test]
    fn context_propagates_across_threads() {
        let corr = gen_corr_id();
        let ((), evs) = with_vec_sink(|| {
            let _c = with_correlation(&corr);
            let outer = span!("dispatch");
            let ctx = TraceContext::capture();
            std::thread::scope(|s| {
                s.spawn(|| {
                    let _g = ctx.enter();
                    let _child = span!("worker");
                });
            });
            drop(outer);
        });
        let evs: Vec<_> =
            evs.into_iter().filter(|e| e.corr_id.as_deref() == Some(corr.as_str())).collect();
        assert_eq!(evs.len(), 2);
        let worker = evs.iter().find(|e| e.name == "worker").unwrap();
        let dispatch = evs.iter().find(|e| e.name == "dispatch").unwrap();
        assert_eq!(worker.parent_id, dispatch.span_id, "cross-thread span parents to captured");
        assert_eq!(worker.corr_id.as_deref(), Some(corr.as_str()));
    }

    #[test]
    fn corr_guard_restores_previous() {
        let a = gen_corr_id();
        let b = gen_corr_id();
        let _ga = with_correlation(&a);
        {
            let _gb = with_correlation(&b);
            assert_eq!(current_corr().as_deref(), Some(b.as_str()));
        }
        assert_eq!(current_corr().as_deref(), Some(a.as_str()));
    }

    #[test]
    fn ring_sink_caps_and_evicts() {
        let ring = RingSink::new(2, 2);
        let ev = |corr: &str, id: u64| TraceEvent {
            span_id: id,
            parent_id: 0,
            corr_id: Some(Arc::from(corr)),
            name: "x",
            fields: vec![],
            wall_ms: 0,
            mono_us: 0,
            dur_us: 1,
        };
        for i in 0..5 {
            ring.record(&ev("a", i));
        }
        let a = ring.events_for("a");
        assert_eq!(a.len(), 2, "per-corr cap");
        assert_eq!(a[1].span_id, 4, "newest retained");
        ring.record(&ev("b", 10));
        ring.record(&ev("c", 11)); // evicts "a" (max 2 corrs)
        assert!(ring.events_for("a").is_empty());
        assert_eq!(ring.events_for("b").len(), 1);
        // uncorrelated events are dropped
        ring.record(&TraceEvent { corr_id: None, ..ev("x", 12) });
        assert!(ring.events_for("x").is_empty());
    }

    #[test]
    fn ndjson_sink_writes_parseable_lines() {
        let path = std::env::temp_dir().join(format!("sfw-trace-test-{}.ndjson", std::process::id()));
        let sink = NdjsonSink::create(&path).unwrap();
        sink.record(&TraceEvent {
            span_id: 3,
            parent_id: 1,
            corr_id: Some(Arc::from("c1")),
            name: "fw",
            fields: vec![("layer", "wo".into())],
            wall_ms: 1000,
            mono_us: 2000,
            dur_us: 42,
        });
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let v = crate::util::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.at(&["span"]).as_usize(), Some(3));
        assert_eq!(v.at(&["parent"]).as_usize(), Some(1));
        assert_eq!(v.at(&["name"]).as_str(), Some("fw"));
        assert_eq!(v.at(&["corr"]).as_str(), Some("c1"));
        assert_eq!(v.at(&["fields", "layer"]).as_str(), Some("wo"));
        assert_eq!(v.at(&["dur_us"]).as_usize(), Some(42));
    }

    #[test]
    fn gen_corr_ids_are_unique() {
        let a = gen_corr_id();
        let b = gen_corr_id();
        assert_ne!(a, b);
    }
}
