//! Minimal JSON parser + serializer.
//!
//! The offline crate registry has no `serde`/`serde_json`, so this module
//! is the substrate behind the AOT manifest, safetensors headers, run
//! configs and report output (DESIGN.md §3).  It implements the full
//! JSON grammar (RFC 8259) minus non-UTF-8 exotica, with precise error
//! positions.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

// ---------------------------------------------------------------------------
// Accessors
// ---------------------------------------------------------------------------

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing paths.
    pub fn at(&self, path: &[&str]) -> &Json {
        static NULL: Json = Json::Null;
        let mut cur = self;
        for k in path {
            cur = cur.get(k).unwrap_or(&NULL);
        }
        cur
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- constructors -----------------------------------------------------

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble multi-byte UTF-8 sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf-8")),
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Pretty-printed with 1-space indent (matches python's `json.dumps(indent=1)`).
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(1), 0);
    out
}

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            if !items.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_str(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            if !map.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": [true, false]}, "s": "hi\nthere"}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.at(&["b", "c"]), &Json::Null);
        assert_eq!(v.at(&["s"]).as_str(), Some("hi\nthere"));
        let re = parse(&to_string(&v)).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo wörld — ✓\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld — ✓"));
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn errors_have_positions() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        for (s, x) in [("0", 0.0), ("-0.5", -0.5), ("1e3", 1000.0), ("2.5E-2", 0.025)] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(x), "{s}");
        }
    }

    #[test]
    fn pretty_matches_python_indent1() {
        let v = Json::obj(vec![("a", Json::Num(1.0)), ("b", Json::Arr(vec![Json::Num(2.0)]))]);
        assert_eq!(to_string_pretty(&v), "{\n \"a\": 1,\n \"b\": [\n  2\n ]\n}");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(to_string(&parse("[]").unwrap()), "[]");
        assert_eq!(to_string(&parse("{}").unwrap()), "{}");
    }
}
