//! Tiny leveled logger with wall-clock timestamps.
//!
//! Keeps the coordinator's progress reporting dependency-free.  Level
//! is controlled at runtime by `SPARSEFW_LOG`
//! (`error|warn|info|debug`, default `info`) or [`set_level`].  When a
//! correlation ID is active on the thread
//! ([`crate::util::telemetry::with_correlation`]) every line carries
//! it, so server logs group by job.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

/// `SPARSEFW_LOG` value → numeric level (unknown/absent ⇒ info).
fn parse_level(v: Option<&str>) -> u8 {
    match v {
        Some("error") => 0,
        Some("warn") => 1,
        Some("debug") => 3,
        _ => 2,
    }
}

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let l = parse_level(std::env::var("SPARSEFW_LOG").ok().as_deref());
    LEVEL.store(l, Ordering::Relaxed);
    l
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Would a message at level `l` currently be emitted?
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Render one log line (sans trailing newline): timestamp, level tag,
/// correlation ID when one is active, message.
fn format_line(t: f64, l: Level, corr: Option<&str>, args: std::fmt::Arguments<'_>) -> String {
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => " WARN",
        Level::Info => " INFO",
        Level::Debug => "DEBUG",
    };
    match corr {
        Some(c) => format!("[{t:8.2}s {tag} {c}] {args}"),
        None => format!("[{t:8.2}s {tag}] {args}"),
    }
}

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let corr = crate::util::telemetry::current_corr();
    let line = format_line(t, l, corr.as_deref(), args);
    let mut err = std::io::stderr().lock();
    // analyze: allow(lock-across-blocking, "the stderr lock exists to make this one write atomic")
    let _ = writeln!(err, "{line}");
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! errorlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_values_map_to_levels() {
        assert_eq!(parse_level(Some("error")), Level::Error as u8);
        assert_eq!(parse_level(Some("warn")), Level::Warn as u8);
        assert_eq!(parse_level(Some("debug")), Level::Debug as u8);
        assert_eq!(parse_level(Some("info")), Level::Info as u8);
        assert_eq!(parse_level(Some("garbage")), Level::Info as u8);
        assert_eq!(parse_level(None), Level::Info as u8);
    }

    #[test]
    fn filtering_respects_level() {
        // regression for SPARSEFW_LOG-driven filtering: flip the level
        // and check which messages pass (restore info after — other
        // tests' output shouldn't be affected)
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn lines_carry_correlation_when_active() {
        let bare = format_line(1.5, Level::Info, None, format_args!("hello"));
        assert_eq!(bare, "[    1.50s  INFO] hello");
        let with = format_line(1.5, Level::Info, Some("job-7"), format_args!("hello"));
        assert_eq!(with, "[    1.50s  INFO job-7] hello");
        // the active thread-local corr id is what log() picks up
        let _g = crate::util::telemetry::with_correlation("corr-x");
        let corr = crate::util::telemetry::current_corr();
        let line = format_line(0.0, Level::Warn, corr.as_deref(), format_args!("m"));
        assert!(line.contains(" WARN corr-x] m"), "{line}");
    }
}
