//! Tiny leveled logger with wall-clock timestamps.
//!
//! Keeps the coordinator's progress reporting dependency-free. Level is
//! controlled by `SPARSEFW_LOG` (`error|warn|info|debug`, default `info`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != u8::MAX {
        return l;
    }
    let l = match std::env::var("SPARSEFW_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        _ => 2,
    };
    LEVEL.store(l, Ordering::Relaxed);
    l
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub fn log(l: Level, args: std::fmt::Arguments<'_>) {
    if (l as u8) > level() {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => " WARN",
        Level::Info => " INFO",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    // analyze: allow(lock-across-blocking, "the stderr lock exists to make this one write atomic")
    let _ = writeln!(err, "[{t:8.2}s {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! warnlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debuglog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! errorlog {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) };
}
