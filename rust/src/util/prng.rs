//! Deterministic PRNGs used across the coordinator.
//!
//! [`SplitMix64`] is a line-for-line mirror of `python/compile/prng.py`;
//! the synthetic corpus generator depends on the two producing identical
//! streams (verified against golden values in the AOT manifest).
//! [`Xoshiro256`] (seeded via SplitMix64) is the general-purpose engine
//! for sampling, shuffling and test-input generation.

/// Sebastiano Vigna's splitmix64. Mirrors `python/compile/prng.py`.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Plain-modulo draw in `[0, bound)` — deliberately *not* rejection
    /// sampled so the python mirror stays line-for-line identical.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// Stateless splitmix-style mixer for derived streams (hash of a key).
/// Mirrors `prng.mix64` in python.
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast general-purpose engine for everything that does
/// not need python parity (weight noise, shuffles, property tests).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Unbiased draw in `[0, bound)` (Lemire-style rejection).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % bound;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values of splitmix64(seed=0), widely published.
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(r.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(r.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(1234);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xoshiro_uniformish() {
        let mut r = Xoshiro256::new(7);
        let mut buckets = [0usize; 8];
        for _ in 0..8000 {
            buckets[r.next_below(8) as usize] += 1;
        }
        for b in buckets {
            assert!((800..1200).contains(&b), "bucket {b} out of range");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(99);
        let n = 20000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
