//! Data substrate: the synthetic corpus generator (python mirror) and
//! token-bin dataset loading/batching.

pub mod corpus;
pub mod dataset;

pub use corpus::CorpusGen;
pub use dataset::TokenBin;
