//! Token-bin datasets and sequence batching.
//!
//! The AOT step writes `train.bin` / `val.bin` / `test.bin` as raw u8
//! token streams (vocab 256).  This module loads them, slices them into
//! fixed-length sequences, and samples calibration batches the way the
//! paper samples C4 sequences (random offsets, seeded).

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::prng::Xoshiro256;

/// A loaded token stream.
#[derive(Clone)]
pub struct TokenBin {
    pub tokens: Vec<u8>,
}

impl TokenBin {
    pub fn load(path: &Path) -> Result<Self> {
        let tokens =
            std::fs::read(path).with_context(|| format!("reading token bin {path:?}"))?;
        ensure!(!tokens.is_empty(), "empty token bin {path:?}");
        Ok(Self { tokens })
    }

    pub fn from_tokens(tokens: Vec<u8>) -> Self {
        Self { tokens }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Deterministic non-overlapping sequences (evaluation protocol:
    /// "100 sequences from the validation split").
    pub fn sequential(&self, seq_len: usize, max_seqs: usize) -> Vec<Vec<u8>> {
        let n = (self.tokens.len() / seq_len).min(max_seqs);
        (0..n)
            .map(|i| self.tokens[i * seq_len..(i + 1) * seq_len].to_vec())
            .collect()
    }

    /// Random-offset calibration sample (paper: "randomly sample
    /// 2048-token sequences from C4"), seeded for reproducibility.
    pub fn sample(&self, seq_len: usize, n_seqs: usize, seed: u64) -> Vec<Vec<u8>> {
        assert!(self.tokens.len() > seq_len, "bin shorter than seq_len");
        let mut rng = Xoshiro256::new(seed);
        let bound = (self.tokens.len() - seq_len) as u64;
        (0..n_seqs)
            .map(|_| {
                let off = rng.next_below(bound) as usize;
                self.tokens[off..off + seq_len].to_vec()
            })
            .collect()
    }
}

/// Group sequences into batches of at most `batch` sequences each.
pub fn batches(seqs: &[Vec<u8>], batch: usize) -> Vec<&[Vec<u8>]> {
    assert!(batch > 0);
    seqs.chunks(batch).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bin(n: usize) -> TokenBin {
        TokenBin::from_tokens((0..n).map(|i| (i % 256) as u8).collect())
    }

    #[test]
    fn sequential_slices() {
        let b = bin(1000);
        let seqs = b.sequential(128, 100);
        assert_eq!(seqs.len(), 7);
        assert!(seqs.iter().all(|s| s.len() == 128));
        assert_eq!(seqs[1][0], 128u8);
        assert_eq!(b.sequential(128, 3).len(), 3);
    }

    #[test]
    fn sample_deterministic_and_in_bounds() {
        let b = bin(5000);
        let a = b.sample(128, 16, 9);
        let c = b.sample(128, 16, 9);
        assert_eq!(a, c);
        let d = b.sample(128, 16, 10);
        assert_ne!(a, d);
        assert!(a.iter().all(|s| s.len() == 128));
    }

    #[test]
    fn batching() {
        let b = bin(5000);
        let seqs = b.sample(64, 10, 1);
        let bs = batches(&seqs, 4);
        assert_eq!(bs.len(), 3);
        assert_eq!(bs[2].len(), 2);
    }
}
