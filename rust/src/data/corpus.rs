//! Synthetic corpus generator — line-for-line mirror of
//! `python/compile/data.py` (see that file and DESIGN.md §4 for the
//! process definition).  Parity with the python stream is asserted
//! against golden tokens embedded in the AOT manifest.

use crate::util::prng::{mix64, SplitMix64};

pub const VOCAB: usize = 256;

pub const P_COPY: f64 = 0.04;
pub const P_MARKOV: f64 = 0.65;
pub const P_SUPER: f64 = 0.90;
pub const COPY_BACK: usize = 16;
pub const COPY_LEN: usize = 8;
pub const SUPER_MIN_TOKEN: u8 = 248;
pub const N_SUCCESSORS: u64 = 4;

const SUCC_SALT: u64 = 0xC0FFEE;
const SUPER_SALT: u64 = 0x5EED_BEEF;

const ZIPF_SCALE: u64 = 1 << 20;

/// Integer cumulative Zipf weights, w_i = ZIPF_SCALE / (i + 4).
fn zipf_cdf() -> Vec<u64> {
    let mut cdf = Vec::with_capacity(VOCAB);
    let mut acc = 0u64;
    for i in 0..VOCAB as u64 {
        acc += ZIPF_SCALE / (i + 4);
        cdf.push(acc);
    }
    cdf
}

/// `slot`-th preferred successor of token `prev`.
pub fn successor(prev: u8, slot: u64) -> u8 {
    (mix64(prev as u64 * N_SUCCESSORS + slot + SUCC_SALT) % VOCAB as u64) as u8
}

pub fn super_successor(prev: u8) -> u8 {
    (mix64(prev as u64 + SUPER_SALT) % VOCAB as u64) as u8
}

/// Streaming generator over the corpus process.
pub struct CorpusGen {
    rng: SplitMix64,
    cdf: Vec<u64>,
    total: u64,
    history: Vec<u8>,
    copy_remaining: usize,
}

impl CorpusGen {
    pub fn new(seed: u64) -> Self {
        let cdf = zipf_cdf();
        let total = *cdf.last().unwrap();
        Self {
            rng: SplitMix64::new(seed),
            cdf,
            total,
            history: Vec::new(),
            copy_remaining: 0,
        }
    }

    fn zipf_sample(&mut self) -> u8 {
        let u = self.rng.next_below(self.total);
        // first index with cdf[i] > u
        let (mut lo, mut hi) = (0usize, VOCAB - 1);
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.cdf[mid] > u {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo as u8
    }

    pub fn next_token(&mut self) -> u8 {
        let n = self.history.len();
        let t = if self.copy_remaining > 0 {
            self.copy_remaining -= 1;
            self.history[n - COPY_BACK]
        } else {
            let r = self.rng.next_f64();
            if n > 0 && self.history[n - 1] >= SUPER_MIN_TOKEN && r < P_SUPER {
                super_successor(self.history[n - 1])
            } else if n >= COPY_BACK + COPY_LEN && r < P_COPY {
                self.copy_remaining = COPY_LEN - 1;
                self.history[n - COPY_BACK]
            } else if n > 0 && r < P_COPY + P_MARKOV {
                let slot = self.rng.next_below(N_SUCCESSORS);
                successor(self.history[n - 1], slot)
            } else {
                self.zipf_sample()
            }
        };
        self.history.push(t);
        t
    }

    pub fn generate(&mut self, n: usize) -> Vec<u8> {
        (0..n).map(|_| self.next_token()).collect()
    }
}

/// Generate `n` tokens for `seed` (one-shot convenience).
pub fn generate(seed: u64, n: usize) -> Vec<u8> {
    CorpusGen::new(seed).generate(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(generate(42, 256), generate(42, 256));
        assert_ne!(generate(42, 256), generate(43, 256));
    }

    #[test]
    fn prefix_stability() {
        // generating more tokens must not change the prefix
        let a = generate(7, 64);
        let b = generate(7, 256);
        assert_eq!(a[..], b[..64]);
    }

    #[test]
    fn copy_motifs_present() {
        let toks = generate(1, 20_000);
        // count positions where t[i] == t[i-COPY_BACK]; with 4% copy
        // triggers of length 8 this should be well above chance (~1/256
        // baseline plus markov recurrence).
        let hits = (COPY_BACK..toks.len())
            .filter(|&i| toks[i] == toks[i - COPY_BACK])
            .count();
        let rate = hits as f64 / (toks.len() - COPY_BACK) as f64;
        assert!(rate > 0.10, "copy-rate {rate} too low");
    }

    #[test]
    fn super_tokens_chain() {
        let toks = generate(2, 50_000);
        let mut chained = 0usize;
        let mut total = 0usize;
        for i in 1..toks.len() {
            if toks[i - 1] >= SUPER_MIN_TOKEN {
                total += 1;
                if toks[i] == super_successor(toks[i - 1]) {
                    chained += 1;
                }
            }
        }
        assert!(total > 50, "super tokens too rare ({total})");
        let rate = chained as f64 / total as f64;
        assert!(rate > 0.8, "super-chain rate {rate}");
    }

    #[test]
    fn marginal_is_heavy_tailed() {
        let toks = generate(3, 100_000);
        let mut counts = [0usize; VOCAB];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        // the markov/copy layers spread mass via hashing, so the tail is
        // fatter than pure zipf; still, the lowest-index tokens must be
        // clearly over-represented vs uniform (16/256 = 6.25%)
        let head: usize = counts[..16].iter().sum();
        assert!(head as f64 > 0.10 * toks.len() as f64, "head mass {head}");
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max > 4.0 * (toks.len() as f64 / VOCAB as f64), "max {max}");
    }
}
