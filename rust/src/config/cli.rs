//! Minimal CLI argument parser (the offline registry has no `clap`).
//!
//! Grammar: `sparsefw <subcommand> [--key value | --key=value | --flag]…`
//! A `--key` followed by another flag-looking token (or end-of-args) is
//! a boolean flag.  Negative numbers are *values*, not flags: numeric
//! keys accept `-`-prefixed tokens that parse as numbers
//! (`--alpha -0.5`), while non-numeric `-`-prefixed tokens still read
//! as flags.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use crate::pruner::{Method, MethodRegistry, RefinePass, SparsityPattern, Warmstart};
use crate::util::json;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub bools: BTreeSet<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| is_value_token(n)).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(key.to_string(), v);
                } else {
                    args.bools.insert(key.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{key} must be an integer")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("--{key} must be a number")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.get_usize(key, default as usize)? as u64)
    }

    /// Optional numeric flag: `None` when absent (no default value
    /// makes sense — e.g. `--job-timeout SECS`, unbounded if unset).
    pub fn get_f64_opt(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(s) => s
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key} must be a number")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.contains(key)
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, key: &str) -> Vec<String> {
        self.get(key)
            .map(|s| s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
            .unwrap_or_default()
    }
}

/// A following token counts as a key's value when it does not look like
/// a flag: anything not `-`-prefixed, plus negative numbers
/// (`--alpha -0.5`, `--shift -2`) which numeric-style keys must accept.
fn is_value_token(tok: &str) -> bool {
    !tok.starts_with('-') || tok.parse::<f64>().is_ok()
}

/// Parse a sparsity pattern: `unstructured:0.6`, `per-row:0.5`, `2:4`,
/// or `nm:2:4`.
pub fn parse_pattern(s: &str) -> Result<SparsityPattern> {
    let parts: Vec<&str> = s.split(':').collect();
    match parts.as_slice() {
        ["unstructured", v] => Ok(SparsityPattern::Unstructured { sparsity: v.parse()? }),
        ["per-row", v] | ["per_row", v] => Ok(SparsityPattern::PerRow { sparsity: v.parse()? }),
        ["nm", k, b] => Ok(SparsityPattern::NM { keep: k.parse()?, block: b.parse()? }),
        [k, b] if k.parse::<usize>().is_ok() && b.parse::<usize>().is_ok() => {
            Ok(SparsityPattern::NM { keep: k.parse()?, block: b.parse()? })
        }
        _ => bail!("cannot parse pattern {s:?} (try unstructured:0.6, per-row:0.5, 2:4)"),
    }
}

pub fn parse_warmstart(s: &str) -> Result<Warmstart> {
    Ok(match s {
        "wanda" => Warmstart::Wanda,
        "ria" => Warmstart::Ria,
        "magnitude" => Warmstart::Magnitude,
        _ => bail!("unknown warmstart {s:?}"),
    })
}

/// Build a [`Method`] from CLI flags, through the global
/// [`MethodRegistry`]: `--method NAME` routes to the method's
/// registered CLI lowering (default config for methods registered
/// without one), and `--method-json '{"kind": …}'` passes an arbitrary
/// JSON config — so a newly registered method is immediately reachable
/// from the CLI with zero parser changes.
pub fn parse_method(args: &Args) -> Result<Method> {
    if let Some(src) = args.get("method-json") {
        if args.get("method").is_some() {
            bail!("--method and --method-json conflict; pass one or the other");
        }
        let v = json::parse(src)
            .map_err(|e| anyhow::anyhow!("--method-json is not valid JSON: {e}"))?;
        return crate::config::method_from_json(&v);
    }
    let name = args.get("method").unwrap_or("sparsefw");
    MethodRegistry::global().method_from_cli(name, args)
}

/// Parse the `--refine` flag (`swaps`, `update`, `swaps,update`, or
/// `none`) into refinement post-passes.
pub fn parse_refine(args: &Args) -> Result<Vec<RefinePass>> {
    match args.get("refine") {
        Some(s) => RefinePass::parse_list(s),
        None => Ok(Vec::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_basic() {
        let a = Args::parse(argv("prune --model tiny --iters=300 --fast --alpha 0.5")).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("prune"));
        assert_eq!(a.get("model"), Some("tiny"));
        assert_eq!(a.get_usize("iters", 0).unwrap(), 300);
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), 0.5);
        assert!(a.has("fast"));
        assert_eq!(a.get_usize("missing", 42).unwrap(), 42);
    }

    #[test]
    fn optional_numeric_flags() {
        let a = Args::parse(argv("serve --job-timeout 2.5")).unwrap();
        assert_eq!(a.get_f64_opt("job-timeout").unwrap(), Some(2.5));
        assert_eq!(a.get_f64_opt("absent").unwrap(), None);
        let a = Args::parse(argv("serve --job-timeout soon")).unwrap();
        assert!(a.get_f64_opt("job-timeout").is_err());
    }

    #[test]
    fn negative_numeric_values() {
        // regression: `--alpha -0.5` must bind -0.5 as the value, not
        // turn `alpha` into a boolean flag
        let a = Args::parse(argv("prune --alpha -0.5 --shift -2 --eps=-1e-3 --fast")).unwrap();
        assert_eq!(a.get_f64("alpha", 0.0).unwrap(), -0.5);
        assert!(!a.bools.contains("alpha"));
        assert_eq!(a.get_f64("shift", 0.0).unwrap(), -2.0);
        assert_eq!(a.get_f64("eps", 0.0).unwrap(), -1e-3);
        assert!(a.has("fast"));
        // non-numeric `-`-prefixed tokens are not swallowed as values
        assert!(Args::parse(argv("x --name -oops")).is_err());
    }

    #[test]
    fn parse_lists_and_errors() {
        let a = Args::parse(argv("x --models tiny,small")).unwrap();
        assert_eq!(a.get_list("models"), vec!["tiny", "small"]);
        assert!(Args::parse(argv("x stray extra")).is_err());
    }

    #[test]
    fn patterns() {
        assert_eq!(
            parse_pattern("unstructured:0.6").unwrap(),
            SparsityPattern::Unstructured { sparsity: 0.6 }
        );
        assert_eq!(
            parse_pattern("per-row:0.5").unwrap(),
            SparsityPattern::PerRow { sparsity: 0.5 }
        );
        assert_eq!(parse_pattern("2:4").unwrap(), SparsityPattern::NM { keep: 2, block: 4 });
        assert_eq!(parse_pattern("nm:1:4").unwrap(), SparsityPattern::NM { keep: 1, block: 4 });
        assert!(parse_pattern("wat").is_err());
    }

    #[test]
    fn methods() {
        use crate::config::method_to_json;
        use crate::pruner::fw_engine::DEFAULT_REFRESH_EVERY;
        let a = Args::parse(argv("p --method sparsefw --iters 100 --alpha 0.25 --warmstart ria"))
            .unwrap();
        let m = parse_method(&a).unwrap();
        assert_eq!(m.name(), "sparsefw");
        let mj = method_to_json(&m);
        assert_eq!(mj.at(&["iters"]).as_usize(), Some(100));
        assert_eq!(mj.at(&["alpha"]).as_f64(), Some(0.25));
        assert_eq!(mj.at(&["warmstart"]).as_str(), Some("ria"));
        assert_eq!(
            mj.at(&["engine"]).as_str(),
            Some("incremental"),
            "incremental is the default"
        );
        assert_eq!(mj.at(&["refresh_every"]).as_usize(), Some(DEFAULT_REFRESH_EVERY));
        let a = Args::parse(argv("p --method wanda")).unwrap();
        assert_eq!(parse_method(&a).unwrap().name(), "wanda");
        // unknown methods error naming the registered set
        let a = Args::parse(argv("p --method prune-o-matic")).unwrap();
        let err = parse_method(&a).unwrap_err().to_string();
        assert!(err.contains("prune-o-matic") && err.contains("wanda"), "{err}");
    }

    #[test]
    fn method_json_flag_bypasses_per_method_flags() {
        let a = Args::parse(vec![
            "p".to_string(),
            "--method-json".to_string(),
            r#"{"kind": "sparsegpt", "percdamp": 0.05}"#.to_string(),
        ])
        .unwrap();
        let m = parse_method(&a).unwrap();
        assert_eq!(m.name(), "sparsegpt");
        let mj = crate::config::method_to_json(&m);
        assert_eq!(mj.at(&["percdamp"]).as_f64(), Some(0.05));
        assert_eq!(mj.at(&["blocksize"]).as_usize(), Some(128));
        // passing both selection flags is a refused conflict
        let a = Args::parse(argv("p --method wanda --method-json {}")).unwrap();
        let err = parse_method(&a).unwrap_err().to_string();
        assert!(err.contains("conflict"), "{err}");
    }

    #[test]
    fn fw_engine_flags() {
        let a = Args::parse(argv("p --method sparsefw --fw-engine dense --fw-refresh 16"))
            .unwrap();
        let mj = crate::config::method_to_json(&parse_method(&a).unwrap());
        assert_eq!(mj.at(&["engine"]).as_str(), Some("dense"));
        assert_eq!(mj.at(&["refresh_every"]).as_usize(), Some(16));
        let a = Args::parse(argv("p --method sparsefw --fw-engine warp")).unwrap();
        assert!(parse_method(&a).is_err());
    }

    #[test]
    fn refine_flag_parses_pass_lists() {
        let a = Args::parse(argv("p --refine swaps,update")).unwrap();
        assert_eq!(
            parse_refine(&a).unwrap(),
            vec![RefinePass::swaps(), RefinePass::update()]
        );
        let a = Args::parse(argv("p --refine none")).unwrap();
        assert!(parse_refine(&a).unwrap().is_empty());
        let a = Args::parse(argv("p")).unwrap();
        assert!(parse_refine(&a).unwrap().is_empty());
        let a = Args::parse(argv("p --refine polish")).unwrap();
        assert!(parse_refine(&a).is_err());
    }
}
