//! Workspace + run configuration.
//!
//! [`Workspace`] ties together the artifacts directory (manifest, token
//! bins, checkpoints, HLO executables).  The CLI lowers its flags into
//! a declarative [`crate::coordinator::JobSpec`]; the shared
//! method/pattern JSON codecs live here ([`method_to_json`] & co), and
//! the legacy [`PruneRunConfig`] remains for stored run configs.

pub mod cli;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::TokenBin;
use crate::model::Gpt;
use crate::pruner::{Method, MethodRegistry, SparseFwConfig, SparsityPattern};
use crate::runtime::{Manifest, PjrtRuntime};
use crate::util::json::Json;

/// An opened artifacts directory.
pub struct Workspace {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Workspace {
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        Ok(Self { dir, manifest })
    }

    /// Default location: `$SPARSEFW_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("SPARSEFW_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn load_model(&self, name: &str) -> Result<Gpt> {
        let cfg = self.manifest.model_config(name)?;
        let ckpt = self.manifest.checkpoint_path(name)?;
        Gpt::load(cfg, &ckpt).with_context(|| format!("loading model {name}"))
    }

    pub fn train_bin(&self) -> Result<TokenBin> {
        TokenBin::load(&self.manifest.data_bin("train")?)
    }

    pub fn val_bin(&self) -> Result<TokenBin> {
        TokenBin::load(&self.manifest.data_bin("val")?)
    }

    pub fn test_bin(&self) -> Result<TokenBin> {
        TokenBin::load(&self.manifest.data_bin("test")?)
    }

    pub fn runtime(&self) -> Result<PjrtRuntime> {
        PjrtRuntime::new(self.manifest.clone())
    }
}

/// Which FW-kernel backend executes the hot loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Rust-native matmuls (no artifacts needed).
    Native,
    /// AOT Pallas kernels through PJRT, per-iteration round-trips.
    Pjrt,
    /// PJRT with the fused multi-iteration chunk executable.
    PjrtChunk,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            "pjrt-chunk" | "pjrt_chunk" => Backend::PjrtChunk,
            _ => bail!("unknown backend {s:?} (native|pjrt|pjrt-chunk)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
            Backend::PjrtChunk => "pjrt-chunk",
        }
    }
}

// ---------------------------------------------------------------------------
// Shared JSON codecs for method / pattern — the substrate behind both
// the legacy [`PruneRunConfig`] and the declarative
// [`crate::coordinator::JobSpec`].
// ---------------------------------------------------------------------------

/// Serialize a [`Method`] to its JSON object form: the method's own
/// config fields plus the `"kind"` discriminator (the registry name).
pub fn method_to_json(method: &Method) -> Json {
    let mut obj = match method.config_to_json() {
        Json::Obj(m) => m,
        _ => Default::default(),
    };
    obj.insert("kind".to_string(), Json::Str(method.name().to_string()));
    Json::Obj(obj)
}

/// Parse a [`Method`] from its JSON object form through the global
/// [`MethodRegistry`].  A missing `"kind"` defaults to `"sparsefw"`
/// (the enum-era behaviour); missing config fields fall back to the
/// method's defaults, but *unknown* fields are a named hard error
/// (a typo'd `"alhpa"` must not silently mean "default α").
pub fn method_from_json(mj: &Json) -> Result<Method> {
    let kind = mj.at(&["kind"]).as_str().unwrap_or("sparsefw");
    MethodRegistry::global().method_from_json(kind, mj)
}

/// Serialize a [`SparsityPattern`] to its JSON object form.
pub fn pattern_to_json(pattern: &SparsityPattern) -> Json {
    match pattern {
        SparsityPattern::Unstructured { sparsity } => Json::obj(vec![
            ("kind", "unstructured".into()),
            ("sparsity", (*sparsity).into()),
        ]),
        SparsityPattern::PerRow { sparsity } => Json::obj(vec![
            ("kind", "per_row".into()),
            ("sparsity", (*sparsity).into()),
        ]),
        SparsityPattern::NM { keep, block } => Json::obj(vec![
            ("kind", "nm".into()),
            ("keep", (*keep).into()),
            ("block", (*block).into()),
        ]),
    }
}

/// Parse a [`SparsityPattern`] from its JSON object form.
pub fn pattern_from_json(pj: &Json) -> Result<SparsityPattern> {
    Ok(match pj.at(&["kind"]).as_str().unwrap_or("unstructured") {
        "unstructured" => SparsityPattern::Unstructured {
            sparsity: pj.at(&["sparsity"]).as_f64().unwrap_or(0.5),
        },
        "per_row" => SparsityPattern::PerRow {
            sparsity: pj.at(&["sparsity"]).as_f64().unwrap_or(0.5),
        },
        "nm" => SparsityPattern::NM {
            keep: pj.at(&["keep"]).as_usize().unwrap_or(2),
            block: pj.at(&["block"]).as_usize().unwrap_or(4),
        },
        other => bail!("unknown pattern {other:?}"),
    })
}

/// Full description of one pruning run (JSON round-trippable).
///
/// Superseded by the richer [`crate::coordinator::JobSpec`] (which adds
/// non-uniform allocation, tracing and eval options); kept for
/// callers that stored run configs in report JSON.
#[derive(Clone, Debug)]
pub struct PruneRunConfig {
    pub model: String,
    pub method: Method,
    pub pattern: SparsityPattern,
    pub calib_samples: usize,
    pub calib_seed: u64,
    pub backend: Backend,
}

impl Default for PruneRunConfig {
    fn default() -> Self {
        Self {
            model: "tiny".into(),
            method: Method::sparsefw(SparseFwConfig::default()),
            pattern: SparsityPattern::Unstructured { sparsity: 0.6 },
            calib_samples: 128,
            calib_seed: 7,
            backend: Backend::Native,
        }
    }
}

impl PruneRunConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", self.model.as_str().into()),
            ("method", method_to_json(&self.method)),
            ("pattern", pattern_to_json(&self.pattern)),
            ("calib_samples", self.calib_samples.into()),
            ("calib_seed", (self.calib_seed as usize).into()),
            ("backend", self.backend.label().into()),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            model: v.at(&["model"]).as_str().unwrap_or("tiny").to_string(),
            method: method_from_json(v.at(&["method"]))?,
            pattern: pattern_from_json(v.at(&["pattern"]))?,
            calib_samples: v.at(&["calib_samples"]).as_usize().unwrap_or(128),
            calib_seed: v.at(&["calib_seed"]).as_f64().unwrap_or(7.0) as u64,
            backend: Backend::parse(v.at(&["backend"]).as_str().unwrap_or("native"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::{FwEngine, Warmstart};
    use crate::util::json;

    #[test]
    fn run_config_roundtrip() {
        let cfg = PruneRunConfig {
            model: "small".into(),
            method: Method::sparsefw(SparseFwConfig {
                iters: 123,
                alpha: 0.25,
                warmstart: Warmstart::Ria,
                trace_every: 10,
                use_chunk: false,
                keep_best: true,
                line_search: false,
                engine: FwEngine::Dense,
                refresh_every: 32,
            }),
            pattern: SparsityPattern::NM { keep: 2, block: 4 },
            calib_samples: 64,
            calib_seed: 99,
            backend: Backend::PjrtChunk,
        };
        let j = cfg.to_json();
        let back = PruneRunConfig::from_json(&json::parse(&json::to_string(&j)).unwrap()).unwrap();
        assert_eq!(back.model, "small");
        assert_eq!(back.calib_samples, 64);
        assert_eq!(back.calib_seed, 99);
        assert_eq!(back.backend, Backend::PjrtChunk);
        // the parsed method is the same registry method with the same
        // config — compare the canonical JSON forms
        assert_eq!(back.method.name(), "sparsefw");
        assert_eq!(
            json::to_string(&method_to_json(&cfg.method)),
            json::to_string(&method_to_json(&back.method))
        );
        let mj = method_to_json(&back.method);
        assert_eq!(mj.at(&["iters"]).as_usize(), Some(123));
        assert_eq!(mj.at(&["warmstart"]).as_str(), Some("ria"));
        assert_eq!(mj.at(&["engine"]).as_str(), Some("dense"));
        assert_eq!(mj.at(&["refresh_every"]).as_usize(), Some(32));
        assert_eq!(back.pattern, SparsityPattern::NM { keep: 2, block: 4 });
    }

    #[test]
    fn method_json_unknown_kind_and_field_are_errors() {
        let err = method_from_json(&json::parse(r#"{"kind": "prune-o-matic"}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("prune-o-matic"), "{err}");
        assert!(err.contains("wanda"), "error must name the known set: {err}");
        // missing kind defaults to sparsefw (enum-era behaviour)...
        let m = method_from_json(&json::parse("{}").unwrap()).unwrap();
        assert_eq!(m.name(), "sparsefw");
        // ...but unknown fields inside a known method are hard errors
        let err = method_from_json(&json::parse(r#"{"kind": "sparsefw", "alhpa": 0.1}"#).unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("alhpa"), "{err}");
    }

    #[test]
    fn backend_parse() {
        assert!(Backend::parse("native").is_ok());
        assert!(Backend::parse("pjrt-chunk").is_ok());
        assert!(Backend::parse("gpu").is_err());
    }
}
