//! The method registry — the single source of truth for which pruning
//! methods exist.
//!
//! A [`MethodRegistration`] bundles everything the stack needs to know
//! about one method: its name, a default-config constructor, the JSON
//! codec (`{"kind": name, …config}` ↔ [`Method`]), and an optional CLI
//! lowering (`--method name` + method-specific flags).  CLI parsing
//! ([`crate::config::cli::parse_method`]), JobSpec round-trips
//! ([`crate::config::method_from_json`]), server-side submit validation,
//! `GET /methods` / `sparsefw methods` listings and the
//! `table1_methods` bench all iterate this registry — registering a
//! method is the *only* step after implementing
//! [`LayerPruner`](crate::pruner::LayerPruner).
//!
//! JSON parsing is strict about field names: an unknown top-level key in
//! a method config object is a hard error naming the field (a typo'd
//! `"alhpa"` must not silently fall back to the default α).

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{bail, Result};

use crate::config::cli::{parse_warmstart, Args};
use crate::pruner::fw_engine::FwEngine;
use crate::pruner::method::Method;
use crate::pruner::sparsefw::SparseFwConfig;
use crate::util::json::Json;

type JsonFactory = Box<dyn Fn(&Json) -> Result<Method> + Send + Sync>;
type CliFactory = Box<dyn Fn(&Args) -> Result<Method> + Send + Sync>;
type DefaultFactory = Box<dyn Fn() -> Method + Send + Sync>;

/// Everything the registry knows about one method.
pub struct MethodRegistration {
    name: String,
    make_default: DefaultFactory,
    from_json: JsonFactory,
    from_cli: Option<CliFactory>,
}

impl MethodRegistration {
    /// Register `name` with a default constructor and a JSON config
    /// parser.  The parser receives the full method object (including
    /// `"kind"`) and must reject unknown fields — use
    /// [`check_config_fields`] for that.
    pub fn new(
        name: impl Into<String>,
        make_default: impl Fn() -> Method + Send + Sync + 'static,
        from_json: impl Fn(&Json) -> Result<Method> + Send + Sync + 'static,
    ) -> Self {
        Self {
            name: name.into(),
            make_default: Box::new(make_default),
            from_json: Box::new(from_json),
            from_cli: None,
        }
    }

    /// Add a CLI lowering (method-specific flags → configured method).
    /// Without one, `--method name` builds the default configuration
    /// (and `--method-json` can still pass arbitrary config).
    pub fn with_cli(
        mut self,
        from_cli: impl Fn(&Args) -> Result<Method> + Send + Sync + 'static,
    ) -> Self {
        self.from_cli = Some(Box::new(from_cli));
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Reject unknown top-level fields in a method config object.  `"kind"`
/// is always allowed; everything else must appear in `allowed`.
pub fn check_config_fields(kind: &str, mj: &Json, allowed: &[&str]) -> Result<()> {
    if let Some(obj) = mj.as_obj() {
        for key in obj.keys() {
            if key != "kind" && !allowed.iter().any(|a| a == key) {
                bail!(
                    "unknown field {key:?} in {kind:?} method config (allowed: {})",
                    if allowed.is_empty() { "none".to_string() } else { allowed.join(", ") }
                );
            }
        }
    }
    Ok(())
}

/// Like [`check_config_fields`], with the allowed set derived from the
/// method's own default `config_to_json` keys — one source of truth, so
/// a config field added to the serializer is automatically accepted by
/// the parser (and the registry can never reject its own output).
pub fn check_config_fields_against(kind: &str, mj: &Json, default: &Method) -> Result<()> {
    let allowed: Vec<String> = match default.config_to_json() {
        Json::Obj(m) => m.keys().cloned().collect(),
        _ => Vec::new(),
    };
    let allowed: Vec<&str> = allowed.iter().map(|s| s.as_str()).collect();
    check_config_fields(kind, mj, &allowed)
}

/// Name → [`MethodRegistration`] map behind the whole stack.
pub struct MethodRegistry {
    inner: RwLock<BTreeMap<String, Arc<MethodRegistration>>>,
}

impl MethodRegistry {
    /// An empty registry (tests; prefer [`MethodRegistry::global`]).
    pub fn new() -> Self {
        Self { inner: RwLock::new(BTreeMap::new()) }
    }

    /// The process-wide registry, pre-populated with the built-ins
    /// (magnitude, wanda, ria, sparsefw, sparsegpt).
    pub fn global() -> &'static MethodRegistry {
        static GLOBAL: OnceLock<MethodRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let reg = MethodRegistry::new();
            for r in builtin_registrations() {
                reg.register(r);
            }
            reg
        })
    }

    /// Register (or replace — latest wins) a method.
    pub fn register(&self, registration: MethodRegistration) {
        self.inner
            .write()
            .unwrap()
            .insert(registration.name.clone(), Arc::new(registration));
    }

    /// Registered method names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().keys().cloned().collect()
    }

    pub fn contains(&self, name: &str) -> bool {
        self.inner.read().unwrap().contains_key(name)
    }

    fn lookup(&self, name: &str) -> Result<Arc<MethodRegistration>> {
        // clone out of the guard before formatting an error: names()
        // re-locks, and a same-thread reentrant read is UB-adjacent
        let found = self.inner.read().unwrap().get(name).cloned();
        match found {
            Some(r) => Ok(r),
            None => bail!(
                "unknown method {name:?} (registered: {})",
                self.names().join(", ")
            ),
        }
    }

    /// Build `name` with its default configuration.
    pub fn default(&self, name: &str) -> Result<Method> {
        Ok((self.lookup(name)?.make_default)())
    }

    /// Build `name` from its JSON config object (strict field names).
    pub fn method_from_json(&self, name: &str, mj: &Json) -> Result<Method> {
        (self.lookup(name)?.from_json)(mj)
    }

    /// Build `name` from CLI flags (falls back to the default config
    /// for methods registered without a CLI lowering).
    pub fn method_from_cli(&self, name: &str, args: &Args) -> Result<Method> {
        let reg = self.lookup(name)?;
        match &reg.from_cli {
            Some(f) => f(args),
            None => Ok((reg.make_default)()),
        }
    }
}

// ---------------------------------------------------------------------------
// Built-in registrations
// ---------------------------------------------------------------------------

// missing fields fall back to the one canonical default set —
// [`SparseFwConfig::default`] — so a saved spec with a field omitted
// always parses to the same config `--method sparsefw` builds
fn sparsefw_from_json(mj: &Json) -> Result<Method> {
    let d = SparseFwConfig::default();
    check_config_fields_against("sparsefw", mj, &Method::sparsefw(d.clone()))?;
    Ok(Method::sparsefw(SparseFwConfig {
        iters: mj.at(&["iters"]).as_usize().unwrap_or(d.iters),
        alpha: mj.at(&["alpha"]).as_f64().unwrap_or(d.alpha),
        warmstart: match mj.at(&["warmstart"]).as_str() {
            Some(s) => parse_warmstart(s)?,
            None => d.warmstart,
        },
        trace_every: mj.at(&["trace_every"]).as_usize().unwrap_or(d.trace_every),
        use_chunk: mj.at(&["use_chunk"]).as_bool().unwrap_or(d.use_chunk),
        keep_best: mj.at(&["keep_best"]).as_bool().unwrap_or(d.keep_best),
        line_search: mj.at(&["line_search"]).as_bool().unwrap_or(d.line_search),
        engine: match mj.at(&["engine"]).as_str() {
            Some(s) => FwEngine::parse(s)?,
            None => d.engine,
        },
        refresh_every: mj.at(&["refresh_every"]).as_usize().unwrap_or(d.refresh_every),
    }))
}

fn sparsefw_from_cli(args: &Args) -> Result<Method> {
    let d = SparseFwConfig::default();
    Ok(Method::sparsefw(SparseFwConfig {
        iters: args.get_usize("iters", d.iters)?,
        alpha: args.get_f64("alpha", d.alpha)?,
        warmstart: match args.get("warmstart") {
            Some(s) => parse_warmstart(s)?,
            None => d.warmstart,
        },
        trace_every: args.get_usize("trace-every", d.trace_every)?,
        use_chunk: !args.has("no-chunk"),
        keep_best: !args.has("no-keep-best"),
        line_search: args.has("line-search"),
        engine: match args.get("fw-engine") {
            Some(s) => FwEngine::parse(s)?,
            None => d.engine,
        },
        refresh_every: args.get_usize("fw-refresh", d.refresh_every)?,
    }))
}

/// SparseGPT's reference-implementation defaults, shared by the
/// default constructor and both parsers.
const SPARSEGPT_PERCDAMP: f64 = 0.01;
const SPARSEGPT_BLOCKSIZE: usize = 128;

fn sparsegpt_default() -> Method {
    Method::sparsegpt(SPARSEGPT_PERCDAMP, SPARSEGPT_BLOCKSIZE)
}

fn sparsegpt_from_json(mj: &Json) -> Result<Method> {
    check_config_fields_against("sparsegpt", mj, &sparsegpt_default())?;
    Ok(Method::sparsegpt(
        mj.at(&["percdamp"]).as_f64().unwrap_or(SPARSEGPT_PERCDAMP),
        mj.at(&["blocksize"]).as_usize().unwrap_or(SPARSEGPT_BLOCKSIZE),
    ))
}

fn builtin_registrations() -> Vec<MethodRegistration> {
    let configless = |name: &'static str, make: fn() -> Method| {
        MethodRegistration::new(name, make, move |mj| {
            check_config_fields(name, mj, &[])?;
            Ok(make())
        })
    };
    vec![
        configless("magnitude", Method::magnitude),
        configless("wanda", Method::wanda),
        configless("ria", Method::ria),
        MethodRegistration::new(
            "sparsefw",
            || Method::sparsefw(SparseFwConfig::default()),
            sparsefw_from_json,
        )
        .with_cli(sparsefw_from_cli),
        MethodRegistration::new("sparsegpt", sparsegpt_default, sparsegpt_from_json)
            .with_cli(|args| {
                Ok(Method::sparsegpt(
                    args.get_f64("percdamp", SPARSEGPT_PERCDAMP)?,
                    args.get_usize("blocksize", SPARSEGPT_BLOCKSIZE)?,
                ))
            }),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn global_registry_lists_builtins_sorted() {
        let names = MethodRegistry::global().names();
        for want in ["magnitude", "ria", "sparsefw", "sparsegpt", "wanda"] {
            assert!(names.iter().any(|n| n == want), "{want} missing: {names:?}");
        }
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn unknown_method_error_names_known_set() {
        let err = MethodRegistry::global().default("bogus").unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        assert!(err.contains("sparsefw") && err.contains("wanda"), "{err}");
    }

    #[test]
    fn unknown_config_field_is_a_named_hard_error() {
        // the regression the strict parser exists for: a typo'd "alhpa"
        let mj = json::parse(r#"{"kind": "sparsefw", "alhpa": 0.5}"#).unwrap();
        let err = MethodRegistry::global()
            .method_from_json("sparsefw", &mj)
            .unwrap_err()
            .to_string();
        assert!(err.contains("alhpa"), "{err}");
        assert!(err.contains("sparsefw"), "{err}");
        // config-less methods reject any field at all
        let mj = json::parse(r#"{"kind": "wanda", "iters": 3}"#).unwrap();
        let err = MethodRegistry::global()
            .method_from_json("wanda", &mj)
            .unwrap_err()
            .to_string();
        assert!(err.contains("iters"), "{err}");
    }

    #[test]
    fn registration_replaces_latest_wins() {
        let reg = MethodRegistry::new();
        reg.register(MethodRegistration::new("m", Method::wanda, |_| Ok(Method::wanda())));
        assert_eq!(reg.default("m").unwrap().name(), "wanda");
        reg.register(MethodRegistration::new("m", Method::ria, |_| Ok(Method::ria())));
        assert_eq!(reg.default("m").unwrap().name(), "ria");
        assert_eq!(reg.names(), vec!["m".to_string()]);
    }
}
