//! Non-uniform layerwise sparsity allocation (OWL-style).
//!
//! The paper (and Wanda) use a *uniform* sparsity budget per layer; Yin
//! et al. 2023 ("Outlier Weighed Layerwise sparsity"), cited in the
//! paper's related work, show that skewing the budget by each layer's
//! activation-outlier mass helps at high sparsity.  This module
//! implements that allocation as a drop-in for any pruning method here:
//!
//! 1. per layer, compute the **outlier ratio** — the fraction of Wanda
//!    saliencies `|W_ij|·‖X_j‖` exceeding `λ × layer mean`;
//! 2. convert ratios to per-layer sparsity shifts, linearly rescaled to
//!    `[−max_shift, +max_shift]` with outlier-heavy layers getting
//!    *lower* sparsity;
//! 3. re-center the shifts so the weighted mean sparsity equals the
//!    target (the total parameter budget is preserved exactly).

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::calib::Calibration;
use crate::model::Gpt;
use crate::pruner::saliency::wanda_scores;

#[derive(Clone, Debug)]
pub struct OwlConfig {
    /// Outlier threshold multiplier λ (Yin et al. use M=5..7).
    pub lambda: f64,
    /// Maximum deviation of any layer from the target sparsity.
    pub max_shift: f64,
}

impl Default for OwlConfig {
    fn default() -> Self {
        Self { lambda: 5.0, max_shift: 0.08 }
    }
}

/// Fraction of saliencies above `λ ×` the layer mean.
pub fn outlier_ratio(saliency: &[f32], lambda: f64) -> f64 {
    if saliency.is_empty() {
        return 0.0;
    }
    let mean = saliency.iter().map(|&x| x as f64).sum::<f64>() / saliency.len() as f64;
    let thresh = lambda * mean;
    saliency.iter().filter(|&&x| (x as f64) > thresh).count() as f64 / saliency.len() as f64
}

/// Per-layer sparsities averaging (parameter-weighted) to `target`.
pub fn owl_sparsities(
    model: &Gpt,
    calib: &Calibration,
    target: f64,
    cfg: &OwlConfig,
) -> Result<BTreeMap<String, f64>> {
    ensure!((0.0..1.0).contains(&target), "target sparsity out of range");
    let layers = model.cfg.layers();
    let mut ratios = Vec::with_capacity(layers.len());
    let mut weights = Vec::with_capacity(layers.len());
    for l in &layers {
        let s = wanda_scores(model.mat(&l.name), calib.gram(&l.name));
        ratios.push(outlier_ratio(&s.data, cfg.lambda));
        weights.push((l.d_out * l.d_in) as f64);
    }

    let (rmin, rmax) = ratios
        .iter()
        .fold((f64::MAX, f64::MIN), |(a, b), &r| (a.min(r), b.max(r)));
    let span = (rmax - rmin).max(1e-12);

    // outlier-heavy layer → lower sparsity (keep more weights there)
    let raw: Vec<f64> = ratios
        .iter()
        .map(|&r| -cfg.max_shift * (2.0 * (r - rmin) / span - 1.0))
        .collect();
    // re-center: parameter-weighted mean shift must be zero
    let wsum: f64 = weights.iter().sum();
    let mean_shift: f64 = raw.iter().zip(&weights).map(|(s, w)| s * w).sum::<f64>() / wsum;

    let mut out = BTreeMap::new();
    for ((l, s), _w) in layers.iter().zip(&raw).zip(&weights) {
        let sp = (target + (s - mean_shift)).clamp(0.0, 0.999);
        out.insert(l.name.clone(), sp);
    }
    Ok(out)
}

/// Parameter-weighted mean sparsity of an allocation (sanity metric).
pub fn mean_sparsity(model: &Gpt, alloc: &BTreeMap<String, f64>) -> f64 {
    let mut acc = 0.0;
    let mut wsum = 0.0;
    for l in model.cfg.layers() {
        let w = (l.d_out * l.d_in) as f64;
        acc += alloc[&l.name] * w;
        wsum += w;
    }
    acc / wsum
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TokenBin;
    use crate::model::testutil::{random_model, tiny_cfg};

    fn setup() -> (Gpt, Calibration) {
        let cfg = tiny_cfg();
        let model = random_model(&cfg, 3);
        let bin = TokenBin::from_tokens(crate::data::corpus::generate(8, 8192));
        let calib = Calibration::collect(&model, &bin, 6, 4).unwrap();
        (model, calib)
    }

    #[test]
    fn outlier_ratio_basics() {
        assert_eq!(outlier_ratio(&[], 5.0), 0.0);
        assert_eq!(outlier_ratio(&[1.0; 100], 5.0), 0.0); // no outliers
        let mut v = vec![1.0f32; 99];
        v.push(1000.0);
        assert!((outlier_ratio(&v, 5.0) - 0.01).abs() < 1e-9);
    }

    #[test]
    fn allocation_preserves_budget_and_bounds() {
        let (model, calib) = setup();
        let cfg = OwlConfig::default();
        for target in [0.5, 0.6, 0.7] {
            let alloc = owl_sparsities(&model, &calib, target, &cfg).unwrap();
            assert_eq!(alloc.len(), model.cfg.layers().len());
            let mean = mean_sparsity(&model, &alloc);
            assert!((mean - target).abs() < 1e-9, "mean {mean} vs {target}");
            for (_name, &s) in &alloc {
                assert!(s >= target - 2.0 * cfg.max_shift - 1e-9);
                assert!(s <= target + 2.0 * cfg.max_shift + 1e-9);
            }
        }
    }

    #[test]
    fn outlier_heavy_layer_gets_lower_sparsity() {
        let (mut model, calib) = setup();
        // inflate one layer's weights so its wanda saliencies have a
        // heavy outlier tail
        {
            let w = model.params.get_mut("blocks.0.wup").unwrap();
            for (i, v) in w.data.iter_mut().enumerate() {
                if i % 97 == 0 {
                    *v *= 50.0;
                }
            }
        }
        let alloc = owl_sparsities(&model, &calib, 0.6, &OwlConfig::default()).unwrap();
        let heavy = alloc["blocks.0.wup"];
        let mean = mean_sparsity(&model, &alloc);
        assert!(
            heavy < mean,
            "outlier-heavy layer got sparsity {heavy} >= mean {mean}"
        );
    }

    #[test]
    fn rejects_bad_target() {
        let (model, calib) = setup();
        assert!(owl_sparsities(&model, &calib, 1.5, &OwlConfig::default()).is_err());
    }
}
