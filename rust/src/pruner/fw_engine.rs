//! Incremental sparse-vertex FW engine — O(nnz) iterations, intra-layer
//! parallelism, zero-alloc hot loop.
//!
//! The dense engine pays a full `(W⊙M)·G` matmul — O(d_out·d_in²) — on
//! every iteration, although each FW step only mixes in a k-sparse
//! binary vertex V.  The gradient is affine in M:
//!
//! ```text
//!   ∇L(M) = −2·W⊙(H − P),     P = (W⊙M)·G,   H = W·G
//!   M_{t+1} = (1−η)·M_t + η·V
//!   ⇒ P_{t+1} = (1−η)·P_t + η·(W⊙V)·G
//! ```
//!
//! so this engine maintains `P` across iterations and pays only the
//! sparse row-gather product `(W⊙V)·G` per step
//! ([`tensor::gather::vertex_matmul_into`], O(nnz(V)·d_in)).  At the
//! paper's operating point (50% unstructured sparsity, α = 0.9) a
//! vertex touches ~5% of the entries — a ~20× flop cut per iteration.
//! The α-fixed contribution `P̄ = (W⊙M̄)·G` is constant and computed
//! once.  The exact line-search scalars come from the same maintained
//! state: `⟨∇L, D⟩` is an elementwise pass, and
//! `q(D) = ‖(W⊙D)X‖² = Σ (S_V − P)⊙(W⊙D)` with `S_V = (W⊙V)·G` — no
//! extra objective matmul.
//!
//! **Drift control.**  `P` accumulates f32 rounding; every
//! `refresh_every` iterations the engine recomputes it exactly from the
//! current iterate (`tensor::gather::masked_matmul_into`), bounding the
//! divergence from the dense path (regression-tested to ≤ 1e-4 relative
//! after the paper's T = 2000).
//!
//! **Intra-layer parallelism.**  `L(M) = Σ_i L_i(m_i)` is
//! row-decomposable, and the `PerRow`/`NM` constraint sets decompose
//! with it, so one big layer splits into independent row blocks that
//! run the whole FW loop concurrently (the dense native backend only
//! parallelizes *across* layers, so a lone `mlp_down` serializes).  The
//! `Global` (unstructured) LMO couples rows; there the blocks run the
//! gradient/gather/update phases in parallel and reconcile the vertex
//! through an exact candidate merge (each block pre-selects its local
//! bottom-k; the global bottom-k is contained in the union).
//!
//! With `line_search`, row-separable blocks optimize η *per block* — a
//! step at least as good as any shared η on the separable objective.
//! The step then depends on the partition, so line-search runs derive
//! their block count from the layer shape alone (never the machine's
//! core count): a given `JobSpec` replays identically anywhere.
//! Open-loop runs are bit-identical for any worker count.
//!
//! [`tensor::gather::vertex_matmul_into`]: crate::tensor::gather::vertex_matmul_into
//! [`tensor::gather::masked_matmul_into`]: crate::tensor::gather::masked_matmul_into

use anyhow::{bail, Result};

use crate::pruner::lmo::lmo_into;
use crate::pruner::mask::BudgetSpec;
use crate::tensor::gather::{masked_matmul_into, vertex_matmul_into};
use crate::tensor::topk::bottom_k_into;
use crate::tensor::Mat;
use crate::util::pool::{chunk_ranges, default_workers};

/// Which native FW engine executes the hot loop (A/B comparable via
/// `--fw-engine`; PJRT backends always take their own kernel path).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FwEngine {
    /// Full `(W⊙M)·G` matmul per iteration (the reference path).
    Dense,
    /// Maintained-state engine in this module (the default).
    Incremental,
}

impl FwEngine {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dense" => FwEngine::Dense,
            "incremental" | "inc" => FwEngine::Incremental,
            _ => bail!("unknown FW engine {s:?} (dense|incremental)"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            FwEngine::Dense => "dense",
            FwEngine::Incremental => "incremental",
        }
    }
}

/// Default exact-refresh period for the maintained `P` state.
pub const DEFAULT_REFRESH_EVERY: usize = 64;

/// Below this many elements a layer (or block) is not worth splitting —
/// per-iteration thread handoff would dominate the saved work.
const PARALLEL_MIN_NUMEL: usize = 1 << 15;
/// The `Global` driver spawns threads per *iteration* (phases around
/// the LMO merge), so it needs more work per phase to amortize spawn
/// cost than the spawn-once row-separable driver.
const GLOBAL_PARALLEL_MIN_NUMEL: usize = 1 << 16;
/// Minimum rows per block when splitting.
const MIN_BLOCK_ROWS: usize = 16;
/// Block count used for line-search runs: with `line_search` the step
/// size (and so the result) depends on the block partition, so the
/// partition must derive from the layer shape alone — never from the
/// machine's core count — for `JobSpec` replays to reproduce bit-for-
/// bit anywhere.  Open-loop runs are partition-invariant and may use
/// all cores.
const LINE_SEARCH_BLOCKS: usize = 4;

/// Preallocated per-block buffers: nothing in the hot loop allocates
/// after the first iteration.
struct FwScratch {
    /// Gradient over the block (`−2·W⊙(H − P̄ − P)`, zeroed on M̄).
    grad: Vec<f32>,
    /// Sparse-vertex product `S_V = (W⊙V)·G`.
    sv: Vec<f32>,
    /// Current vertex support, block-local flat indices, sorted.
    v_idx: Vec<u32>,
    /// Selection scratch for the (bottom-k based) LMO.
    idx_buf: Vec<u32>,
    /// Global-LMO candidates `(grad value, layer-global flat index)`.
    cand: Vec<(f32, u32)>,
}

impl FwScratch {
    fn new(numel: usize) -> Self {
        Self {
            grad: vec![0.0; numel],
            sv: vec![0.0; numel],
            v_idx: Vec::new(),
            idx_buf: Vec::new(),
            cand: Vec::new(),
        }
    }
}

/// One row block of the incremental engine: the maintained products
/// plus scratch.  The weight/gram/mask slices are passed per call so a
/// block can interleave with tracing and parallel drivers without
/// holding borrows.
pub struct FwBlock {
    rows: usize,
    cols: usize,
    /// Maintained `P = (W⊙M)·G` over the free iterate.
    p: Vec<f32>,
    /// Constant `P̄ = (W⊙M̄)·G` of the α-fixed mask.
    p_fixed: Vec<f32>,
    scratch: FwScratch,
    /// Iterations taken (drives the open-loop η_t = 2/(t+2) schedule).
    t: usize,
    since_refresh: usize,
    /// Line-search partial sums (⟨∇L,D⟩, q(D)) for the global reduce.
    partials: (f64, f64),
}

fn open_loop_eta(t: usize) -> f32 {
    2.0 / (t as f32 + 2.0)
}

fn eta_from(inner: f64, q: f64, t: usize) -> f32 {
    if q <= 0.0 {
        open_loop_eta(t)
    } else {
        (-inner / (2.0 * q)).clamp(0.0, 1.0) as f32
    }
}

impl FwBlock {
    /// Build the block state for rows `w`/`fixed`/`m` (slices of a
    /// layer, `rows×cols`): computes `P` from the warmstart iterate and
    /// the constant `P̄` — O(nnz·d_in) and O(nnz(M̄)·d_in).
    pub fn new(w: &[f32], g: &Mat, fixed: &[f32], m: &[f32], rows: usize, cols: usize) -> Self {
        let numel = rows * cols;
        let mut p = vec![0.0; numel];
        masked_matmul_into(w, m, rows, cols, g, &mut p);
        let mut p_fixed = vec![0.0; numel];
        masked_matmul_into(w, fixed, rows, cols, g, &mut p_fixed);
        Self {
            rows,
            cols,
            p,
            p_fixed,
            scratch: FwScratch::new(numel),
            t: 0,
            since_refresh: 0,
            partials: (0.0, 0.0),
        }
    }

    /// `∇L = −2·W⊙(H − P̄ − P)`, zeroed on the α-fixed coordinates (the
    /// LMO then never selects them: it only takes negative entries).
    fn compute_grad(&mut self, w: &[f32], h: &[f32], fixed: &[f32]) {
        for (i, gv) in self.scratch.grad.iter_mut().enumerate() {
            *gv = if fixed[i] != 0.0 {
                0.0
            } else {
                -2.0 * w[i] * (h[i] - self.p_fixed[i] - self.p[i])
            };
        }
    }

    /// Block-local LMO into the reused index buffers.
    fn local_lmo(&mut self, budget: &BudgetSpec) {
        lmo_into(
            &self.scratch.grad,
            self.rows,
            self.cols,
            budget,
            &mut self.scratch.idx_buf,
            &mut self.scratch.v_idx,
        );
    }

    /// Global-LMO candidate pre-selection: this block's `keep` smallest
    /// gradient entries (negatives only) as (value, layer-global index)
    /// pairs with `base = first_row·cols`.  The layer-global bottom-k
    /// is a subset of the union of block bottom-k's, so the serial
    /// merge over candidates reproduces the dense LMO exactly.
    fn preselect(&mut self, keep: usize, base: u32) {
        let k = bottom_k_into(&self.scratch.grad, keep, &mut self.scratch.idx_buf);
        self.scratch.cand.clear();
        for &ix in &self.scratch.idx_buf[..k] {
            let v = self.scratch.grad[ix as usize];
            if v < 0.0 {
                self.scratch.cand.push((v, base + ix));
            }
        }
    }

    /// `S_V = (W⊙V)·G` for the current vertex.
    fn compute_sv(&mut self, w: &[f32], g: &Mat) {
        vertex_matmul_into(w, self.rows, self.cols, &self.scratch.v_idx, g, &mut self.scratch.sv);
    }

    /// Line-search partials from the maintained state (no matmul):
    /// `inner = ⟨∇L, V − M⟩` and `q = Σ (S_V − P)⊙(W⊙(V − M))`.
    fn ls_partials(&mut self, w: &[f32], m: &[f32]) {
        let s = &self.scratch;
        let mut inner = 0.0f64;
        let mut q = 0.0f64;
        for i in 0..self.rows * self.cols {
            let diff = (s.sv[i] - self.p[i]) as f64;
            inner -= s.grad[i] as f64 * m[i] as f64;
            q -= diff * w[i] as f64 * m[i] as f64;
        }
        for &ix in &s.v_idx {
            let ix = ix as usize;
            inner += s.grad[ix] as f64;
            q += (s.sv[ix] - self.p[ix]) as f64 * w[ix] as f64;
        }
        self.partials = (inner, q);
    }

    /// Convex update `M ← (1−η)M + ηV`, `P ← (1−η)P + η·S_V`.
    fn apply(&mut self, m: &mut [f32], eta: f32) {
        let a = 1.0 - eta;
        let s = &self.scratch;
        for (mv, (pv, &svv)) in m.iter_mut().zip(self.p.iter_mut().zip(&s.sv)) {
            *mv *= a;
            *pv = a * *pv + eta * svv;
        }
        for &ix in &s.v_idx {
            m[ix as usize] += eta;
        }
        self.t += 1;
    }

    /// Periodic exact recompute of `P` from the current iterate.
    fn maybe_refresh(&mut self, w: &[f32], g: &Mat, m: &[f32], refresh_every: usize) {
        self.since_refresh += 1;
        if refresh_every > 0 && self.since_refresh >= refresh_every {
            // a large drift right before the refresh means the
            // incremental update is wrong, not that fp noise piled up
            #[cfg(feature = "debug-invariants")]
            {
                let drift = self.p_rel_drift(w, g, m);
                assert!(
                    drift <= 1e-2,
                    "fw invariant: maintained P drifted {drift:.3e} from the exact \
                     recompute at refresh"
                );
            }
            masked_matmul_into(w, m, self.rows, self.cols, g, &mut self.p);
            self.since_refresh = 0;
        }
    }

    /// One full FW step with a block-local LMO (the serial and
    /// row-separable paths; the unstructured multi-block driver
    /// sequences the same phases with a merge in between).
    fn step(
        &mut self,
        w: &[f32],
        g: &Mat,
        h: &[f32],
        fixed: &[f32],
        m: &mut [f32],
        budget: &BudgetSpec,
        line_search: bool,
        refresh_every: usize,
    ) {
        self.compute_grad(w, h, fixed);
        self.local_lmo(budget);
        self.compute_sv(w, g);
        let eta = if line_search {
            self.ls_partials(w, m);
            eta_from(self.partials.0, self.partials.1, self.t)
        } else {
            open_loop_eta(self.t)
        };
        self.apply(m, eta);
        self.maybe_refresh(w, g, m, refresh_every);
    }

    /// Run `iters` steps; resumable (the iteration counter persists), so
    /// tracing callers can interleave recording.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        w: &[f32],
        g: &Mat,
        h: &[f32],
        fixed: &[f32],
        m: &mut [f32],
        budget: &BudgetSpec,
        iters: usize,
        line_search: bool,
        refresh_every: usize,
    ) {
        for _ in 0..iters {
            self.step(w, g, h, fixed, m, budget, line_search, refresh_every);
        }
    }

    /// Iterations taken so far (resumable runs accumulate).
    pub fn iters(&self) -> usize {
        self.t
    }

    /// Measure convergence at the *current* iterate without advancing
    /// it: the FW duality gap `⟨∇L, M−V⟩` (≥ 0 up to fp noise; an upper
    /// bound on suboptimality of the relaxation), the step size the
    /// next iteration would take, and the maintained-state relative
    /// drift.  Only scratch buffers are written — `m`, `P`, and the
    /// iteration counter are untouched, and `step()` recomputes every
    /// scratch quantity it uses, so probing between `run()` segments
    /// leaves the iterate sequence bit-identical.
    #[allow(clippy::too_many_arguments)]
    pub fn convergence_probe(
        &mut self,
        w: &[f32],
        g: &Mat,
        h: &[f32],
        fixed: &[f32],
        m: &[f32],
        budget: &BudgetSpec,
        line_search: bool,
    ) -> (f64, f64, f64) {
        self.compute_grad(w, h, fixed);
        self.local_lmo(budget);
        self.compute_sv(w, g);
        self.ls_partials(w, m);
        let (inner, q) = self.partials;
        let eta =
            if line_search { eta_from(inner, q, self.t) } else { open_loop_eta(self.t) } as f64;
        (-inner, eta, self.p_rel_drift(w, g, m))
    }

    /// Relative Frobenius divergence of the maintained `P` from an
    /// exact recompute at the current iterate (drift regression tests).
    pub fn p_rel_drift(&self, w: &[f32], g: &Mat, m: &[f32]) -> f64 {
        let mut exact = vec![0.0f32; self.rows * self.cols];
        masked_matmul_into(w, m, self.rows, self.cols, g, &mut exact);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in self.p.iter().zip(&exact) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        (num / den.max(1e-30)).sqrt()
    }
}

// ---------------------------------------------------------------------------
// Layer drivers
// ---------------------------------------------------------------------------

/// Row-block count for a layer: 1 (serial) unless the layer is big
/// enough that thread handoff is noise.  Line-search runs get a
/// shape-derived (machine-independent) partition — see
/// [`LINE_SEARCH_BLOCKS`].
fn engine_workers(rows: usize, cols: usize, line_search: bool) -> usize {
    if rows * cols < PARALLEL_MIN_NUMEL {
        return 1;
    }
    let cap = (rows / MIN_BLOCK_ROWS).max(1);
    if line_search {
        cap.min(LINE_SEARCH_BLOCKS)
    } else {
        default_workers(rows).min(cap).max(1)
    }
}

/// Budgets of `budget` restricted to the row range `r`.
fn slice_budget(budget: &BudgetSpec, r: &std::ops::Range<usize>, cols: usize) -> BudgetSpec {
    match budget {
        // only valid for the full range — the global LMO couples rows
        BudgetSpec::Global { .. } => budget.clone(),
        BudgetSpec::PerRow { keep } => BudgetSpec::PerRow { keep: keep[r.clone()].to_vec() },
        BudgetSpec::NM { keep, block } => {
            let nb = cols / block;
            BudgetSpec::NM { keep: keep[r.start * nb..r.end * nb].to_vec(), block: *block }
        }
    }
}

/// Run `iters` incremental FW steps on a whole layer, starting from the
/// (binary warmstart) iterate `m`, picking the block parallelism
/// automatically.  `m` is updated in place.
#[allow(clippy::too_many_arguments)]
pub fn run_incremental(
    w: &Mat,
    g: &Mat,
    h: &Mat,
    fixed: &Mat,
    budget: &BudgetSpec,
    m: &mut Mat,
    iters: usize,
    line_search: bool,
    refresh_every: usize,
) {
    let mut workers = engine_workers(w.rows, w.cols, line_search);
    // the global driver pays 2-3 thread spawns per iteration (its
    // phases bracket the serial LMO merge); below this size the spawn
    // cost outweighs the split work, so run one block
    if matches!(budget, BudgetSpec::Global { .. }) && w.rows * w.cols < GLOBAL_PARALLEL_MIN_NUMEL
    {
        workers = 1;
    }
    run_incremental_with(w, g, h, fixed, budget, m, iters, line_search, refresh_every, workers);
}

/// [`run_incremental`] with an explicit row-block count (tests pin this
/// for machine-independent results).
#[allow(clippy::too_many_arguments)]
pub fn run_incremental_with(
    w: &Mat,
    g: &Mat,
    h: &Mat,
    fixed: &Mat,
    budget: &BudgetSpec,
    m: &mut Mat,
    iters: usize,
    line_search: bool,
    refresh_every: usize,
    workers: usize,
) {
    let (rows, cols) = (w.rows, w.cols);
    let workers = workers.clamp(1, rows.max(1));
    if workers <= 1 {
        let mut blk = FwBlock::new(&w.data, g, &fixed.data, &m.data, rows, cols);
        blk.run(
            &w.data, g, &h.data, &fixed.data, &mut m.data, budget, iters, line_search,
            refresh_every,
        );
        return;
    }
    match budget {
        BudgetSpec::Global { keep } => run_global(
            w, g, h, fixed, *keep, m, iters, line_search, refresh_every, workers,
        ),
        _ => run_rowsep(w, g, h, fixed, budget, m, iters, line_search, refresh_every, workers),
    }
}

/// Row-separable constraints (`PerRow`/`NM`): fully independent FW
/// loops per row block, one thread each — no per-iteration handoff.
#[allow(clippy::too_many_arguments)]
fn run_rowsep(
    w: &Mat,
    g: &Mat,
    h: &Mat,
    fixed: &Mat,
    budget: &BudgetSpec,
    m: &mut Mat,
    iters: usize,
    line_search: bool,
    refresh_every: usize,
    workers: usize,
) {
    let cols = w.cols;
    let ranges = chunk_ranges(w.rows, workers);
    std::thread::scope(|s| {
        let mut m_rest: &mut [f32] = &mut m.data;
        for r in &ranges {
            let (mb, rest) = m_rest.split_at_mut(r.len() * cols);
            m_rest = rest;
            let (lo, hi) = (r.start * cols, r.end * cols);
            let (wb, hb, fb) = (&w.data[lo..hi], &h.data[lo..hi], &fixed.data[lo..hi]);
            let sub = slice_budget(budget, r, cols);
            let nrows = r.len();
            s.spawn(move || {
                let mut blk = FwBlock::new(wb, g, fb, mb, nrows, cols);
                blk.run(wb, g, hb, fb, mb, &sub, iters, line_search, refresh_every);
            });
        }
    });
}

/// Unstructured (`Global`) budget: the LMO couples rows, so every
/// iteration runs two parallel phases over the row blocks —
/// (gradient + candidate pre-select) and (gather + update) — joined by
/// a serial exact candidate merge that reproduces the dense selection.
#[allow(clippy::too_many_arguments)]
fn run_global(
    w: &Mat,
    g: &Mat,
    h: &Mat,
    fixed: &Mat,
    keep: usize,
    m: &mut Mat,
    iters: usize,
    line_search: bool,
    refresh_every: usize,
    workers: usize,
) {
    fn slice<'a>(mat: &'a Mat, r: &std::ops::Range<usize>, cols: usize) -> &'a [f32] {
        &mat.data[r.start * cols..r.end * cols]
    }
    let cols = w.cols;
    let ranges = chunk_ranges(w.rows, workers);

    // block construction in parallel: P̄ init is the expensive part
    let mut blocks: Vec<FwBlock> = std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .map(|r| {
                let (wb, fb, mb) = (slice(w, r, cols), slice(fixed, r, cols), slice(m, r, cols));
                let nrows = r.len();
                s.spawn(move || FwBlock::new(wb, g, fb, mb, nrows, cols))
            })
            .collect();
        handles.into_iter().map(|hd| hd.join().expect("fw block init")).collect()
    });

    let cmp = |a: &(f32, u32), b: &(f32, u32)| {
        a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
    };
    let mut merged: Vec<(f32, u32)> = Vec::new();

    for t in 0..iters {
        // phase 1 — parallel: gradient + local bottom-k candidates
        std::thread::scope(|s| {
            for (blk, r) in blocks.iter_mut().zip(&ranges) {
                let (wb, hb, fb) = (slice(w, r, cols), slice(h, r, cols), slice(fixed, r, cols));
                let base = (r.start * cols) as u32;
                s.spawn(move || {
                    blk.compute_grad(wb, hb, fb);
                    blk.preselect(keep, base);
                });
            }
        });

        // serial: exact merge — same comparator (value, index) as the
        // dense LMO's bottom-k, over the candidate union
        merged.clear();
        for blk in &blocks {
            merged.extend_from_slice(&blk.scratch.cand);
        }
        let k = keep.min(merged.len());
        if k > 0 && k < merged.len() {
            merged.select_nth_unstable_by(k - 1, cmp);
        }
        merged.truncate(k);
        merged.sort_unstable_by_key(|&(_, ix)| ix);
        let mut pos = 0usize;
        for (blk, r) in blocks.iter_mut().zip(&ranges) {
            let (base, end) = ((r.start * cols) as u32, (r.end * cols) as u32);
            blk.scratch.v_idx.clear();
            while pos < merged.len() && merged[pos].1 < end {
                blk.scratch.v_idx.push(merged[pos].1 - base);
                pos += 1;
            }
        }

        // phase 2 — parallel: sparse gather (+ line-search partials)
        let eta = if line_search {
            std::thread::scope(|s| {
                let mut m_rest: &[f32] = &m.data;
                for (blk, r) in blocks.iter_mut().zip(&ranges) {
                    let (mb, rest) = m_rest.split_at(r.len() * cols);
                    m_rest = rest;
                    let wb = slice(w, r, cols);
                    s.spawn(move || {
                        blk.compute_sv(wb, g);
                        blk.ls_partials(wb, mb);
                    });
                }
            });
            let (inner, q) = blocks
                .iter()
                .fold((0.0, 0.0), |(i, q), b| (i + b.partials.0, q + b.partials.1));
            eta_from(inner, q, t)
        } else {
            open_loop_eta(t)
        };

        // phase 3 — parallel: convex update + periodic exact refresh
        std::thread::scope(|s| {
            let mut m_rest: &mut [f32] = &mut m.data;
            for (blk, r) in blocks.iter_mut().zip(&ranges) {
                let (mb, rest) = m_rest.split_at_mut(r.len() * cols);
                m_rest = rest;
                let wb = slice(w, r, cols);
                s.spawn(move || {
                    if !line_search {
                        blk.compute_sv(wb, g);
                    }
                    blk.apply(mb, eta);
                    blk.maybe_refresh(wb, g, mb, refresh_every);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::SparsityPattern;
    use crate::pruner::saliency::{saliency_mask, wanda_scores};
    use crate::pruner::sparsefw::alpha_fixed_mask;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        let mut x = Mat::gaussian(din, b, 1.0, &mut rng);
        for i in 0..din {
            if i % 5 == 0 {
                for v in x.row_mut(i) {
                    *v *= 4.0;
                }
            }
        }
        (w, matmul_a_bt(&x, &x))
    }

    /// Warmstart state shared by the driver tests.
    fn fw_inputs(
        w: &Mat,
        g: &Mat,
        pattern: &SparsityPattern,
        alpha: f64,
    ) -> (Mat, Mat, BudgetSpec, Mat) {
        let scores = wanda_scores(w, g);
        let warm = saliency_mask(&scores, pattern);
        let fixed = alpha_fixed_mask(&scores, pattern, alpha);
        let budget = BudgetSpec::free_budgets(pattern, w.rows, w.cols, &fixed);
        let m = Mat::from_vec(
            w.rows,
            w.cols,
            warm.data
                .iter()
                .zip(&fixed.data)
                .map(|(&wm, &fx)| if fx != 0.0 { 0.0 } else { wm })
                .collect(),
        );
        let h = crate::pruner::fw_math::precompute_h(w, g);
        (h, fixed, budget, m)
    }

    #[test]
    fn engine_parse_labels() {
        assert_eq!(FwEngine::parse("dense").unwrap(), FwEngine::Dense);
        assert_eq!(FwEngine::parse("incremental").unwrap(), FwEngine::Incremental);
        assert_eq!(FwEngine::parse("inc").unwrap(), FwEngine::Incremental);
        assert!(FwEngine::parse("warp").is_err());
        assert_eq!(FwEngine::Incremental.label(), "incremental");
    }

    /// Open-loop runs must be bit-identical for any worker count — the
    /// global candidate merge is exact and all row math is block-local.
    #[test]
    fn parallel_blocks_match_serial_exactly() {
        let (w, g) = setup(24, 32, 96, 9);
        for pattern in [
            SparsityPattern::Unstructured { sparsity: 0.5 },
            SparsityPattern::PerRow { sparsity: 0.5 },
            SparsityPattern::NM { keep: 2, block: 4 },
        ] {
            let (h, fixed, budget, m0) = fw_inputs(&w, &g, &pattern, 0.5);
            let mut serial = m0.clone();
            run_incremental_with(&w, &g, &h, &fixed, &budget, &mut serial, 40, false, 16, 1);
            let mut par = m0.clone();
            run_incremental_with(&w, &g, &h, &fixed, &budget, &mut par, 40, false, 16, 3);
            assert_eq!(serial.data, par.data, "{pattern:?}");
        }
    }

    /// With line search the blocks optimize η separately, which can only
    /// help the (separable) continuous objective — check both paths
    /// still land close on this well-conditioned instance.
    #[test]
    fn parallel_line_search_stays_close_to_serial() {
        let (w, g) = setup(24, 32, 96, 10);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let (h, fixed, budget, m0) = fw_inputs(&w, &g, &pattern, 0.5);
        let total = |m: &Mat| {
            let mut tm = m.clone();
            tm.add_inplace(&fixed);
            crate::pruner::fw_math::objective(&w, &tm, &g)
        };
        let mut serial = m0.clone();
        run_incremental_with(&w, &g, &h, &fixed, &budget, &mut serial, 40, true, 16, 1);
        let mut par = m0.clone();
        run_incremental_with(&w, &g, &h, &fixed, &budget, &mut par, 40, true, 16, 3);
        let (a, b) = (total(&serial), total(&par));
        assert!((a - b).abs() <= 0.05 * (1.0 + a.abs()), "{a} vs {b}");
    }

    /// The maintained P must track the exact product through a long run
    /// when the periodic refresh is on.
    #[test]
    fn maintained_state_drift_is_refreshed_away() {
        let (w, g) = setup(12, 24, 64, 11);
        let pattern = SparsityPattern::Unstructured { sparsity: 0.5 };
        let (h, fixed, budget, m0) = fw_inputs(&w, &g, &pattern, 0.9);
        let mut m = m0.clone();
        let mut blk = FwBlock::new(&w.data, &g, &fixed.data, &m.data, w.rows, w.cols);
        blk.run(
            &w.data, &g, &h.data, &fixed.data, &mut m.data, &budget, 500, false,
            DEFAULT_REFRESH_EVERY,
        );
        assert!(
            blk.p_rel_drift(&w.data, &g, &m.data) <= 1e-4,
            "drift {}",
            blk.p_rel_drift(&w.data, &g, &m.data)
        );
    }
}
