//! Pruning methods: the paper's SparseFW plus every baseline it
//! compares against or discusses (§2.1).
//!
//! * [`sparsefw`] — Frank-Wolfe on the convex relaxation (the paper's
//!   contribution; Algorithms 1–2).
//! * [`saliency`] — Wanda / RIA / magnitude greedy mask selection.
//! * [`sparsegpt`] — greedy-with-reconstruction baseline (context).
//! * [`lmo`], [`rounding`], [`mask`] — the constraint-set machinery.
//! * [`fw_math`] — native mirror of the Pallas kernels.
//! * [`fw_engine`] — the incremental sparse-vertex hot loop (maintained
//!   `(W⊙M)·G` state, O(nnz) iterations, row-block parallelism).

pub mod allocation;
pub mod fw_engine;
pub mod fw_math;
pub mod lmo;
pub mod mask;
pub mod rounding;
pub mod saliency;
pub mod sparsefw;
pub mod sparsegpt;

pub use fw_engine::FwEngine;
pub use mask::{BudgetSpec, SparsityPattern};
pub use sparsefw::{FwKernels, FwTrace, LayerResult, NativeKernels, SparseFwConfig, Warmstart};

use crate::tensor::Mat;
use anyhow::Result;

/// A pruning method as selected in configs / CLI / reports.
#[derive(Clone, Debug)]
pub enum PruneMethod {
    Magnitude,
    Wanda,
    Ria,
    SparseFw(SparseFwConfig),
    /// Greedy + weight reconstruction; `percdamp`, `blocksize`.
    SparseGpt { percdamp: f64, blocksize: usize },
}

impl PruneMethod {
    pub fn label(&self) -> String {
        match self {
            PruneMethod::Magnitude => "magnitude".into(),
            PruneMethod::Wanda => "wanda".into(),
            PruneMethod::Ria => "ria".into(),
            PruneMethod::SparseFw(c) => format!("sparsefw({})", c.warmstart.label()),
            PruneMethod::SparseGpt { .. } => "sparsegpt".into(),
        }
    }

    /// Prune one layer. Returns the binary mask plus (for reconstruction
    /// methods) replacement weights.
    pub fn prune_layer<K: FwKernels + ?Sized>(
        &self,
        kernels: &K,
        w: &Mat,
        g: &Mat,
        pattern: &SparsityPattern,
    ) -> Result<LayerPruneOutput> {
        match self {
            PruneMethod::Magnitude => {
                let m = saliency::saliency_mask(&saliency::magnitude_scores(w), pattern);
                LayerPruneOutput::from_mask(kernels, w, g, m)
            }
            PruneMethod::Wanda => {
                let m = saliency::saliency_mask(&saliency::wanda_scores(w, g), pattern);
                LayerPruneOutput::from_mask(kernels, w, g, m)
            }
            PruneMethod::Ria => {
                let m = saliency::saliency_mask(&saliency::ria_scores(w, g), pattern);
                LayerPruneOutput::from_mask(kernels, w, g, m)
            }
            PruneMethod::SparseFw(cfg) => {
                let r = sparsefw::run_layer(kernels, w, g, pattern, cfg)?;
                Ok(LayerPruneOutput {
                    obj: r.final_obj,
                    warm_obj: Some(r.warm_obj),
                    trace: r.trace,
                    mask: r.mask,
                    new_weights: None,
                    fw_iters: r.fw_iters,
                })
            }
            PruneMethod::SparseGpt { percdamp, blocksize } => {
                let r = sparsegpt::sparsegpt(w, g, pattern, *percdamp, *blocksize)?;
                let obj = kernels.objective(w, &r.mask, g)?;
                Ok(LayerPruneOutput {
                    obj,
                    warm_obj: None,
                    trace: None,
                    mask: r.mask,
                    new_weights: Some(r.weights),
                    fw_iters: 0,
                })
            }
        }
    }
}

/// Result of pruning one layer with any method.
pub struct LayerPruneOutput {
    pub mask: Mat,
    /// L(mask) under the layer objective.
    pub obj: f64,
    /// L(warmstart) when the method has one (SparseFW).
    pub warm_obj: Option<f64>,
    /// Reconstructed weights (SparseGPT only).
    pub new_weights: Option<Mat>,
    pub trace: Option<FwTrace>,
    /// FW iterations executed (0 for the greedy/one-shot methods).
    pub fw_iters: usize,
}

impl LayerPruneOutput {
    fn from_mask<K: FwKernels + ?Sized>(kernels: &K, w: &Mat, g: &Mat, mask: Mat) -> Result<Self> {
        let obj = kernels.objective(w, &mask, g)?;
        Ok(Self { mask, obj, warm_obj: None, new_weights: None, trace: None, fw_iters: 0 })
    }
}
