//! Pruning methods: the paper's SparseFW plus every baseline it
//! compares against or discusses (§2.1), behind an *open* method API.
//!
//! * [`method`] — the [`LayerPruner`] trait ([`LayerCtx`] in,
//!   [`LayerPruneOutput`] out), the cloneable [`Method`] handle, and
//!   the built-in implementations.
//! * [`registry`] — name → factory [`MethodRegistry`]: the single
//!   source of truth behind CLI parsing, JobSpec JSON, server
//!   validation, and the method listings.
//! * [`refine`] — composable post-passes for any method's mask
//!   (SparseSwaps-style 1-swaps, least-squares weight update).
//! * [`sparsefw`] — Frank-Wolfe on the convex relaxation (the paper's
//!   contribution; Algorithms 1–2).
//! * [`saliency`] — Wanda / RIA / magnitude greedy mask selection.
//! * [`sparsegpt`] — greedy-with-reconstruction baseline (context).
//! * [`lmo`], [`rounding`], [`mask`] — the constraint-set machinery.
//! * [`fw_math`] — native mirror of the Pallas kernels.
//! * [`fw_engine`] — the incremental sparse-vertex hot loop (maintained
//!   `(W⊙M)·G` state, O(nnz) iterations, row-block parallelism).

pub mod allocation;
pub mod fw_engine;
pub mod fw_math;
pub mod lmo;
pub mod mask;
pub mod method;
pub mod refine;
pub mod registry;
pub mod rounding;
pub mod saliency;
pub mod sparsefw;
pub mod sparsegpt;

pub use fw_engine::FwEngine;
pub use mask::{BudgetSpec, SparsityPattern};
pub use method::{LayerCtx, LayerPruneOutput, LayerPruner, Method, MethodCaps};
pub use refine::RefinePass;
pub use registry::{MethodRegistration, MethodRegistry};
pub use sparsefw::{
    ConvergenceTrace, FwKernels, FwTrace, LayerResult, NativeKernels, SparseFwConfig, Warmstart,
};

use crate::tensor::Mat;
use anyhow::Result;

/// Enum-era method selector, kept as a thin construction shim over the
/// open [`Method`] API: enum values convert via [`PruneMethod::to_method`]
/// (or `Into<Method>`), and every enum-era saved spec replays
/// bit-identically through the registry.  New code — and new methods —
/// should use [`Method`] / [`LayerPruner`] directly.
#[derive(Clone, Debug)]
pub enum PruneMethod {
    Magnitude,
    Wanda,
    Ria,
    SparseFw(SparseFwConfig),
    /// Greedy + weight reconstruction; `percdamp`, `blocksize`.
    SparseGpt { percdamp: f64, blocksize: usize },
}

impl PruneMethod {
    /// The registry-backed [`Method`] this enum value names.
    pub fn to_method(&self) -> Method {
        match self {
            PruneMethod::Magnitude => Method::magnitude(),
            PruneMethod::Wanda => Method::wanda(),
            PruneMethod::Ria => Method::ria(),
            PruneMethod::SparseFw(c) => Method::sparsefw(c.clone()),
            PruneMethod::SparseGpt { percdamp, blocksize } => {
                Method::sparsegpt(*percdamp, *blocksize)
            }
        }
    }

    pub fn label(&self) -> String {
        self.to_method().label()
    }

    /// Prune one layer (compatibility wrapper over
    /// [`Method::prune_layer`] with a bare [`LayerCtx`]).
    pub fn prune_layer<K: FwKernels>(
        &self,
        kernels: &K,
        w: &Mat,
        g: &Mat,
        pattern: &SparsityPattern,
    ) -> Result<LayerPruneOutput> {
        self.to_method()
            .prune_layer(&LayerCtx::new(kernels, w, g, pattern))
    }
}

impl From<PruneMethod> for Method {
    fn from(m: PruneMethod) -> Method {
        m.to_method()
    }
}

impl From<&PruneMethod> for Method {
    fn from(m: &PruneMethod) -> Method {
        m.to_method()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    /// The enum shim and the Method API must produce identical masks.
    #[test]
    fn enum_shim_matches_method_api() {
        let mut rng = Xoshiro256::new(9);
        let w = Mat::gaussian(8, 16, 1.0, &mut rng);
        let x = Mat::gaussian(16, 64, 1.0, &mut rng);
        let g = matmul_a_bt(&x, &x);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        for (legacy, modern) in [
            (PruneMethod::Magnitude, Method::magnitude()),
            (PruneMethod::Wanda, Method::wanda()),
            (PruneMethod::Ria, Method::ria()),
            (
                PruneMethod::SparseFw(SparseFwConfig { iters: 40, alpha: 0.5, ..Default::default() }),
                Method::sparsefw(SparseFwConfig { iters: 40, alpha: 0.5, ..Default::default() }),
            ),
            (
                PruneMethod::SparseGpt { percdamp: 0.01, blocksize: 8 },
                Method::sparsegpt(0.01, 8),
            ),
        ] {
            let a = legacy.prune_layer(&NativeKernels, &w, &g, &pattern).unwrap();
            let b = modern
                .prune_layer(&LayerCtx::new(&NativeKernels, &w, &g, &pattern))
                .unwrap();
            assert_eq!(a.mask.data, b.mask.data, "{}", legacy.label());
            assert_eq!(a.obj, b.obj, "{}", legacy.label());
            assert_eq!(legacy.label(), modern.label());
        }
    }
}
