//! Native (rust-side) implementations of the FW objective and gradient —
//! the same math as the Pallas kernels (`python/compile/kernels/`), used
//! by the `Native` backend and as the cross-check for the PJRT backend.
//!
//!   L(M)  = ‖WX − (M⊙W)X‖_F² = Σ_ij [(Z·G) ⊙ Z]_ij,  Z = W⊙(1−M)
//!   ∇L(M) = −2 · W ⊙ (H − (W⊙M)·G),                  H = W·G

use crate::tensor::{matmul, Mat};

/// H = W·G, precomputed once per layer (Algorithm 1 line 1).
pub fn precompute_h(w: &Mat, g: &Mat) -> Mat {
    matmul(w, g)
}

/// ∇L(M) = −2·W⊙(H − (W⊙M)G).
pub fn fw_grad(w: &Mat, m: &Mat, g: &Mat, h: &Mat) -> Mat {
    let wm = w.hadamard(m);
    let mut prod = matmul(&wm, g);
    // prod ← -2 * w ⊙ (h - prod)
    for ((p, &hv), &wv) in prod.data.iter_mut().zip(&h.data).zip(&w.data) {
        *p = -2.0 * wv * (hv - *p);
    }
    prod
}

/// L(M) via the gram form (sequence-length independent).
pub fn objective(w: &Mat, m: &Mat, g: &Mat) -> f64 {
    let z = Mat::from_vec(
        w.rows,
        w.cols,
        w.data
            .iter()
            .zip(&m.data)
            .map(|(&wv, &mv)| wv * (1.0 - mv))
            .collect(),
    );
    let zg = matmul(&z, g);
    zg.data
        .iter()
        .zip(&z.data)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

/// Dense-output check: ‖WX − (M⊙W)X‖_F² straight from X (tests only;
/// O(d_out·d_in·B)).
pub fn objective_from_x(w: &Mat, m: &Mat, x: &Mat) -> f64 {
    let wx = matmul(w, x);
    let mwx = matmul(&w.hadamard(m), x);
    wx.data
        .iter()
        .zip(&mwx.data)
        .map(|(&a, &b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat, Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        let x = Mat::gaussian(din, b, 1.0, &mut rng);
        let g = matmul_a_bt(&x, &x);
        let m = Mat::from_fn(dout, din, |_, _| rng.next_f32());
        (w, x, g, m)
    }

    #[test]
    fn gram_objective_matches_x_objective() {
        let (w, x, g, m) = setup(6, 8, 40, 1);
        let a = objective(&w, &m, &g);
        let b = objective_from_x(&w, &m, &x);
        assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (w, _x, g, m) = setup(4, 6, 30, 2);
        let h = precompute_h(&w, &g);
        let grad = fw_grad(&w, &m, &g, &h);
        let eps = 1e-3f32;
        for idx in [0usize, 5, 11, 17, 23] {
            let mut mp = m.clone();
            mp.data[idx] += eps;
            let mut mm = m.clone();
            mm.data[idx] -= eps;
            let fd = (objective(&w, &mp, &g) - objective(&w, &mm, &g)) / (2.0 * eps as f64);
            let an = grad.data[idx] as f64;
            assert!(
                (fd - an).abs() < 2e-2 * (1.0 + an.abs()),
                "idx {idx}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn full_mask_zero_objective() {
        let (w, _x, g, _m) = setup(4, 6, 30, 3);
        let ones = Mat::ones(4, 6);
        assert!(objective(&w, &ones, &g).abs() < 1e-3);
        // and the gradient there is -2·W⊙(H−H)... wait, with M=1,
        // (W⊙M)G == WG == H so the gradient must vanish except sign
        // structure — check it's ~0.
        let h = precompute_h(&w, &g);
        let grad = fw_grad(&w, &ones, &g, &h);
        assert!(grad.abs_max() < 1e-2);
    }

    #[test]
    fn empty_mask_full_error() {
        let (w, x, g, _m) = setup(4, 6, 30, 4);
        let zeros = Mat::zeros(4, 6);
        let wx = matmul(&w, &x);
        assert!((objective(&w, &zeros, &g) - wx.frob_sq()).abs() < 1e-2 * (1.0 + wx.frob_sq()));
    }
}
