//! SparseGPT-style greedy pruning with weight reconstruction
//! (Frantar & Alistarh, 2023) — the greedy-with-reconstruction baseline
//! the paper discusses in §2.1 (implemented for context/ablations; the
//! paper's main comparisons are against pure mask-selection methods).
//!
//! Faithful port of the blocked OBS procedure: with damped Hessian
//! `H = XXᵀ + λI`, compute `Hinv = H⁻¹` and its upper Cholesky factor
//! `U` (`Hinv = UᵀU`).  Columns are processed left-to-right in blocks;
//! within a block, pruning scores are `w_j²/U_jj²`, pruned weights are
//! zeroed and their error `w_j/U_jj` propagated into the still-unseen
//! columns through row `j` of `U` — the cheap sequential form of the
//! optimal-brain-surgeon update.

use anyhow::{anyhow, Result};

use crate::pruner::mask::SparsityPattern;
use crate::tensor::linalg::{chol_inverse, cholesky, MatF64};
use crate::tensor::topk::top_k_indices;
use crate::tensor::Mat;
use crate::util::pool::parallel_for;
use std::sync::Mutex;

pub struct SparseGptResult {
    /// Binary mask of kept weights.
    pub mask: Mat,
    /// Reconstructed weights (kept weights updated to compensate).
    pub weights: Mat,
}

/// Run SparseGPT on one layer.
///
/// `percdamp` is the relative dampening λ = percdamp·mean(diag G)
/// (0.01 in the reference implementation); `blocksize` the lazy-update
/// block width (128 in the reference implementation).
pub fn sparsegpt(
    w: &Mat,
    g: &Mat,
    pattern: &SparsityPattern,
    percdamp: f64,
    blocksize: usize,
) -> Result<SparseGptResult> {
    pattern.validate(w.cols)?;
    let din = w.cols;
    let mut h = MatF64::from_mat(g);
    let damp = percdamp * h.mean_diag() + 1e-10;
    h.add_diag(damp);
    let hinv = chol_inverse(&h).ok_or_else(|| anyhow!("gram matrix not PD after damping"))?;
    // upper factor U with Hinv = UᵀU  (U = Lᵀ for Hinv = LLᵀ)
    let l = cholesky(&hinv).ok_or_else(|| anyhow!("Hinv not PD"))?;
    let u = {
        let mut u = MatF64::zeros(din);
        for i in 0..din {
            for j in 0..=i {
                *u.at_mut(j, i) = l.at(i, j);
            }
        }
        u
    };

    // per-block prune quota
    let prune_per_block = |j1: usize, j2: usize| -> usize {
        let width = j2 - j1;
        match pattern {
            SparsityPattern::Unstructured { sparsity } | SparsityPattern::PerRow { sparsity } => {
                (sparsity * width as f64).round() as usize
            }
            SparsityPattern::NM { .. } => 0, // handled at m-block granularity below
        }
    };

    let mask = Mutex::new(Mat::zeros(w.rows, w.cols));
    let weights = Mutex::new(Mat::zeros(w.rows, w.cols));

    parallel_for(w.rows, |i| {
        let mut row: Vec<f64> = w.row(i).iter().map(|&x| x as f64).collect();
        let mut keep = vec![true; din];

        let mut j1 = 0;
        while j1 < din {
            let j2 = (j1 + blocksize).min(din);
            // --- select prune set for this block from current weights ---
            let scores: Vec<f32> = (j1..j2)
                .map(|j| {
                    let d = u.at(j, j);
                    (-(row[j] * row[j]) / (d * d)) as f32 // negated: top-k of -score = smallest scores
                })
                .collect();
            match pattern {
                SparsityPattern::Unstructured { .. } | SparsityPattern::PerRow { .. } => {
                    let np = prune_per_block(j1, j2).min(j2 - j1);
                    for jj in top_k_indices(&scores, np) {
                        keep[j1 + jj] = false;
                    }
                }
                SparsityPattern::NM { keep: km, block } => {
                    let mut b = j1;
                    while b < j2 {
                        let be = (b + block).min(j2);
                        let seg: Vec<f32> = scores[b - j1..be - j1].to_vec();
                        let np = (be - b).saturating_sub(*km);
                        for jj in top_k_indices(&seg, np) {
                            keep[b + jj] = false;
                        }
                        b = be;
                    }
                }
            }
            // --- sequential OBS elimination within the block ---
            for j in j1..j2 {
                let d = u.at(j, j);
                if !keep[j] {
                    let err = row[j] / d;
                    row[j] = 0.0;
                    // propagate into all later columns via row j of U
                    for t in j + 1..din {
                        row[t] -= err * u.at(j, t);
                    }
                }
            }
            j1 = j2;
        }

        let mut mk = mask.lock().unwrap();
        let mut wt = weights.lock().unwrap();
        for j in 0..din {
            *mk.at_mut(i, j) = if keep[j] { 1.0 } else { 0.0 };
            *wt.at_mut(i, j) = row[j] as f32;
        }
    });

    Ok(SparseGptResult {
        mask: mask.into_inner().unwrap(),
        weights: weights.into_inner().unwrap(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::fw_math::objective_from_x;
    use crate::tensor::{matmul, matmul_a_bt};
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        let x = Mat::gaussian(din, b, 1.0, &mut rng);
        let g = matmul_a_bt(&x, &x);
        (w, x, g)
    }

    #[test]
    fn respects_nm_pattern() {
        let (w, _x, g) = setup(8, 16, 64, 1);
        let pat = SparsityPattern::NM { keep: 2, block: 4 };
        let r = sparsegpt(&w, &g, &pat, 0.01, 8).unwrap();
        assert!(crate::pruner::mask::mask_satisfies(&r.mask, &pat));
        // reconstructed weights are zero exactly off-mask
        for (m, wv) in r.mask.data.iter().zip(&r.weights.data) {
            if *m == 0.0 {
                assert_eq!(*wv, 0.0);
            }
        }
    }

    #[test]
    fn reconstruction_beats_pure_masking() {
        // the OBS update must reduce ‖WX − ŴX‖² vs just zeroing the same
        // weights
        let (w, x, g) = setup(12, 32, 128, 2);
        let pat = SparsityPattern::PerRow { sparsity: 0.5 };
        let r = sparsegpt(&w, &g, &pat, 0.01, 8).unwrap();
        let masked_err = objective_from_x(&w, &r.mask, &x);
        let wx = matmul(&w, &x);
        let rx = matmul(&r.weights, &x);
        let recon_err: f64 = wx
            .data
            .iter()
            .zip(&rx.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(
            recon_err < masked_err,
            "recon {recon_err} !< masked {masked_err}"
        );
    }

    #[test]
    fn single_prune_matches_obs_formula() {
        // with blocksize = din and exactly one prune per row, SparseGPT's
        // first elimination must agree with the closed-form OBS choice
        // argmin_q w_q² / [H⁻¹]_qq
        let (w, _x, g) = setup(4, 8, 64, 3);
        let pat = SparsityPattern::PerRow { sparsity: 1.0 / 8.0 };
        let r = sparsegpt(&w, &g, &pat, 0.01, 8).unwrap();

        let mut h = MatF64::from_mat(&g);
        h.add_diag(0.01 * h.mean_diag() + 1e-10);
        let hinv = chol_inverse(&h).unwrap();
        for i in 0..4 {
            // OBS score uses Hinv diag; SparseGPT's in-order variant uses
            // U_jj² which equals [Hinv]_jj only for the *last* column, so
            // we only check that exactly one weight was pruned and that
            // it has a low OBS score rank (sanity, not exact equality).
            let pruned: Vec<usize> = (0..8).filter(|&j| r.mask.at(i, j) == 0.0).collect();
            assert_eq!(pruned.len(), 1, "row {i}");
            let scores: Vec<f64> = (0..8)
                .map(|j| (w.at(i, j) as f64).powi(2) / hinv.at(j, j))
                .collect();
            let mut order: Vec<usize> = (0..8).collect();
            order.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
            let rank = order.iter().position(|&j| j == pruned[0]).unwrap();
            assert!(rank <= 3, "row {i}: pruned col has OBS rank {rank}");
        }
    }
}
