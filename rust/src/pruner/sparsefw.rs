//! SparseFW — the paper's algorithm (Algorithms 1 & 2).
//!
//! Per layer:
//! 1. Compute the warmstart saliency S (Wanda / RIA / magnitude) and the
//!    greedy warmstart mask (full budget k).
//! 2. α-fixing: mark the top ⌊budget·α⌋ saliency weights *per constraint
//!    unit* as unprunable (M̄); FW optimizes only the remaining budget
//!    k_new = k − ⌊k·α⌋ (Algorithm 2 lines 1–3).
//! 3. Frank-Wolfe for T iterations on the convex relaxation: gradient
//!    (Pallas kernel via PJRT, or the native mirror), LMO over the free
//!    coordinates, convex update with η_t = 2/(t+2).
//! 4. Threshold the relaxed mask to the k_new largest free entries and
//!    return M* + M̄ (Algorithm 2 lines 10–11).
//!
//! The FW gradient/objective evaluations go through the [`FwKernels`]
//! trait so the same driver runs against the native matmuls or the
//! AOT-compiled Pallas kernels (`runtime::PjrtKernels`).
//!
//! ## §Perf — the FW engines
//!
//! The native backend has two interchangeable hot loops, selected by
//! [`SparseFwConfig::engine`] (`--fw-engine dense|incremental`):
//!
//! * **dense** — one full `(W⊙M)·G` matmul per iteration through the
//!   [`FwKernels`] trait (reference semantics; the only path for PJRT
//!   backends, whose kernels live behind the trait).
//! * **incremental** (default) — [`crate::pruner::fw_engine`] maintains
//!   `P_t = (W⊙M_t)·G` across iterations via
//!   `P_{t+1} = (1−η)P_t + η(W⊙V)G`, paying only an O(nnz(V)·d_in)
//!   sparse row-gather per step plus elementwise passes, with a
//!   periodic exact refresh bounding f32 drift and row-block intra-layer
//!   parallelism.  At the paper's operating point (50% sparsity,
//!   α = 0.9, T = 2000) this is the difference between the matmul
//!   dominating end-to-end pruning time and the LMO/gather being the
//!   cost — see `benches/fw_hot_loop.rs`, tracked in `BENCH_fw.json` by
//!   `scripts/ci.sh`.

use anyhow::Result;

use crate::pruner::fw_engine::{self, FwBlock, FwEngine, DEFAULT_REFRESH_EVERY};
use crate::pruner::fw_math;
use crate::pruner::lmo::lmo;
use crate::pruner::mask::{BudgetSpec, SparsityPattern};
use crate::pruner::rounding::{threshold, threshold_residual};
use crate::pruner::saliency::{magnitude_scores, ria_scores, saliency_mask, wanda_scores};
use crate::tensor::Mat;
use crate::util::json::Json;

/// Warmstart / α-fixing saliency source (paper Table 1 uses Wanda & RIA).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Warmstart {
    Wanda,
    Ria,
    Magnitude,
}

impl Warmstart {
    pub fn label(&self) -> &'static str {
        match self {
            Warmstart::Wanda => "wanda",
            Warmstart::Ria => "ria",
            Warmstart::Magnitude => "magnitude",
        }
    }

    pub fn scores(&self, w: &Mat, g: &Mat) -> Mat {
        match self {
            Warmstart::Wanda => wanda_scores(w, g),
            Warmstart::Ria => ria_scores(w, g),
            Warmstart::Magnitude => magnitude_scores(w),
        }
    }
}

/// Gradient/objective backend: native matmuls or AOT Pallas via PJRT.
///
/// Deliberately *not* `Sync`: the PJRT client is `Rc`-based, so PJRT
/// backends are single-threaded; the coordinator parallelizes across
/// layers only with the (zero-sized, `Sync`) [`NativeKernels`].
pub trait FwKernels {
    fn fw_grad(&self, w: &Mat, m: &Mat, g: &Mat, h: &Mat) -> Result<Mat>;

    fn objective(&self, w: &Mat, m: &Mat, g: &Mat) -> Result<f64>;

    /// Optional fused multi-iteration path (unstructured LMO baked into
    /// the executable).  Returns `None` when unsupported; `t0` is the
    /// global iteration offset, `max_iters` an upper bound on how many
    /// steps to take.  On success returns the updated relaxed mask over
    /// free coordinates and the number of iterations actually executed
    /// (the artifact's chunk length).
    fn fw_chunk(
        &self,
        _w: &Mat,
        _m: &Mat,
        _g: &Mat,
        _h: &Mat,
        _fixed: &Mat,
        _k_new: usize,
        _t0: usize,
        _max_iters: usize,
    ) -> Result<Option<(Mat, usize)>> {
        Ok(None)
    }

    /// True when the kernels compute on native [`Mat`]s in-process, so
    /// [`run_layer`] may swap the trait-driven dense loop for the
    /// maintained-state engine in [`crate::pruner::fw_engine`].  PJRT
    /// backends keep the default `false`: their per-iteration math must
    /// stay on the compiled kernels.
    fn native_incremental(&self) -> bool {
        false
    }
}

/// Pure-rust backend (mirrors the Pallas kernels bit-for-bit in
/// semantics; cross-checked by integration tests).
pub struct NativeKernels;

impl FwKernels for NativeKernels {
    fn fw_grad(&self, w: &Mat, m: &Mat, g: &Mat, h: &Mat) -> Result<Mat> {
        Ok(fw_math::fw_grad(w, m, g, h))
    }

    fn objective(&self, w: &Mat, m: &Mat, g: &Mat) -> Result<f64> {
        Ok(fw_math::objective(w, m, g))
    }

    fn native_incremental(&self) -> bool {
        true
    }
}

#[derive(Clone, Debug)]
pub struct SparseFwConfig {
    /// FW iterations T (paper uses 2000; Fig 3 shows flattening there).
    pub iters: usize,
    /// Fraction of the keep-budget fixed to the top saliency weights
    /// (paper Table 2: α = 0.9 is the consistent best; α = 0 is vanilla
    /// FW and underperforms the baselines).
    pub alpha: f64,
    /// Saliency used for the warmstart mask *and* the α-fixing.
    pub warmstart: Warmstart,
    /// Record a trace point every `trace_every` iterations (0 = off).
    pub trace_every: usize,
    /// Use the fused multi-iteration PJRT executable when available.
    pub use_chunk: bool,
    /// Engineering guard beyond the paper: if the rounded FW mask has
    /// *higher* local error than the warmstart (possible at small T —
    /// the Fig 4 thresholding dip), return the warmstart mask instead.
    /// Guarantees final_obj ≤ warm_obj.  Disable to reproduce the raw
    /// Algorithm 1/2 behaviour (Fig 4 traces always report raw values).
    pub keep_best: bool,
    /// Extension beyond the paper: exact line search instead of the
    /// open-loop η_t = 2/(t+2).  The objective is a quadratic in η along
    /// the FW direction D = V − M_t, so the optimal step has the closed
    /// form η* = clamp(−⟨∇L, D⟩ / (2·q(D)), 0, 1) with
    /// q(D) = ‖(W⊙D)X‖² — evaluated by the existing objective kernel at
    /// mask (1 − D).  One extra kernel call per iteration, markedly
    /// faster convergence (see EXPERIMENTS.md §Extensions).
    ///
    /// On the incremental engine the scalars come from the maintained
    /// state (no extra matmul), and η is optimized *per row block* on
    /// row-separable patterns.
    pub line_search: bool,
    /// Native hot-loop engine (`--fw-engine`): the incremental
    /// sparse-vertex engine (default) or the dense per-iteration
    /// matmul.  Ignored by PJRT backends.  See the §Perf note above.
    pub engine: FwEngine,
    /// Exact-refresh period of the incremental engine's maintained
    /// `P = (W⊙M)·G` state (`--fw-refresh`; 0 = never refresh).  Bounds
    /// f32 drift; the default keeps a 2000-iteration run within 1e-4
    /// relative of the exact product.
    pub refresh_every: usize,
}

impl Default for SparseFwConfig {
    fn default() -> Self {
        Self {
            iters: 500,
            alpha: 0.9,
            warmstart: Warmstart::Wanda,
            trace_every: 0,
            use_chunk: true,
            keep_best: true,
            line_search: false,
            engine: FwEngine::Incremental,
            refresh_every: DEFAULT_REFRESH_EVERY,
        }
    }
}

/// Fig-4-style per-layer optimization trace.
#[derive(Clone, Debug, Default)]
pub struct FwTrace {
    pub iters: Vec<usize>,
    /// L(M̄ + M_t) of the continuous iterate.
    pub continuous_obj: Vec<f64>,
    /// L(M̄ + round(M_t)) of the thresholded iterate.
    pub thresholded_obj: Vec<f64>,
    /// Mean ℓ₁ threshold residual ‖M_t − round(M_t)‖₁ / numel.
    pub residual: Vec<f64>,
}

/// Per-layer FW convergence certificate, recorded at the same
/// `trace_every` subsample points as [`FwTrace`]: the paper's rounding
/// bound rides on the FW convergence bound, and the duality gap
/// `⟨∇L, M−V⟩ ≥ L(M) − L*` is its checkable witness — a layer whose
/// final gap stays large converged badly and its rounded mask carries
/// no guarantee (`sparsefw trace` flags exactly that).  Columns are
/// parallel arrays indexed by `iters`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ConvergenceTrace {
    /// Iteration numbers of the sample points (0 = at the warmstart).
    pub iters: Vec<usize>,
    /// L(M̄ + M_t) of the continuous iterate.
    pub objective: Vec<f64>,
    /// FW duality gap `⟨∇L, M_t − V_t⟩` (≥ 0 up to fp noise).
    pub gap: Vec<f64>,
    /// Step size the next iteration takes (open-loop schedule or exact
    /// line search, whichever the run uses).
    pub eta: Vec<f64>,
    /// Relative drift of the incremental engine's maintained `P` from
    /// an exact recompute (0 on the dense engine — no maintained state).
    pub refresh_drift: Vec<f64>,
}

impl ConvergenceTrace {
    pub fn push(&mut self, t: usize, obj: f64, gap: f64, eta: f64, drift: f64) {
        self.iters.push(t);
        self.objective.push(obj);
        self.gap.push(gap);
        self.eta.push(eta);
        self.refresh_drift.push(drift);
    }

    pub fn len(&self) -> usize {
        self.iters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.iters.is_empty()
    }

    /// Last recorded duality gap — the certificate `sparsefw trace`
    /// compares against its threshold.
    pub fn final_gap(&self) -> Option<f64> {
        self.gap.last().copied()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("iters", Json::Arr(self.iters.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("objective", Json::arr_f64(&self.objective)),
            ("gap", Json::arr_f64(&self.gap)),
            ("eta", Json::arr_f64(&self.eta)),
            ("refresh_drift", Json::arr_f64(&self.refresh_drift)),
        ])
    }

    pub fn from_json(v: &Json) -> ConvergenceTrace {
        fn nums(v: &Json) -> Vec<f64> {
            v.as_arr().unwrap_or(&[]).iter().filter_map(Json::as_f64).collect()
        }
        ConvergenceTrace {
            iters: v
                .at(&["iters"])
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            objective: nums(v.at(&["objective"])),
            gap: nums(v.at(&["gap"])),
            eta: nums(v.at(&["eta"])),
            refresh_drift: nums(v.at(&["refresh_drift"])),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LayerResult {
    /// Final binary mask (M* + M̄), satisfying the pattern exactly.
    pub mask: Mat,
    /// L(warmstart mask) — the greedy baseline error.
    pub warm_obj: f64,
    /// L(final mask).
    pub final_obj: f64,
    /// (warm − final) / warm, the Fig 2 metric.
    pub rel_reduction: f64,
    /// FW iterations actually executed (0 on the degenerate warmstart
    /// returns) — feeds the server's iterations/sec metric.
    pub fw_iters: usize,
    pub trace: Option<FwTrace>,
    /// Convergence certificate (`trace_every > 0` runs only).
    pub convergence: Option<ConvergenceTrace>,
}

/// α-fixed mask M̄: top ⌊budget·α⌋ saliency entries per constraint unit.
pub fn alpha_fixed_mask(scores: &Mat, pattern: &SparsityPattern, alpha: f64) -> Mat {
    let (r, c) = (scores.rows, scores.cols);
    let scaled = match BudgetSpec::full(pattern, r, c) {
        BudgetSpec::Global { keep } => BudgetSpec::Global { keep: (keep as f64 * alpha) as usize },
        BudgetSpec::PerRow { keep } => BudgetSpec::PerRow {
            keep: keep.into_iter().map(|k| (k as f64 * alpha) as usize).collect(),
        },
        BudgetSpec::NM { keep, block } => BudgetSpec::NM {
            keep: keep.into_iter().map(|k| (k as f64 * alpha) as usize).collect(),
            block,
        },
    };
    threshold(scores, &scaled, None)
}

/// Run SparseFW on a single layer given its weight matrix and gram
/// matrix G = XXᵀ.
pub fn run_layer<K: FwKernels + ?Sized>(
    kernels: &K,
    w: &Mat,
    g: &Mat,
    pattern: &SparsityPattern,
    cfg: &SparseFwConfig,
) -> Result<LayerResult> {
    pattern.validate(w.cols)?;
    let (rows, cols) = (w.rows, w.cols);

    let scores = cfg.warmstart.scores(w, g);
    let warm = saliency_mask(&scores, pattern);
    let warm_obj = kernels.objective(w, &warm, g)?;

    if cfg.iters == 0 || cfg.alpha >= 1.0 {
        // T = 0 or α = 1.0 degenerate to the greedy warmstart (Table 2's
        // "1.0 (Wanda)" column).
        return Ok(LayerResult {
            mask: warm.clone(),
            warm_obj,
            final_obj: warm_obj,
            rel_reduction: 0.0,
            fw_iters: 0,
            trace: None,
            convergence: None,
        });
    }

    // Algorithm 2 lines 1–3: fix top ⌊k·α⌋ saliency weights.
    let fixed = alpha_fixed_mask(&scores, pattern, cfg.alpha);
    let free_budget = BudgetSpec::free_budgets(pattern, rows, cols, &fixed);

    // Warm-start the free coordinates with the remainder of the greedy
    // mask (nested by construction: same scores, same tie-breaks).
    let mut m = Mat::from_vec(
        rows,
        cols,
        warm.data
            .iter()
            .zip(&fixed.data)
            .map(|(&wm, &fx)| if fx != 0.0 { 0.0 } else { wm })
            .collect(),
    );

    let h = fw_math::precompute_h(w, g); // Algorithm 1 line 1
    let k_new = free_budget.total();

    let mut trace = (cfg.trace_every > 0).then(FwTrace::default);
    let mut conv = (cfg.trace_every > 0).then(ConvergenceTrace::default);
    let record = |t: usize, m: &Mat, trace: &mut Option<FwTrace>| -> Result<()> {
        if let Some(tr) = trace.as_mut() {
            let total = add_masks(m, &fixed);
            let cont = kernels.objective(w, &total, g)?;
            let rounded = threshold(m, &free_budget, Some(&fixed));
            let thr = kernels.objective(w, &add_masks(&rounded, &fixed), g)?;
            tr.iters.push(t);
            tr.continuous_obj.push(cont);
            tr.thresholded_obj.push(thr);
            tr.residual.push(threshold_residual(m, &rounded));
        }
        Ok(())
    };

    record(0, &m, &mut trace)?;

    if cfg.engine == FwEngine::Incremental && kernels.native_incremental() {
        // Incremental sparse-vertex engine (see fw_engine.rs): O(nnz)
        // iterations on maintained state, row-block parallel.  Tracing
        // pins a single block so recorded iterates are well-defined.
        if cfg.trace_every > 0 {
            let mut block =
                FwBlock::new(&w.data, g, &fixed.data, &m.data, rows, cols);
            // convergence probe at each sample point: gap/η/drift come
            // from the block's own scratch (no iterate perturbation —
            // see `FwBlock::convergence_probe`), the objective through
            // the kernels like every other recorded value
            let probe = |block: &mut FwBlock,
                             t: usize,
                             m: &Mat,
                             conv: &mut Option<ConvergenceTrace>|
             -> Result<()> {
                if let Some(cv) = conv.as_mut() {
                    let obj = kernels.objective(w, &add_masks(m, &fixed), g)?;
                    let (gap, eta, drift) = block.convergence_probe(
                        &w.data,
                        g,
                        &h.data,
                        &fixed.data,
                        &m.data,
                        &free_budget,
                        cfg.line_search,
                    );
                    cv.push(t, obj, gap, eta, drift);
                }
                Ok(())
            };
            probe(&mut block, 0, &m, &mut conv)?;
            let mut t = 0usize;
            while t < cfg.iters {
                let next = (((t / cfg.trace_every) + 1) * cfg.trace_every).min(cfg.iters);
                block.run(
                    &w.data,
                    g,
                    &h.data,
                    &fixed.data,
                    &mut m.data,
                    &free_budget,
                    next - t,
                    cfg.line_search,
                    cfg.refresh_every,
                );
                t = next;
                record(t, &m, &mut trace)?;
                probe(&mut block, t, &m, &mut conv)?;
            }
        } else {
            fw_engine::run_incremental(
                w,
                g,
                &h,
                &fixed,
                &free_budget,
                &mut m,
                cfg.iters,
                cfg.line_search,
                cfg.refresh_every,
            );
        }
    } else {
        // Dense engine: one (W⊙M)·G matmul per iteration through the
        // FwKernels trait.  `mask_buf` is reused for both the total
        // mask M+M̄ (gradient input) and the line-search mask 1−D — no
        // per-iteration buffer allocations.
        let chunkable = cfg.use_chunk
            && trace.is_none()
            && !cfg.line_search // the fused artifact bakes in the open-loop step
            && matches!(pattern, SparsityPattern::Unstructured { .. });

        // Convergence probe for the dense engine: one extra gradient
        // (and, under line search, objective) evaluation per sample
        // point, all through the kernels — no maintained state, so
        // drift records as 0.
        let record_conv = |t: usize, m: &Mat, conv: &mut Option<ConvergenceTrace>| -> Result<()> {
            let Some(cv) = conv.as_mut() else { return Ok(()) };
            let total = add_masks(m, &fixed);
            let obj = kernels.objective(w, &total, g)?;
            let mut grad = kernels.fw_grad(w, &total, g, &h)?;
            for (gv, fx) in grad.data.iter_mut().zip(&fixed.data) {
                if *fx != 0.0 {
                    *gv = 0.0;
                }
            }
            let v = lmo(&grad, &free_budget);
            let inner: f64 = grad
                .data
                .iter()
                .zip(&v.data)
                .zip(&m.data)
                .map(|((&gv, &vv), &mv)| gv as f64 * (vv - mv) as f64)
                .sum();
            let eta = if cfg.line_search {
                let mut ls_buf = Mat::zeros(rows, cols);
                for ((b, &vv), &mv) in ls_buf.data.iter_mut().zip(&v.data).zip(&m.data) {
                    *b = 1.0 - (vv - mv);
                }
                let q = kernels.objective(w, &ls_buf, g)?;
                if q <= 0.0 {
                    2.0 / (t as f64 + 2.0)
                } else {
                    (-inner / (2.0 * q)).clamp(0.0, 1.0)
                }
            } else {
                2.0 / (t as f64 + 2.0)
            };
            cv.push(t, obj, -inner, eta, 0.0);
            Ok(())
        };
        record_conv(0, &m, &mut conv)?;

        let mut mask_buf = Mat::zeros(rows, cols);
        let mut t = 0usize;
        while t < cfg.iters {
            // Fused PJRT path: run a whole chunk inside one executable.
            if chunkable {
                if let Some((m_next, done)) =
                    kernels.fw_chunk(w, &m, g, &h, &fixed, k_new, t, cfg.iters - t)?
                {
                    debug_assert!(done > 0 && done <= cfg.iters - t);
                    m = m_next;
                    t += done;
                    continue;
                }
            }
            // Algorithm 2 lines 6–9.
            for ((b, &mv), &fv) in
                mask_buf.data.iter_mut().zip(&m.data).zip(&fixed.data)
            {
                *b = mv + fv;
                debug_assert!(*b <= 1.0 + 1e-5, "overlapping masks");
            }
            let mut grad = kernels.fw_grad(w, &mask_buf, g, &h)?;
            // LMO over free coordinates only (∇f ⊙ (1 − M̄)).
            for (gv, fx) in grad.data.iter_mut().zip(&fixed.data) {
                if *fx != 0.0 {
                    *gv = 0.0;
                }
            }
            let v = lmo(&grad, &free_budget);
            let eta = if cfg.line_search {
                // η* = −⟨∇L, D⟩ / (2·q(D)) on the quadratic, D = V − M_t.
                let inner: f64 = grad
                    .data
                    .iter()
                    .zip(&v.data)
                    .zip(&m.data)
                    .map(|((&g_, &vv), &mv)| g_ as f64 * (vv - mv) as f64)
                    .sum();
                // q(D) = ‖(W⊙D)X‖² = objective evaluated at mask 1 − D.
                for ((b, &vv), &mv) in
                    mask_buf.data.iter_mut().zip(&v.data).zip(&m.data)
                {
                    *b = 1.0 - (vv - mv);
                }
                let q = kernels.objective(w, &mask_buf, g)?;
                if q <= 0.0 {
                    2.0 / (t as f32 + 2.0)
                } else {
                    ((-inner / (2.0 * q)).clamp(0.0, 1.0)) as f32
                }
            } else {
                2.0 / (t as f32 + 2.0)
            };
            m.axby(1.0 - eta, eta, &v);
            t += 1;
            if cfg.trace_every > 0 && (t % cfg.trace_every == 0 || t == cfg.iters) {
                record(t, &m, &mut trace)?;
                record_conv(t, &m, &mut conv)?;
            }
        }
    }

    // Algorithm 2 lines 10–11: round and re-insert the fixed weights.
    let rounded = threshold(&m, &free_budget, Some(&fixed));
    let mut mask = add_masks(&rounded, &fixed);
    let mut final_obj = kernels.objective(w, &mask, g)?;

    if cfg.keep_best && final_obj > warm_obj {
        mask = warm;
        final_obj = warm_obj;
    }

    Ok(LayerResult {
        rel_reduction: if warm_obj > 0.0 { (warm_obj - final_obj) / warm_obj } else { 0.0 },
        mask,
        warm_obj,
        final_obj,
        fw_iters: cfg.iters,
        trace,
        convergence: conv,
    })
}

fn add_masks(a: &Mat, b: &Mat) -> Mat {
    let mut out = a.clone();
    for (x, y) in out.data.iter_mut().zip(&b.data) {
        *x += y;
        debug_assert!(*x <= 1.0 + 1e-5, "overlapping masks");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::mask_satisfies;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        // anisotropic activations: scale some columns up (outlier features)
        let mut x = Mat::gaussian(din, b, 1.0, &mut rng);
        for i in 0..din {
            if i % 7 == 0 {
                for v in x.row_mut(i) {
                    *v *= 6.0;
                }
            }
        }
        (w, matmul_a_bt(&x, &x))
    }

    #[test]
    fn reduces_error_vs_warmstart() {
        let (w, g) = setup(24, 32, 128, 1);
        for pattern in [
            SparsityPattern::Unstructured { sparsity: 0.6 },
            SparsityPattern::PerRow { sparsity: 0.6 },
            SparsityPattern::NM { keep: 2, block: 4 },
        ] {
            let cfg = SparseFwConfig { iters: 150, alpha: 0.5, ..Default::default() };
            let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
            assert!(mask_satisfies(&r.mask, &pattern), "{pattern:?}");
            assert_eq!(r.mask.count_nonzero(), pattern.keep_total(24, 32));
            assert!(
                r.final_obj <= r.warm_obj * 1.0001,
                "{pattern:?}: {} !<= {}",
                r.final_obj,
                r.warm_obj
            );
        }
    }

    #[test]
    fn alpha_one_is_warmstart() {
        let (w, g) = setup(8, 16, 64, 2);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let cfg = SparseFwConfig { iters: 50, alpha: 1.0, ..Default::default() };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        let warm = saliency_mask(&wanda_scores(&w, &g), &pattern);
        assert_eq!(r.mask.data, warm.data);
        assert_eq!(r.rel_reduction, 0.0);
    }

    #[test]
    fn fixed_weights_survive() {
        let (w, g) = setup(8, 16, 64, 3);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let scores = wanda_scores(&w, &g);
        let fixed = alpha_fixed_mask(&scores, &pattern, 0.75);
        let cfg = SparseFwConfig { iters: 100, alpha: 0.75, ..Default::default() };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        for (i, (&fx, &mk)) in fixed.data.iter().zip(&r.mask.data).enumerate() {
            if fx != 0.0 {
                assert_eq!(mk, 1.0, "fixed coord {i} was pruned");
            }
        }
    }

    #[test]
    fn trace_is_recorded_and_monotoneish() {
        let (w, g) = setup(16, 16, 64, 4);
        let pattern = SparsityPattern::Unstructured { sparsity: 0.5 };
        let cfg = SparseFwConfig {
            iters: 200,
            alpha: 0.0,
            trace_every: 20,
            ..Default::default()
        };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        let tr = r.trace.unwrap();
        assert!(tr.iters.len() >= 10);
        // continuous objective at the end must beat the start (FW
        // convergence on a convex problem)
        assert!(
            *tr.continuous_obj.last().unwrap() < tr.continuous_obj[0],
            "{:?}",
            tr.continuous_obj
        );
        // residual is zero at t=0 (binary warmstart) and positive later
        assert_eq!(tr.residual[0], 0.0);
        assert!(tr.residual[2] > 0.0);
    }

    #[test]
    fn line_search_converges_at_least_as_fast() {
        let (w, g) = setup(16, 24, 96, 7);
        let pattern = SparsityPattern::Unstructured { sparsity: 0.6 };
        let base = SparseFwConfig {
            iters: 30,
            alpha: 0.0,
            keep_best: false,
            use_chunk: false,
            ..Default::default()
        };
        let open = run_layer(&NativeKernels, &w, &g, &pattern, &base).unwrap();
        let ls = run_layer(
            &NativeKernels,
            &w,
            &g,
            &pattern,
            &SparseFwConfig { line_search: true, ..base },
        )
        .unwrap();
        // at a small iteration budget, exact line search must not lose to
        // the open-loop schedule (it optimizes each step exactly)
        assert!(
            ls.final_obj <= open.final_obj * 1.02,
            "line-search {} vs open {}",
            ls.final_obj,
            open.final_obj
        );
    }

    #[test]
    fn line_search_step_is_clamped_and_descends() {
        let (w, g) = setup(8, 16, 64, 8);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let cfg = SparseFwConfig {
            iters: 60,
            alpha: 0.25,
            line_search: true,
            trace_every: 10,
            keep_best: false,
            use_chunk: false,
            ..Default::default()
        };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        let tr = r.trace.unwrap();
        // continuous objective must be non-increasing under exact line
        // search (each step minimizes along a descent direction)
        for win in tr.continuous_obj.windows(2) {
            assert!(win[1] <= win[0] * 1.0001, "{:?}", tr.continuous_obj);
        }
    }

    #[test]
    fn convergence_gap_decays_and_respects_refresh() {
        // seeded layer, sample points aligned to the exact refresh
        // (trace_every == refresh_every): every recorded gap is taken
        // right after P is recomputed exactly
        let (w, g) = setup(16, 24, 96, 12);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let cfg = SparseFwConfig {
            iters: 200,
            alpha: 0.5,
            trace_every: 25,
            refresh_every: 25,
            ..Default::default()
        };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        let cv = r.convergence.unwrap();
        assert_eq!(cv.len(), 9, "t = 0, 25, …, 200");
        assert_eq!(cv.iters[0], 0);
        assert_eq!(*cv.iters.last().unwrap(), 200);
        let scale = 1.0 + cv.objective[0].abs();
        for (&gap, &eta) in cv.gap.iter().zip(&cv.eta) {
            assert!(gap >= -1e-6 * scale, "duality gap must be ≥ 0 up to fp noise: {gap}");
            assert!((0.0..=1.0).contains(&eta), "step size out of [0,1]: {eta}");
        }
        // monotone-ish decay: past the large-η burn-in (the t = 0 → 25
        // window steps with η up to 1), the gap never increases across
        // a refresh beyond local FW zig-zag noise, and decays overall
        let peak = cv.gap.iter().cloned().fold(0.0f64, f64::max);
        for win in cv.gap[1..].windows(2) {
            assert!(
                win[1] <= win[0] * 2.0 + 1e-9 * scale,
                "gap jumped after a refresh: {:?}",
                cv.gap
            );
        }
        assert!(
            cv.final_gap().unwrap() <= peak * 0.5 + 1e-9 * scale,
            "gap failed to decay: {:?}",
            cv.gap
        );
        // objective decays with it, and the maintained state stays tight
        assert!(*cv.objective.last().unwrap() <= cv.objective[0]);
        for &d in &cv.refresh_drift {
            assert!(d <= 1e-3, "maintained-state drift too large: {d}");
        }
    }

    #[test]
    fn convergence_probe_does_not_perturb_the_iterates() {
        // open-loop incremental runs are bit-identical with tracing on
        // or off: the probe only writes scratch
        let (w, g) = setup(16, 24, 96, 13);
        let pattern = SparsityPattern::Unstructured { sparsity: 0.5 };
        let base = SparseFwConfig { iters: 60, alpha: 0.5, ..Default::default() };
        let plain = run_layer(&NativeKernels, &w, &g, &pattern, &base).unwrap();
        let traced = run_layer(
            &NativeKernels,
            &w,
            &g,
            &pattern,
            &SparseFwConfig { trace_every: 10, ..base },
        )
        .unwrap();
        assert_eq!(plain.mask.data, traced.mask.data);
        assert_eq!(plain.final_obj, traced.final_obj);
        assert!(traced.convergence.is_some());
        assert!(plain.convergence.is_none());
    }

    #[test]
    fn convergence_trace_json_roundtrip() {
        let mut cv = ConvergenceTrace::default();
        cv.push(0, 10.0, 2.5, 1.0, 0.0);
        cv.push(25, 4.0, 0.5, 0.074, 1.2e-6);
        let back = ConvergenceTrace::from_json(&cv.to_json());
        assert_eq!(back, cv);
        assert_eq!(back.final_gap(), Some(0.5));
        // missing/garbage input degrades to empty, not a panic
        assert!(ConvergenceTrace::from_json(&Json::Null).is_empty());
    }

    #[test]
    fn dense_engine_records_convergence_too() {
        let (w, g) = setup(8, 16, 64, 14);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let cfg = SparseFwConfig {
            iters: 40,
            alpha: 0.5,
            trace_every: 10,
            engine: FwEngine::Dense,
            use_chunk: false,
            ..Default::default()
        };
        let r = run_layer(&NativeKernels, &w, &g, &pattern, &cfg).unwrap();
        let cv = r.convergence.unwrap();
        assert_eq!(cv.iters, vec![0, 10, 20, 30, 40]);
        // dense engine has no maintained state: drift records as 0
        assert!(cv.refresh_drift.iter().all(|&d| d == 0.0));
        assert!(cv.gap.iter().all(|&gp| gp >= -1e-6));
    }

    #[test]
    fn more_iters_no_worse() {
        let (w, g) = setup(16, 24, 96, 5);
        let pattern = SparsityPattern::Unstructured { sparsity: 0.6 };
        let short = run_layer(
            &NativeKernels,
            &w,
            &g,
            &pattern,
            &SparseFwConfig { iters: 10, alpha: 0.5, ..Default::default() },
        )
        .unwrap();
        let long = run_layer(
            &NativeKernels,
            &w,
            &g,
            &pattern,
            &SparseFwConfig { iters: 400, alpha: 0.5, ..Default::default() },
        )
        .unwrap();
        assert!(long.final_obj <= short.final_obj * 1.05);
    }
}
