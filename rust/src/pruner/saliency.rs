//! Saliency scores and the greedy baseline pruners built on them.
//!
//! * **Magnitude** — `S_ij = |W_ij|` (the classical criterion; the paper
//!   notes it fails at LLM scale due to activation outliers).
//! * **Wanda** (Sun et al., 2023) — `S_ij = |W_ij|·‖X_j,:‖₂`.  Note
//!   `‖X_j,:‖₂ = √G_jj`, so scores come straight from the gram matrix.
//! * **RIA** (Zhang et al., 2024) — Wanda on the relative-importance
//!   rescaled weights (paper Eq. 6):
//!   `S_ij = |W_ij|·(1/Σ_k|W_ik| + 1/Σ_k|W_kj|)·‖X_j,:‖₂`.
//!
//! A baseline *mask* is the per-unit top-k of the saliency matrix under
//! the requested [`SparsityPattern`] — exactly the greedy solution of
//! (MASK SELECTION) that §2.1 of the paper derives for these methods.

use crate::pruner::mask::{BudgetSpec, SparsityPattern};
use crate::pruner::rounding::threshold;
use crate::tensor::Mat;

/// Per-column activation norms `‖X_j,:‖₂ = sqrt(G_jj)`.
pub fn act_norms(g: &Mat) -> Vec<f32> {
    assert_eq!(g.rows, g.cols);
    (0..g.rows).map(|j| g.at(j, j).max(0.0).sqrt()).collect()
}

pub fn magnitude_scores(w: &Mat) -> Mat {
    Mat::from_vec(w.rows, w.cols, w.data.iter().map(|x| x.abs()).collect())
}

pub fn wanda_scores(w: &Mat, g: &Mat) -> Mat {
    let norms = act_norms(g);
    assert_eq!(norms.len(), w.cols);
    Mat::from_fn(w.rows, w.cols, |i, j| w.at(i, j).abs() * norms[j])
}

pub fn ria_scores(w: &Mat, g: &Mat) -> Mat {
    let norms = act_norms(g);
    let row_sums: Vec<f32> = (0..w.rows)
        .map(|i| w.row(i).iter().map(|x| x.abs()).sum::<f32>().max(1e-12))
        .collect();
    let mut col_sums = vec![0.0f32; w.cols];
    for i in 0..w.rows {
        for (j, cs) in col_sums.iter_mut().enumerate() {
            *cs += w.at(i, j).abs();
        }
    }
    for cs in &mut col_sums {
        *cs = cs.max(1e-12);
    }
    Mat::from_fn(w.rows, w.cols, |i, j| {
        w.at(i, j).abs() * (1.0 / row_sums[i] + 1.0 / col_sums[j]) * norms[j]
    })
}

/// Greedy baseline mask: top-k saliency per constraint unit.
pub fn saliency_mask(scores: &Mat, pattern: &SparsityPattern) -> Mat {
    let budget = BudgetSpec::full(pattern, scores.rows, scores.cols);
    threshold(scores, &budget, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::mask_satisfies;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        let x = Mat::gaussian(din, b, 1.0, &mut rng);
        let g = matmul_a_bt(&x, &x);
        (w, g)
    }

    #[test]
    fn wanda_reduces_to_magnitude_for_isotropic_inputs() {
        let mut rng = Xoshiro256::new(1);
        let w = Mat::gaussian(6, 8, 1.0, &mut rng);
        let g = {
            let mut g = Mat::zeros(8, 8);
            for j in 0..8 {
                *g.at_mut(j, j) = 4.0; // equal column norms
            }
            g
        };
        let sw = wanda_scores(&w, &g);
        let sm = magnitude_scores(&w);
        let pat = SparsityPattern::PerRow { sparsity: 0.5 };
        assert_eq!(saliency_mask(&sw, &pat).data, saliency_mask(&sm, &pat).data);
    }

    #[test]
    fn wanda_prefers_high_activation_columns() {
        // |w| identical everywhere; G has one huge-diag column -> every
        // row must keep that column first.
        let w = Mat::ones(4, 6);
        let mut g = Mat::zeros(6, 6);
        for j in 0..6 {
            *g.at_mut(j, j) = if j == 3 { 100.0 } else { 1.0 };
        }
        let m = saliency_mask(
            &wanda_scores(&w, &g),
            &SparsityPattern::PerRow { sparsity: 5.0 / 6.0 },
        );
        for i in 0..4 {
            assert_eq!(m.at(i, 3), 1.0, "row {i} must keep col 3");
            assert_eq!(m.row(i).iter().filter(|&&x| x != 0.0).count(), 1);
        }
    }

    #[test]
    fn ria_is_wanda_on_rescaled_weights() {
        let (w, g) = setup(5, 8, 32, 7);
        // paper §2.1: RIA == Wanda applied to W′ with
        // W′_ij = W_ij (1/row_i + 1/col_j)
        let row_sums: Vec<f32> = (0..5).map(|i| w.row(i).iter().map(|x| x.abs()).sum()).collect();
        let mut col_sums = vec![0.0f32; 8];
        for i in 0..5 {
            for j in 0..8 {
                col_sums[j] += w.at(i, j).abs();
            }
        }
        let wp = Mat::from_fn(5, 8, |i, j| {
            w.at(i, j) * (1.0 / row_sums[i] + 1.0 / col_sums[j])
        });
        let s1 = ria_scores(&w, &g);
        let s2 = wanda_scores(&wp, &g);
        assert!(s1.max_abs_diff(&s2) < 1e-5);
    }

    #[test]
    fn masks_satisfy_patterns() {
        let (w, g) = setup(8, 16, 64, 3);
        for pat in [
            SparsityPattern::Unstructured { sparsity: 0.5 },
            SparsityPattern::PerRow { sparsity: 0.6 },
            SparsityPattern::NM { keep: 2, block: 4 },
        ] {
            for scores in [magnitude_scores(&w), wanda_scores(&w, &g), ria_scores(&w, &g)] {
                let m = saliency_mask(&scores, &pat);
                assert!(mask_satisfies(&m, &pat), "{pat:?}");
                assert_eq!(m.count_nonzero(), pat.keep_total(8, 16));
            }
        }
    }
}
