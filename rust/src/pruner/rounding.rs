//! Thresholding: rounding a relaxed mask `M_T ∈ [0,1]` to a binary mask
//! satisfying the original constraints (Algorithm 1 line 7 / Algorithm 2
//! line 10): keep the budget-many *largest* entries per constraint unit.
//!
//! `forbid` coordinates (the α-fixed set, which lives outside the free
//! budget) are never selected.  The Lemma 2 analysis bounds the error
//! this rounding introduces via the threshold residual
//! `‖M_T − round(M_T)‖₁`, reported by [`threshold_residual`].

use crate::pruner::mask::BudgetSpec;
use crate::tensor::topk::top_k_indices;
use crate::tensor::Mat;

/// Round `m` (relaxed, in [0,1]) to a binary mask under `budget`,
/// never selecting coordinates where `forbid` is nonzero.
pub fn threshold(m: &Mat, budget: &BudgetSpec, forbid: Option<&Mat>) -> Mat {
    let keyed: Vec<f32> = match forbid {
        None => m.data.clone(),
        Some(f) => {
            assert_eq!((f.rows, f.cols), (m.rows, m.cols));
            m.data
                .iter()
                .zip(&f.data)
                .map(|(&v, &fb)| if fb != 0.0 { f32::NEG_INFINITY } else { v })
                .collect()
        }
    };
    let mut out = Mat::zeros(m.rows, m.cols);
    match budget {
        BudgetSpec::Global { keep } => {
            for idx in top_k_indices(&keyed, *keep) {
                if keyed[idx] > f32::NEG_INFINITY {
                    out.data[idx] = 1.0;
                }
            }
        }
        BudgetSpec::PerRow { keep } => {
            assert_eq!(keep.len(), m.rows);
            for i in 0..m.rows {
                let row = &keyed[i * m.cols..(i + 1) * m.cols];
                for j in top_k_indices(row, keep[i]) {
                    if row[j] > f32::NEG_INFINITY {
                        out.data[i * m.cols + j] = 1.0;
                    }
                }
            }
        }
        BudgetSpec::NM { keep, block } => {
            let nb = m.cols / block;
            assert_eq!(keep.len(), m.rows * nb);
            for i in 0..m.rows {
                for b in 0..nb {
                    let off = i * m.cols + b * block;
                    let seg = &keyed[off..off + block];
                    for j in top_k_indices(seg, keep[i * nb + b]) {
                        if seg[j] > f32::NEG_INFINITY {
                            out.data[off + j] = 1.0;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Mean ℓ₁ threshold residual `‖M − round(M)‖₁ / numel` (Fig 4 right).
pub fn threshold_residual(m: &Mat, rounded: &Mat) -> f64 {
    m.l1_dist(rounded) / m.numel() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::{mask_satisfies, SparsityPattern};

    #[test]
    fn keeps_largest() {
        let m = Mat::from_vec(1, 5, vec![0.9, 0.1, 0.5, 0.8, 0.2]);
        let r = threshold(&m, &BudgetSpec::Global { keep: 2 }, None);
        assert_eq!(r.data, vec![1.0, 0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn respects_forbid() {
        let m = Mat::from_vec(1, 4, vec![0.9, 0.8, 0.7, 0.6]);
        let f = Mat::from_vec(1, 4, vec![1.0, 0.0, 0.0, 0.0]);
        let r = threshold(&m, &BudgetSpec::Global { keep: 2 }, Some(&f));
        assert_eq!(r.data, vec![0.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn nm_rounding_is_feasible() {
        let m = Mat::from_vec(2, 8, (0..16).map(|i| (i as f32 * 0.31) % 1.0).collect());
        let pat = SparsityPattern::NM { keep: 2, block: 4 };
        let b = BudgetSpec::full(&pat, 2, 8);
        let r = threshold(&m, &b, None);
        assert!(mask_satisfies(&r, &pat));
        assert_eq!(r.count_nonzero(), 8);
    }

    #[test]
    fn residual_zero_for_binary() {
        let m = Mat::from_vec(1, 4, vec![1.0, 0.0, 1.0, 0.0]);
        let r = threshold(&m, &BudgetSpec::Global { keep: 2 }, None);
        assert_eq!(threshold_residual(&m, &r), 0.0);
    }
}
