//! Sparsity patterns and keep-budget accounting.
//!
//! The paper's constraint sets (§2.2, Appendix D):
//!
//! * **Unstructured** — `‖M‖₀ ≤ k` over the whole matrix (C_k).
//! * **Per-row** — equal budget per row (what Wanda enforces; decouples
//!   the rows).
//! * **n:m semi-structured** — keep at most `keep` nonzeros in every
//!   block of `block` consecutive entries of each row (C_{n:m});
//!   "2:4" = `{ keep: 2, block: 4 }`.
//!
//! [`BudgetSpec`] turns a pattern (minus any α-fixed coordinates) into
//! explicit per-unit keep counts consumed by the LMO and the rounding
//! step.

use anyhow::{ensure, Result};

use crate::tensor::Mat;

#[derive(Clone, Debug, PartialEq)]
pub enum SparsityPattern {
    /// Global budget: keep `round((1−sparsity)·numel)` weights.
    Unstructured { sparsity: f64 },
    /// Per-row budget: keep `round((1−sparsity)·d_in)` weights per row.
    PerRow { sparsity: f64 },
    /// Keep `keep` of every `block` consecutive entries per row.
    NM { keep: usize, block: usize },
}

impl SparsityPattern {
    pub fn validate(&self, d_in: usize) -> Result<()> {
        match self {
            SparsityPattern::Unstructured { sparsity } | SparsityPattern::PerRow { sparsity } => {
                ensure!(
                    (0.0..=1.0).contains(sparsity),
                    "sparsity must be in [0,1], got {sparsity}"
                );
            }
            SparsityPattern::NM { keep, block } => {
                ensure!(*block > 0 && keep <= block, "bad n:m pattern {keep}:{block}");
                ensure!(
                    d_in % *block == 0,
                    "d_in={d_in} not divisible by block={block}"
                );
            }
        }
        Ok(())
    }

    /// Total kept weights for a (d_out × d_in) layer.
    pub fn keep_total(&self, d_out: usize, d_in: usize) -> usize {
        match self {
            SparsityPattern::Unstructured { sparsity } => {
                (((1.0 - sparsity) * (d_out * d_in) as f64).round() as usize).min(d_out * d_in)
            }
            SparsityPattern::PerRow { sparsity } => {
                let per_row = ((1.0 - sparsity) * d_in as f64).round() as usize;
                per_row.min(d_in) * d_out
            }
            SparsityPattern::NM { keep, block } => d_out * (d_in / block) * keep,
        }
    }

    /// Achieved sparsity for a layer shape (reporting convenience).
    pub fn sparsity(&self, d_out: usize, d_in: usize) -> f64 {
        1.0 - self.keep_total(d_out, d_in) as f64 / (d_out * d_in) as f64
    }

    pub fn label(&self) -> String {
        match self {
            SparsityPattern::Unstructured { sparsity } => format!("unstructured-{:.0}%", sparsity * 100.0),
            SparsityPattern::PerRow { sparsity } => format!("per-row-{:.0}%", sparsity * 100.0),
            SparsityPattern::NM { keep, block } => format!("{keep}:{block}"),
        }
    }
}

/// Explicit keep budgets per constraint unit, after removing α-fixed
/// coordinates from the pattern's budget.
#[derive(Clone, Debug, PartialEq)]
pub enum BudgetSpec {
    /// One global budget over all free coordinates.
    Global { keep: usize },
    /// keep[i] for row i.
    PerRow { keep: Vec<usize> },
    /// keep[row * n_blocks + b] for block b of row `row`.
    NM { keep: Vec<usize>, block: usize },
}

impl BudgetSpec {
    /// Budgets of `pattern` with the ones of `fixed` already spent.
    /// `fixed` must itself satisfy the pattern (checked by saturating
    /// subtraction + debug assert).
    pub fn free_budgets(pattern: &SparsityPattern, d_out: usize, d_in: usize, fixed: &Mat) -> Self {
        assert_eq!((fixed.rows, fixed.cols), (d_out, d_in));
        match pattern {
            SparsityPattern::Unstructured { .. } => {
                let used = fixed.data.iter().filter(|&&x| x != 0.0).count();
                let total = pattern.keep_total(d_out, d_in);
                debug_assert!(used <= total, "fixed mask exceeds budget");
                BudgetSpec::Global { keep: total.saturating_sub(used) }
            }
            SparsityPattern::PerRow { sparsity } => {
                let per_row = (((1.0 - sparsity) * d_in as f64).round() as usize).min(d_in);
                let keep = (0..d_out)
                    .map(|i| {
                        let used = fixed.row(i).iter().filter(|&&x| x != 0.0).count();
                        per_row.saturating_sub(used)
                    })
                    .collect();
                BudgetSpec::PerRow { keep }
            }
            SparsityPattern::NM { keep, block } => {
                let nb = d_in / block;
                let mut keeps = Vec::with_capacity(d_out * nb);
                for i in 0..d_out {
                    let row = fixed.row(i);
                    for b in 0..nb {
                        let used = row[b * block..(b + 1) * block]
                            .iter()
                            .filter(|&&x| x != 0.0)
                            .count();
                        keeps.push(keep.saturating_sub(used));
                    }
                }
                BudgetSpec::NM { keep: keeps, block: *block }
            }
        }
    }

    /// Budgets of the raw pattern (no fixed coordinates).
    pub fn full(pattern: &SparsityPattern, d_out: usize, d_in: usize) -> Self {
        Self::free_budgets(pattern, d_out, d_in, &Mat::zeros(d_out, d_in))
    }

    pub fn total(&self) -> usize {
        match self {
            BudgetSpec::Global { keep } => *keep,
            BudgetSpec::PerRow { keep } => keep.iter().sum(),
            BudgetSpec::NM { keep, .. } => keep.iter().sum(),
        }
    }
}

/// Check a binary mask against a pattern's constraints.
pub fn mask_satisfies(mask: &Mat, pattern: &SparsityPattern) -> bool {
    let (d_out, d_in) = (mask.rows, mask.cols);
    if mask.data.iter().any(|&x| x != 0.0 && x != 1.0) {
        return false;
    }
    match pattern {
        SparsityPattern::Unstructured { .. } => {
            mask.count_nonzero() <= pattern.keep_total(d_out, d_in)
        }
        SparsityPattern::PerRow { sparsity } => {
            let per_row = (((1.0 - sparsity) * d_in as f64).round() as usize).min(d_in);
            (0..d_out).all(|i| mask.row(i).iter().filter(|&&x| x != 0.0).count() <= per_row)
        }
        SparsityPattern::NM { keep, block } => {
            if d_in % block != 0 {
                return false;
            }
            (0..d_out).all(|i| {
                mask.row(i)
                    .chunks(*block)
                    .all(|c| c.iter().filter(|&&x| x != 0.0).count() <= *keep)
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_totals() {
        let p = SparsityPattern::Unstructured { sparsity: 0.6 };
        assert_eq!(p.keep_total(10, 10), 40);
        let p = SparsityPattern::PerRow { sparsity: 0.5 };
        assert_eq!(p.keep_total(4, 10), 20);
        let p = SparsityPattern::NM { keep: 2, block: 4 };
        assert_eq!(p.keep_total(4, 16), 32);
        assert!((p.sparsity(4, 16) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn validation() {
        assert!(SparsityPattern::NM { keep: 2, block: 4 }.validate(16).is_ok());
        assert!(SparsityPattern::NM { keep: 2, block: 4 }.validate(18).is_err());
        assert!(SparsityPattern::NM { keep: 5, block: 4 }.validate(16).is_err());
        assert!(SparsityPattern::Unstructured { sparsity: 1.5 }.validate(8).is_err());
    }

    #[test]
    fn free_budgets_subtract_fixed() {
        let mut fixed = Mat::zeros(2, 8);
        fixed.data[0] = 1.0; // row 0, block 0
        fixed.data[9] = 1.0; // row 1, block 0 (col 1)
        let b = BudgetSpec::free_budgets(
            &SparsityPattern::PerRow { sparsity: 0.5 },
            2,
            8,
            &fixed,
        );
        assert_eq!(b, BudgetSpec::PerRow { keep: vec![3, 3] });

        let b = BudgetSpec::free_budgets(&SparsityPattern::NM { keep: 2, block: 4 }, 2, 8, &fixed);
        assert_eq!(
            b,
            BudgetSpec::NM { keep: vec![1, 2, 1, 2], block: 4 }
        );

        let b = BudgetSpec::free_budgets(
            &SparsityPattern::Unstructured { sparsity: 0.5 },
            2,
            8,
            &fixed,
        );
        assert_eq!(b.total(), 6);
    }

    #[test]
    fn satisfies_checks() {
        let mut m = Mat::zeros(2, 8);
        for j in 0..4 {
            m.data[j] = 1.0;
        }
        assert!(mask_satisfies(&m, &SparsityPattern::Unstructured { sparsity: 0.5 }));
        assert!(!mask_satisfies(&m, &SparsityPattern::NM { keep: 2, block: 4 }));
        m.data[2] = 0.5;
        assert!(!mask_satisfies(&m, &SparsityPattern::Unstructured { sparsity: 0.0 }));
    }
}
