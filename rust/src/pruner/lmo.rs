//! Linear Minimization Oracles over the relaxed constraint sets.
//!
//! Paper Eq. (12) + Appendix D: minimizing `⟨V, ∇L⟩` over the convex
//! hull of feasible masks selects the (up to) budget-many entries with
//! the most *negative* gradient coefficients and sets them to one —
//! entries with non-negative coefficients are never selected (the
//! coupling constraint is an inequality, so leaving them at zero is
//! optimal).
//!
//! The [`BudgetSpec`] variants give the three constraint geometries:
//! global (C_k), per-row, and n:m blocks (the cartesian-product LMO of
//! Appendix D).

use crate::pruner::mask::BudgetSpec;
use crate::tensor::topk::{bottom_k_indices, bottom_k_into};
use crate::tensor::Mat;
use crate::util::pool::parallel_for;
use std::sync::Mutex;

/// `argmin_{V ∈ C} ⟨V, grad⟩` — returns a binary vertex mask.
pub fn lmo(grad: &Mat, budget: &BudgetSpec) -> Mat {
    match budget {
        BudgetSpec::Global { keep } => lmo_global(grad, *keep),
        BudgetSpec::PerRow { keep } => lmo_per_row(grad, keep),
        BudgetSpec::NM { keep, block } => lmo_nm(grad, keep, *block),
    }
}

fn lmo_global(grad: &Mat, keep: usize) -> Mat {
    let mut v = Mat::zeros(grad.rows, grad.cols);
    for idx in bottom_k_indices(&grad.data, keep) {
        if grad.data[idx] < 0.0 {
            v.data[idx] = 1.0;
        }
    }
    v
}

fn lmo_per_row(grad: &Mat, keep: &[usize]) -> Mat {
    assert_eq!(keep.len(), grad.rows);
    let out = Mutex::new(Mat::zeros(grad.rows, grad.cols));
    parallel_for(grad.rows, |i| {
        let row = grad.row(i);
        let sel: Vec<usize> = bottom_k_indices(row, keep[i])
            .into_iter()
            .filter(|&j| row[j] < 0.0)
            .collect();
        let mut m = out.lock().unwrap();
        for j in sel {
            m.data[i * grad.cols + j] = 1.0;
        }
    });
    out.into_inner().unwrap()
}

fn lmo_nm(grad: &Mat, keep: &[usize], block: usize) -> Mat {
    let nb = grad.cols / block;
    assert_eq!(keep.len(), grad.rows * nb);
    let mut v = Mat::zeros(grad.rows, grad.cols);
    for i in 0..grad.rows {
        let row = grad.row(i);
        for b in 0..nb {
            let seg = &row[b * block..(b + 1) * block];
            for j in bottom_k_indices(seg, keep[i * nb + b]) {
                if seg[j] < 0.0 {
                    v.data[i * grad.cols + b * block + j] = 1.0;
                }
            }
        }
    }
    v
}

/// Sparse-vertex LMO: same selection as [`lmo`] but emitting the
/// vertex's support as sorted flat indices (`i·cols + j`) instead of a
/// dense matrix.  `idx_buf` is select scratch reused across calls, so
/// the incremental FW hot loop (`pruner::fw_engine`) allocates nothing
/// after warmup.  `grad` is a `rows×cols` block (possibly a row slice
/// of a larger layer, with `budget` sliced to match).
pub fn lmo_into(
    grad: &[f32],
    rows: usize,
    cols: usize,
    budget: &BudgetSpec,
    idx_buf: &mut Vec<u32>,
    out: &mut Vec<u32>,
) {
    debug_assert_eq!(grad.len(), rows * cols);
    out.clear();
    match budget {
        BudgetSpec::Global { keep } => {
            let k = bottom_k_into(grad, *keep, idx_buf);
            for &ix in &idx_buf[..k] {
                if grad[ix as usize] < 0.0 {
                    out.push(ix);
                }
            }
        }
        BudgetSpec::PerRow { keep } => {
            debug_assert_eq!(keep.len(), rows);
            for i in 0..rows {
                let row = &grad[i * cols..(i + 1) * cols];
                let k = bottom_k_into(row, keep[i], idx_buf);
                for &j in &idx_buf[..k] {
                    if row[j as usize] < 0.0 {
                        out.push((i * cols) as u32 + j);
                    }
                }
            }
        }
        BudgetSpec::NM { keep, block } => {
            let nb = cols / block;
            debug_assert_eq!(keep.len(), rows * nb);
            for i in 0..rows {
                for b in 0..nb {
                    let off = i * cols + b * block;
                    let seg = &grad[off..off + block];
                    let k = bottom_k_into(seg, keep[i * nb + b], idx_buf);
                    for &j in &idx_buf[..k] {
                        if seg[j as usize] < 0.0 {
                            out.push(off as u32 + j);
                        }
                    }
                }
            }
        }
    }
    out.sort_unstable();
}

/// Brute-force LMO value check helper: ⟨V, grad⟩.
pub fn lmo_value(v: &Mat, grad: &Mat) -> f64 {
    v.data
        .iter()
        .zip(&grad.data)
        .map(|(a, b)| (a * b) as f64)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn global_selects_most_negative() {
        let grad = Mat::from_vec(2, 3, vec![-5.0, 1.0, -1.0, -3.0, 0.0, 2.0]);
        let v = lmo(&grad, &BudgetSpec::Global { keep: 2 });
        assert_eq!(v.data, vec![1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn never_selects_nonnegative() {
        let grad = Mat::from_vec(1, 4, vec![1.0, 2.0, 0.0, -0.5]);
        let v = lmo(&grad, &BudgetSpec::Global { keep: 3 });
        assert_eq!(v.count_nonzero(), 1);
        assert_eq!(v.data[3], 1.0);
    }

    #[test]
    fn per_row_budgets() {
        let grad = Mat::from_vec(2, 4, vec![-4.0, -3.0, -2.0, -1.0, -1.0, -2.0, -3.0, -4.0]);
        let v = lmo(&grad, &BudgetSpec::PerRow { keep: vec![1, 2] });
        assert_eq!(v.data, vec![1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn nm_blocks() {
        let grad = Mat::from_vec(1, 8, vec![-1.0, -2.0, 3.0, -4.0, -9.0, -8.0, -7.0, -6.0]);
        let v = lmo(
            &grad,
            &BudgetSpec::NM { keep: vec![2, 2], block: 4 },
        );
        assert_eq!(v.data, vec![0.0, 1.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0]);
    }

    /// The sparse-index LMO must make the exact same selection as the
    /// dense one on every constraint geometry.
    #[test]
    fn lmo_into_matches_dense_lmo() {
        let mut rng = Xoshiro256::new(23);
        let (rows, cols) = (6, 8);
        let mut idx_buf = Vec::new();
        let mut out = Vec::new();
        for trial in 0..25 {
            let grad = Mat::gaussian(rows, cols, 1.0, &mut rng);
            let budgets = [
                BudgetSpec::Global { keep: 1 + rng.next_below(20) as usize },
                BudgetSpec::PerRow {
                    keep: (0..rows).map(|_| rng.next_below(5) as usize).collect(),
                },
                BudgetSpec::NM {
                    keep: (0..rows * 2).map(|_| rng.next_below(4) as usize).collect(),
                    block: 4,
                },
            ];
            for budget in &budgets {
                let dense = lmo(&grad, budget);
                lmo_into(&grad.data, rows, cols, budget, &mut idx_buf, &mut out);
                let want: Vec<u32> = dense
                    .data
                    .iter()
                    .enumerate()
                    .filter(|(_, &x)| x != 0.0)
                    .map(|(i, _)| i as u32)
                    .collect();
                assert_eq!(out, want, "trial {trial} budget {budget:?}");
            }
        }
    }

    /// The LMO must be optimal: no other feasible vertex has smaller
    /// inner product with the gradient.  Checked by exhaustive
    /// enumeration on small instances.
    #[test]
    fn global_is_optimal_vs_bruteforce() {
        let mut rng = Xoshiro256::new(11);
        for _ in 0..20 {
            let grad = Mat::gaussian(2, 4, 1.0, &mut rng);
            let keep = 1 + (rng.next_below(6) as usize);
            let v = lmo(&grad, &BudgetSpec::Global { keep });
            let best = lmo_value(&v, &grad);
            // enumerate all binary masks with <= keep ones (8 cells)
            for bits in 0u32..256 {
                if bits.count_ones() as usize > keep {
                    continue;
                }
                let cand = Mat::from_vec(
                    2,
                    4,
                    (0..8).map(|i| ((bits >> i) & 1) as f32).collect(),
                );
                assert!(lmo_value(&cand, &grad) >= best - 1e-9);
            }
        }
    }
}
