//! The open method API: [`LayerPruner`] is the object-safe trait every
//! pruning method implements, [`LayerCtx`] the one-stop context it
//! receives, and [`Method`] the cloneable handle the rest of the stack
//! (JobSpec, CLI, server, reports) carries around.
//!
//! The paper frames SparseFW as one point in a family of layer-wise
//! mask optimizers (§2.1); this module makes that family *open*: a new
//! method is one trait impl plus one
//! [`MethodRegistration`](crate::pruner::registry::MethodRegistration)
//! — CLI parsing, JobSpec JSON round-trip, server-side validation and
//! the `GET /methods` / `sparsefw methods` listings all route through
//! the [`MethodRegistry`](crate::pruner::registry::MethodRegistry) and
//! pick the new method up for free.  The legacy [`PruneMethod`]
//! (see [`crate::pruner`]) enum survives as a thin construction shim.

use std::fmt;
use std::sync::Arc;

use anyhow::Result;

use crate::pruner::mask::SparsityPattern;
use crate::pruner::saliency;
use crate::pruner::sparsefw::{self, ConvergenceTrace, FwKernels, FwTrace, SparseFwConfig};
use crate::pruner::sparsegpt;
use crate::tensor::Mat;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Context + output
// ---------------------------------------------------------------------------

/// Everything a method needs to prune one layer, bundled so the trait
/// stays object-safe (no `<K: FwKernels>` generic threading).
pub struct LayerCtx<'a> {
    /// Gradient/objective backend (native matmuls or AOT Pallas kernels
    /// via PJRT).  Deliberately a trait object: methods must not care.
    pub kernels: &'a (dyn FwKernels + 'a),
    /// The layer's dense weights (d_out × d_in).
    pub w: &'a Mat,
    /// Calibration gram matrix G = XXᵀ (d_in × d_in).
    pub g: &'a Mat,
    /// The resolved sparsity pattern for this layer.
    pub pattern: &'a SparsityPattern,
    /// Layer name, for logs/errors ("" when pruning outside a model).
    pub layer: &'a str,
    /// Spec-level tracing override: record a trace point every N
    /// iterations (0 = leave the method's own setting untouched).
    pub trace_every: usize,
}

impl<'a> LayerCtx<'a> {
    /// Context with no layer name and no tracing override.
    pub fn new(
        kernels: &'a (dyn FwKernels + 'a),
        w: &'a Mat,
        g: &'a Mat,
        pattern: &'a SparsityPattern,
    ) -> Self {
        Self { kernels, w, g, pattern, layer: "", trace_every: 0 }
    }

    pub fn with_trace_every(mut self, every: usize) -> Self {
        self.trace_every = every;
        self
    }
}

/// Result of pruning one layer with any method.
pub struct LayerPruneOutput {
    pub mask: Mat,
    /// L(mask) under the layer objective (after a weight-update refine
    /// pass this is the realized reconstruction error ‖WX − ŴX‖²).
    pub obj: f64,
    /// L(warmstart) when the method has one (SparseFW).
    pub warm_obj: Option<f64>,
    /// Reconstructed weights (SparseGPT, or the weight-update refine
    /// pass); zero exactly off-mask.
    pub new_weights: Option<Mat>,
    pub trace: Option<FwTrace>,
    /// Per-iteration convergence certificate (objective / duality gap /
    /// step size / refresh drift), recorded by iterative methods when
    /// tracing is on; `None` for greedy methods or untraced runs.
    pub convergence: Option<ConvergenceTrace>,
    /// FW iterations executed (0 for the greedy/one-shot methods).
    pub fw_iters: usize,
    /// Objective improvement contributed by refine post-passes
    /// (obj_before_refine − obj_after_refine ≥ 0); `None` when no
    /// refine pass ran.
    pub refine_obj_delta: Option<f64>,
}

impl LayerPruneOutput {
    pub(crate) fn from_mask(
        kernels: &(dyn FwKernels + '_),
        w: &Mat,
        g: &Mat,
        mask: Mat,
    ) -> Result<Self> {
        let obj = kernels.objective(w, &mask, g)?;
        Ok(Self {
            mask,
            obj,
            warm_obj: None,
            new_weights: None,
            trace: None,
            convergence: None,
            fw_iters: 0,
            refine_obj_delta: None,
        })
    }
}

// ---------------------------------------------------------------------------
// The trait
// ---------------------------------------------------------------------------

/// Capability flags a method advertises (listed by `GET /methods` and
/// `sparsefw methods`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MethodCaps {
    /// May return [`LayerPruneOutput::new_weights`] (SparseGPT-style
    /// reconstruction).
    pub reconstructs_weights: bool,
    /// The per-iteration hot loop can execute through the compiled PJRT
    /// [`FwKernels`] (methods that only *score* through the kernels run
    /// their inner loop natively regardless of backend).
    pub supports_pjrt: bool,
    /// Runs an iterative optimization (reports nonzero `fw_iters`).
    pub iterative: bool,
}

impl MethodCaps {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("reconstructs_weights", self.reconstructs_weights.into()),
            ("supports_pjrt", self.supports_pjrt.into()),
            ("iterative", self.iterative.into()),
        ])
    }
}

/// An object-safe, layer-wise pruning method.
///
/// Implement this plus register a
/// [`MethodRegistration`](crate::pruner::registry::MethodRegistration)
/// and the whole stack — `--method NAME`, JobSpec JSON, `sparsefw
/// serve` submissions, `GET /methods`, the `table1_methods` bench —
/// picks the method up with no further changes (see the lib.rs
/// "adding a pruning method" walkthrough).
pub trait LayerPruner: Send + Sync {
    /// Registry name (`"wanda"`, `"sparsefw"`, …) — the `"kind"` field
    /// of the method's JSON form and the `--method` CLI value.
    fn name(&self) -> &str;

    /// Human label for reports (defaults to [`LayerPruner::name`]).
    fn label(&self) -> String {
        self.name().to_string()
    }

    fn caps(&self) -> MethodCaps {
        MethodCaps::default()
    }

    /// Prune one layer.
    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput>;

    /// This instance's configuration as a JSON object (config fields
    /// only — the registry adds the `"kind"` discriminator).  Must
    /// round-trip through the registration's `from_json`.
    fn config_to_json(&self) -> Json {
        Json::obj(vec![])
    }
}

// ---------------------------------------------------------------------------
// Method: the cloneable handle
// ---------------------------------------------------------------------------

/// A pruning method as carried by [`crate::coordinator::JobSpec`],
/// reports, and the server: a shared handle to a [`LayerPruner`].
#[derive(Clone)]
pub struct Method(Arc<dyn LayerPruner>);

impl Method {
    /// Wrap any [`LayerPruner`] implementation.
    pub fn from_pruner(p: impl LayerPruner + 'static) -> Self {
        Method(Arc::new(p))
    }

    /// Look a method up in the global registry and build it with its
    /// default configuration.
    pub fn named(name: &str) -> Result<Self> {
        crate::pruner::registry::MethodRegistry::global().default(name)
    }

    pub fn name(&self) -> &str {
        self.0.name()
    }

    pub fn label(&self) -> String {
        self.0.label()
    }

    pub fn caps(&self) -> MethodCaps {
        self.0.caps()
    }

    pub fn config_to_json(&self) -> Json {
        self.0.config_to_json()
    }

    pub fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        self.0.prune_layer(ctx)
    }

    // -- builtin constructors ----------------------------------------------

    pub fn magnitude() -> Self {
        Method::from_pruner(MagnitudePruner)
    }

    pub fn wanda() -> Self {
        Method::from_pruner(WandaPruner)
    }

    pub fn ria() -> Self {
        Method::from_pruner(RiaPruner)
    }

    pub fn sparsefw(cfg: SparseFwConfig) -> Self {
        Method::from_pruner(SparseFwPruner(cfg))
    }

    pub fn sparsegpt(percdamp: f64, blocksize: usize) -> Self {
        Method::from_pruner(SparseGptPruner { percdamp, blocksize })
    }
}

impl fmt::Debug for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Method({})", self.label())
    }
}

impl Default for Method {
    fn default() -> Self {
        Method::sparsefw(SparseFwConfig::default())
    }
}

// ---------------------------------------------------------------------------
// Built-in methods
// ---------------------------------------------------------------------------

fn saliency_output(ctx: &LayerCtx, scores: Mat) -> Result<LayerPruneOutput> {
    let mask = saliency::saliency_mask(&scores, ctx.pattern);
    LayerPruneOutput::from_mask(ctx.kernels, ctx.w, ctx.g, mask)
}

/// `S_ij = |W_ij|` — the classical greedy criterion.
pub struct MagnitudePruner;

impl LayerPruner for MagnitudePruner {
    fn name(&self) -> &str {
        "magnitude"
    }

    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        saliency_output(ctx, saliency::magnitude_scores(ctx.w))
    }
}

/// Wanda (Sun et al., 2023): `S_ij = |W_ij|·‖X_j,:‖₂`.
pub struct WandaPruner;

impl LayerPruner for WandaPruner {
    fn name(&self) -> &str {
        "wanda"
    }

    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        saliency_output(ctx, saliency::wanda_scores(ctx.w, ctx.g))
    }
}

/// RIA (Zhang et al., 2024): Wanda on relative-importance rescaled W.
pub struct RiaPruner;

impl LayerPruner for RiaPruner {
    fn name(&self) -> &str {
        "ria"
    }

    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        saliency_output(ctx, saliency::ria_scores(ctx.w, ctx.g))
    }
}

/// The paper's SparseFW (Algorithms 1–2) over a [`SparseFwConfig`].
pub struct SparseFwPruner(pub SparseFwConfig);

impl LayerPruner for SparseFwPruner {
    fn name(&self) -> &str {
        "sparsefw"
    }

    fn label(&self) -> String {
        format!("sparsefw({})", self.0.warmstart.label())
    }

    fn caps(&self) -> MethodCaps {
        MethodCaps { reconstructs_weights: false, supports_pjrt: true, iterative: true }
    }

    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        // spec-level tracing override (JobSpec::trace_every)
        let traced;
        let cfg = if ctx.trace_every > 0 {
            traced = SparseFwConfig { trace_every: ctx.trace_every, ..self.0.clone() };
            &traced
        } else {
            &self.0
        };
        let r = sparsefw::run_layer(ctx.kernels, ctx.w, ctx.g, ctx.pattern, cfg)?;
        Ok(LayerPruneOutput {
            obj: r.final_obj,
            warm_obj: Some(r.warm_obj),
            trace: r.trace,
            convergence: r.convergence,
            mask: r.mask,
            new_weights: None,
            fw_iters: r.fw_iters,
            refine_obj_delta: None,
        })
    }

    fn config_to_json(&self) -> Json {
        let c = &self.0;
        Json::obj(vec![
            ("iters", c.iters.into()),
            ("alpha", c.alpha.into()),
            ("warmstart", c.warmstart.label().into()),
            ("trace_every", c.trace_every.into()),
            ("use_chunk", c.use_chunk.into()),
            ("keep_best", c.keep_best.into()),
            ("line_search", c.line_search.into()),
            ("engine", c.engine.label().into()),
            ("refresh_every", c.refresh_every.into()),
        ])
    }
}

/// SparseGPT (Frantar & Alistarh, 2023): greedy + OBS reconstruction.
pub struct SparseGptPruner {
    pub percdamp: f64,
    pub blocksize: usize,
}

impl LayerPruner for SparseGptPruner {
    fn name(&self) -> &str {
        "sparsegpt"
    }

    fn caps(&self) -> MethodCaps {
        MethodCaps { reconstructs_weights: true, supports_pjrt: false, iterative: false }
    }

    fn prune_layer(&self, ctx: &LayerCtx) -> Result<LayerPruneOutput> {
        let r = sparsegpt::sparsegpt(ctx.w, ctx.g, ctx.pattern, self.percdamp, self.blocksize)?;
        let obj = ctx.kernels.objective(ctx.w, &r.mask, ctx.g)?;
        Ok(LayerPruneOutput {
            obj,
            warm_obj: None,
            trace: None,
            convergence: None,
            mask: r.mask,
            new_weights: Some(r.weights),
            fw_iters: 0,
            refine_obj_delta: None,
        })
    }

    fn config_to_json(&self) -> Json {
        Json::obj(vec![
            ("percdamp", self.percdamp.into()),
            ("blocksize", self.blocksize.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::mask_satisfies;
    use crate::pruner::sparsefw::NativeKernels;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn setup(seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(8, 16, 1.0, &mut rng);
        let x = Mat::gaussian(16, 64, 1.0, &mut rng);
        (w, matmul_a_bt(&x, &x))
    }

    #[test]
    fn builtin_methods_produce_feasible_masks() {
        let (w, g) = setup(1);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        for method in [
            Method::magnitude(),
            Method::wanda(),
            Method::ria(),
            Method::sparsefw(SparseFwConfig { iters: 30, alpha: 0.5, ..Default::default() }),
            Method::sparsegpt(0.01, 8),
        ] {
            let ctx = LayerCtx::new(&NativeKernels, &w, &g, &pattern);
            let out = method.prune_layer(&ctx).unwrap();
            assert!(mask_satisfies(&out.mask, &pattern), "{}", method.name());
            assert!(out.obj.is_finite());
            assert_eq!(
                out.new_weights.is_some(),
                method.caps().reconstructs_weights,
                "{}",
                method.name()
            );
        }
    }

    #[test]
    fn trace_override_through_ctx() {
        let (w, g) = setup(2);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let method = Method::sparsefw(SparseFwConfig { iters: 40, alpha: 0.5, ..Default::default() });
        let ctx = LayerCtx::new(&NativeKernels, &w, &g, &pattern).with_trace_every(10);
        let out = method.prune_layer(&ctx).unwrap();
        assert!(out.trace.is_some(), "ctx trace_every must enable tracing");
        let ctx = LayerCtx::new(&NativeKernels, &w, &g, &pattern);
        assert!(method.prune_layer(&ctx).unwrap().trace.is_none());
    }

    #[test]
    fn labels_and_caps() {
        assert_eq!(Method::wanda().label(), "wanda");
        assert_eq!(
            Method::sparsefw(SparseFwConfig::default()).label(),
            "sparsefw(wanda)"
        );
        assert!(Method::sparsegpt(0.01, 128).caps().reconstructs_weights);
        assert!(Method::sparsefw(SparseFwConfig::default()).caps().iterative);
        assert!(!Method::wanda().caps().reconstructs_weights);
    }
}
