//! Composable mask-refinement post-passes, applicable to *any* method
//! through the open [`LayerPruner`](crate::pruner::LayerPruner) API —
//! the proof that the method layer is genuinely open:
//!
//! * [`RefinePass::Swaps`] — SparseSwaps-style greedy 1-swap mask
//!   refinement (Zimmer et al., 2025): after rounding, repeatedly swap
//!   one kept weight for one pruned weight when that strictly lowers
//!   the layer objective.  The objective is row-separable
//!   (`L = Σ_r z_r G z_rᵀ`, `z_r = w_r ⊙ (1 − m_r)`), so with the
//!   maintained state `S = Z·G` every candidate swap scores in O(1):
//!
//!   `Δ(prune a, keep b) = 2(w_a S_ra − w_b S_rb) + w_a²G_aa + w_b²G_bb
//!                          − 2 w_a w_b G_ab` (same row; the cross term
//!   vanishes across rows).  Accepting a swap costs one O(d_in) update
//!   of `S`.  Swaps stay inside the pattern's constraint unit (row /
//!   n:m block / whole matrix), so feasibility and the keep count are
//!   invariant.
//!
//! * [`RefinePass::WeightUpdate`] — least-squares masked weight update
//!   (Boža, 2024): per row, re-solve the kept weights against the gram,
//!   `(G_SS + λI) ŵ_S = G_S,: w_rᵀ` — the cheap post-hoc reconstruction
//!   that recovers most of SparseGPT's gains for any mask.
//!
//! Passes compose in order (`--refine swaps,update`); a swaps pass that
//! changes the mask after weights were reconstructed re-runs the
//! update on the final mask.  A final keep-best guard re-evaluates the
//! realized objective and reverts the whole refinement if float noise
//! ever made it worse, so refine **never raises the layer objective**
//! (regression-tested across all three sparsity patterns).

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::pruner::mask::SparsityPattern;
use crate::pruner::method::LayerPruneOutput;
use crate::pruner::sparsefw::FwKernels;
use crate::tensor::linalg::{chol_solve, cholesky, MatF64};
use crate::tensor::{matmul, Mat};
use crate::util::json::Json;
use crate::util::pool::parallel_for;

/// Default cap on accepted swaps per constraint unit.
pub const DEFAULT_MAX_SWAPS: usize = 32;
/// Default relative dampening of the least-squares update.
pub const DEFAULT_UPDATE_PERCDAMP: f64 = 0.01;

/// One refinement stage.  Parsed from `--refine swaps,update` and the
/// JobSpec JSON `"refine"` array (strings for defaults, objects for
/// tuned parameters).
#[derive(Clone, Debug, PartialEq)]
pub enum RefinePass {
    /// Greedy 1-swap mask refinement; `max_swaps` bounds accepted swaps
    /// per constraint unit (row / n:m block; the unstructured pattern
    /// gets per-row passes plus `max_swaps` cross-row budget moves).
    Swaps { max_swaps: usize },
    /// Least-squares masked weight update with relative damping.
    WeightUpdate { percdamp: f64 },
}

impl RefinePass {
    pub fn swaps() -> Self {
        RefinePass::Swaps { max_swaps: DEFAULT_MAX_SWAPS }
    }

    pub fn update() -> Self {
        RefinePass::WeightUpdate { percdamp: DEFAULT_UPDATE_PERCDAMP }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RefinePass::Swaps { .. } => "swaps",
            RefinePass::WeightUpdate { .. } => "update",
        }
    }

    /// Parse one pass name (`swaps` | `update`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.trim() {
            "swaps" => RefinePass::swaps(),
            "update" => RefinePass::update(),
            other => bail!("unknown refine pass {other:?} (swaps|update)"),
        })
    }

    /// Parse a `--refine` flag value: comma- or plus-separated pass
    /// names, or `none`/`off` for the empty list.
    pub fn parse_list(s: &str) -> Result<Vec<Self>> {
        let s = s.trim();
        if s.is_empty() || s == "none" || s == "off" {
            return Ok(Vec::new());
        }
        s.split(|c| c == ',' || c == '+').map(Self::parse).collect()
    }

    /// `"swaps+update"` (empty string for no passes).
    pub fn list_label(passes: &[Self]) -> String {
        passes.iter().map(|p| p.label()).collect::<Vec<_>>().join("+")
    }

    pub fn to_json(&self) -> Json {
        match self {
            RefinePass::Swaps { max_swaps } if *max_swaps == DEFAULT_MAX_SWAPS => {
                Json::Str("swaps".into())
            }
            RefinePass::Swaps { max_swaps } => Json::obj(vec![
                ("kind", "swaps".into()),
                ("max_swaps", (*max_swaps).into()),
            ]),
            RefinePass::WeightUpdate { percdamp } if *percdamp == DEFAULT_UPDATE_PERCDAMP => {
                Json::Str("update".into())
            }
            RefinePass::WeightUpdate { percdamp } => Json::obj(vec![
                ("kind", "update".into()),
                ("percdamp", (*percdamp).into()),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        if let Some(s) = v.as_str() {
            return Self::parse(s);
        }
        let Some(obj) = v.as_obj() else {
            bail!("refine pass must be a string or an object, got {v:?}");
        };
        match v.at(&["kind"]).as_str() {
            Some("swaps") => {
                for k in obj.keys() {
                    if k != "kind" && k != "max_swaps" {
                        bail!("unknown field {k:?} in \"swaps\" refine pass");
                    }
                }
                Ok(RefinePass::Swaps {
                    max_swaps: v.at(&["max_swaps"]).as_usize().unwrap_or(DEFAULT_MAX_SWAPS),
                })
            }
            Some("update") => {
                for k in obj.keys() {
                    if k != "kind" && k != "percdamp" {
                        bail!("unknown field {k:?} in \"update\" refine pass");
                    }
                }
                Ok(RefinePass::WeightUpdate {
                    percdamp: v.at(&["percdamp"]).as_f64().unwrap_or(DEFAULT_UPDATE_PERCDAMP),
                })
            }
            other => bail!("unknown refine pass kind {other:?} (swaps|update)"),
        }
    }

    pub fn list_to_json(passes: &[Self]) -> Json {
        Json::Arr(passes.iter().map(|p| p.to_json()).collect())
    }

    pub fn list_from_json(v: &Json) -> Result<Vec<Self>> {
        match v {
            Json::Null => Ok(Vec::new()),
            Json::Arr(items) => items.iter().map(Self::from_json).collect(),
            other => bail!("\"refine\" must be an array of passes, got {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Application
// ---------------------------------------------------------------------------

/// Realized reconstruction error ‖WX − ŴX‖² in the gram form:
/// Σ (D·G) ⊙ D with D = W − Ŵ.
pub fn recon_error(w: &Mat, new_w: &Mat, g: &Mat) -> f64 {
    let mut d = w.clone();
    d.axby(1.0, -1.0, new_w);
    let dg = matmul(&d, g);
    dg.data
        .iter()
        .zip(&d.data)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

/// Run `passes` in order over a method's output, updating the mask /
/// reconstructed weights, the realized objective `out.obj`, and
/// `out.refine_obj_delta`.  Reverts everything (delta 0) if the
/// re-evaluated objective ever came out worse — refine never raises
/// the layer objective.
pub fn apply_refine(
    passes: &[RefinePass],
    kernels: &(dyn FwKernels + '_),
    w: &Mat,
    g: &Mat,
    pattern: &SparsityPattern,
    out: &mut LayerPruneOutput,
) -> Result<()> {
    if passes.is_empty() {
        return Ok(());
    }
    // the realized objective going in: reconstruction error when the
    // method already rebuilt weights (SparseGPT), plain L(M) otherwise
    let obj_before = match &out.new_weights {
        Some(nw) => recon_error(w, nw, g),
        None => out.obj,
    };
    let mask_before = out.mask.clone();
    let weights_before = out.new_weights.clone();
    let obj_field_before = out.obj;

    let mut weights_stale = false;
    // damping for a stale-weights rebuild: the user's configured update
    // pass wins over the default
    let mut rebuild_percdamp = DEFAULT_UPDATE_PERCDAMP;
    for pass in passes {
        match pass {
            RefinePass::Swaps { max_swaps } => {
                let accepted = swaps_refine(w, g, pattern, &mut out.mask, *max_swaps);
                if accepted > 0 && out.new_weights.is_some() {
                    weights_stale = true;
                }
            }
            RefinePass::WeightUpdate { percdamp } => {
                out.new_weights = Some(lsq_update(w, g, &out.mask, *percdamp));
                weights_stale = false;
                rebuild_percdamp = *percdamp;
            }
        }
    }
    // a swap after reconstruction invalidates the weights: rebuild them
    // on the final mask so downstream application stays consistent
    if weights_stale {
        out.new_weights = Some(lsq_update(w, g, &out.mask, rebuild_percdamp));
    }

    let obj_after = match &out.new_weights {
        Some(nw) => recon_error(w, nw, g),
        None => kernels.objective(w, &out.mask, g)?,
    };
    if obj_after > obj_before {
        // float noise (or a pathological damped solve) made it worse:
        // keep-best, like SparseFW's own guard
        out.mask = mask_before;
        out.new_weights = weights_before;
        out.obj = obj_field_before;
        out.refine_obj_delta = Some(0.0);
    } else {
        out.obj = obj_after;
        out.refine_obj_delta = Some(obj_before - obj_after);
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Greedy 1-swaps
// ---------------------------------------------------------------------------

/// Δ of pruning the kept coordinate `(r, a)` (z_a: 0 → w_a).
#[inline]
fn prune_delta(w: &Mat, g: &Mat, s: &Mat, r: usize, a: usize) -> f64 {
    let wv = w.at(r, a) as f64;
    2.0 * wv * s.at(r, a) as f64 + wv * wv * g.at(a, a) as f64
}

/// Δ of keeping the pruned coordinate `(r, b)` (z_b: w_b → 0).
#[inline]
fn keep_delta(w: &Mat, g: &Mat, s: &Mat, r: usize, b: usize) -> f64 {
    let wv = w.at(r, b) as f64;
    -2.0 * wv * s.at(r, b) as f64 + wv * wv * g.at(b, b) as f64
}

/// Apply an accepted swap to the mask and the maintained `S = Z·G`
/// state: prune `(r_a, a)`, keep `(r_b, b)`.
fn commit_swap(w: &Mat, g: &Mat, s: &mut Mat, mask: &mut Mat, ra: usize, a: usize, rb: usize, b: usize) {
    *mask.at_mut(ra, a) = 0.0;
    *mask.at_mut(rb, b) = 1.0;
    let wa = w.at(ra, a);
    let wb = w.at(rb, b);
    for j in 0..s.cols {
        *s.at_mut(ra, j) += wa * g.at(a, j);
    }
    for j in 0..s.cols {
        *s.at_mut(rb, j) -= wb * g.at(b, j);
    }
}

/// Greedy best-improving 1-swaps inside one row segment
/// `[lo, hi)` (a whole row, or one n:m block).
fn swap_unit(
    w: &Mat,
    g: &Mat,
    s: &mut Mat,
    mask: &mut Mat,
    r: usize,
    lo: usize,
    hi: usize,
    max_swaps: usize,
) -> usize {
    let mut accepted = 0;
    while accepted < max_swaps {
        let kept: Vec<usize> = (lo..hi).filter(|&j| mask.at(r, j) != 0.0).collect();
        let pruned: Vec<usize> = (lo..hi).filter(|&j| mask.at(r, j) == 0.0).collect();
        if kept.is_empty() || pruned.is_empty() {
            break;
        }
        let keep_deltas: Vec<f64> = pruned.iter().map(|&b| keep_delta(w, g, s, r, b)).collect();
        let mut best: Option<(f64, usize, usize)> = None;
        for &a in &kept {
            let pd = prune_delta(w, g, s, r, a);
            let wa = w.at(r, a) as f64;
            for (bi, &b) in pruned.iter().enumerate() {
                let cross = -2.0 * wa * w.at(r, b) as f64 * g.at(a, b) as f64;
                let delta = pd + keep_deltas[bi] + cross;
                if best.map(|(d, _, _)| delta < d).unwrap_or(true) {
                    best = Some((delta, a, b));
                }
            }
        }
        match best {
            Some((delta, a, b)) if delta < 0.0 => {
                commit_swap(w, g, s, mask, r, a, r, b);
                accepted += 1;
            }
            _ => break,
        }
    }
    accepted
}

/// Greedy 1-swaps under the global (unstructured) budget: the best
/// prune candidate and the best keep candidate may live in different
/// rows (their deltas then just add — L is row-separable).  Top-2
/// candidate lists sidestep the same-row cross-term coupling.
fn swap_global(w: &Mat, g: &Mat, s: &mut Mat, mask: &mut Mat, max_swaps: usize) -> usize {
    let (rows, cols) = (mask.rows, mask.cols);
    let mut accepted = 0;
    while accepted < max_swaps {
        // top-2 (smallest-delta) prune and keep candidates
        let mut prunes: Vec<(f64, usize, usize)> = Vec::new(); // (delta, r, j)
        let mut keeps: Vec<(f64, usize, usize)> = Vec::new();
        for r in 0..rows {
            for j in 0..cols {
                if mask.at(r, j) != 0.0 {
                    push_top2(&mut prunes, (prune_delta(w, g, s, r, j), r, j));
                } else {
                    push_top2(&mut keeps, (keep_delta(w, g, s, r, j), r, j));
                }
            }
        }
        let mut best: Option<(f64, (usize, usize), (usize, usize))> = None;
        for &(pd, ra, a) in &prunes {
            for &(kd, rb, b) in &keeps {
                let cross = if ra == rb {
                    -2.0 * w.at(ra, a) as f64 * w.at(rb, b) as f64 * g.at(a, b) as f64
                } else {
                    0.0
                };
                let delta = pd + kd + cross;
                if best.map(|(d, _, _)| delta < d).unwrap_or(true) {
                    best = Some((delta, (ra, a), (rb, b)));
                }
            }
        }
        match best {
            Some((delta, (ra, a), (rb, b))) if delta < 0.0 => {
                commit_swap(w, g, s, mask, ra, a, rb, b);
                accepted += 1;
            }
            _ => break,
        }
    }
    accepted
}

/// Keep the two smallest-delta entries.
fn push_top2(top: &mut Vec<(f64, usize, usize)>, cand: (f64, usize, usize)) {
    top.push(cand);
    top.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    top.truncate(2);
}

/// Greedy 1-swap refinement of `mask` under `pattern`; returns the
/// number of accepted swaps.  Feasibility and the keep count are
/// invariant (swaps stay inside the pattern's constraint unit).
pub fn swaps_refine(
    w: &Mat,
    g: &Mat,
    pattern: &SparsityPattern,
    mask: &mut Mat,
    max_swaps: usize,
) -> usize {
    let (rows, cols) = (w.rows, w.cols);
    assert_eq!((mask.rows, mask.cols), (rows, cols));
    // maintained S = (W ⊙ (1−M)) · G
    let z = Mat::from_vec(
        rows,
        cols,
        w.data
            .iter()
            .zip(&mask.data)
            .map(|(&wv, &mv)| wv * (1.0 - mv))
            .collect(),
    );
    let mut s = matmul(&z, g);
    let mut accepted = 0;
    match pattern {
        SparsityPattern::PerRow { .. } => {
            for r in 0..rows {
                accepted += swap_unit(w, g, &mut s, mask, r, 0, cols, max_swaps);
            }
        }
        SparsityPattern::NM { block, .. } => {
            for r in 0..rows {
                let mut c = 0;
                while c + block <= cols {
                    accepted += swap_unit(w, g, &mut s, mask, r, c, c + block, max_swaps);
                    c += block;
                }
            }
        }
        SparsityPattern::Unstructured { .. } => {
            // row-local swaps preserve the global count too, and give
            // the same per-row refinement depth as the row-separable
            // patterns at the same cost; cross-row swaps then
            // reallocate budget between rows (capped at `max_swaps`
            // moves — each costs a full candidate scan)
            for r in 0..rows {
                accepted += swap_unit(w, g, &mut s, mask, r, 0, cols, max_swaps);
            }
            accepted += swap_global(w, g, &mut s, mask, max_swaps);
        }
    }
    accepted
}

// ---------------------------------------------------------------------------
// Least-squares masked weight update
// ---------------------------------------------------------------------------

/// Per-row least-squares re-solve of the kept weights against the gram
/// (Boža, 2024): `ŵ_S = (G_SS + λI)⁻¹ G_S,: w_rᵀ`, λ relative to
/// `mean(diag G)`.  Rows solve independently (parallel); a row whose
/// damped gram is not PD falls back to its plainly-masked weights, so
/// the result is never worse than masking.
pub fn lsq_update(w: &Mat, g: &Mat, mask: &Mat, percdamp: f64) -> Mat {
    let din = w.cols;
    let gf = MatF64::from_mat(g);
    let damp = percdamp * gf.mean_diag() + 1e-10;
    // fallback: plainly-masked weights
    let out = Mutex::new(w.hadamard(mask));
    parallel_for(w.rows, |i| {
        let support: Vec<usize> = (0..din).filter(|&j| mask.at(i, j) != 0.0).collect();
        if support.is_empty() {
            return;
        }
        let k = support.len();
        let mut a = MatF64::zeros(k);
        for (p, &jp) in support.iter().enumerate() {
            for (q, &jq) in support.iter().enumerate() {
                *a.at_mut(p, q) = gf.at(jp, jq);
            }
            *a.at_mut(p, p) += damp;
        }
        let b: Vec<f64> = support
            .iter()
            .map(|&jp| {
                (0..din)
                    .map(|j| gf.at(jp, j) * w.at(i, j) as f64)
                    .sum()
            })
            .collect();
        let Some(l) = cholesky(&a) else { return };
        let x = chol_solve(&l, &b);
        let mut guard = out.lock().unwrap();
        for j in 0..din {
            *guard.at_mut(i, j) = 0.0;
        }
        for (p, &jp) in support.iter().enumerate() {
            *guard.at_mut(i, jp) = x[p] as f32;
        }
    });
    out.into_inner().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::mask::mask_satisfies;
    use crate::pruner::saliency::{saliency_mask, wanda_scores};
    use crate::pruner::sparsefw::NativeKernels;
    use crate::tensor::matmul_a_bt;
    use crate::util::json;
    use crate::util::prng::Xoshiro256;

    fn setup(dout: usize, din: usize, b: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(dout, din, 1.0, &mut rng);
        let mut x = Mat::gaussian(din, b, 1.0, &mut rng);
        for i in 0..din {
            if i % 5 == 0 {
                for v in x.row_mut(i) {
                    *v *= 4.0;
                }
            }
        }
        (w, matmul_a_bt(&x, &x))
    }

    fn patterns() -> [SparsityPattern; 3] {
        [
            SparsityPattern::Unstructured { sparsity: 0.6 },
            SparsityPattern::PerRow { sparsity: 0.6 },
            SparsityPattern::NM { keep: 2, block: 4 },
        ]
    }

    #[test]
    fn swaps_lower_objective_and_preserve_feasibility() {
        let (w, g) = setup(12, 24, 96, 1);
        for pattern in patterns() {
            let mask0 = saliency_mask(&wanda_scores(&w, &g), &pattern);
            let obj0 = crate::pruner::fw_math::objective(&w, &mask0, &g);
            let mut mask = mask0.clone();
            let accepted = swaps_refine(&w, &g, &pattern, &mut mask, DEFAULT_MAX_SWAPS);
            let obj1 = crate::pruner::fw_math::objective(&w, &mask, &g);
            assert!(
                obj1 <= obj0 * (1.0 + 1e-6),
                "{pattern:?}: {obj1} !<= {obj0}"
            );
            assert!(mask_satisfies(&mask, &pattern), "{pattern:?}");
            assert_eq!(mask.count_nonzero(), mask0.count_nonzero(), "{pattern:?}");
            // greedy masks on anisotropic activations leave improving
            // swaps on the table — the pass must find them in the
            // large-unit patterns (tiny 2-of-4 blocks may already be
            // optimal, so only non-regression is asserted there)
            if !matches!(pattern, SparsityPattern::NM { .. }) {
                assert!(accepted > 0, "{pattern:?}: no swaps accepted");
            }
        }
    }

    #[test]
    fn lsq_update_beats_plain_masking() {
        let (w, g) = setup(8, 16, 64, 2);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let mask = saliency_mask(&wanda_scores(&w, &g), &pattern);
        let masked_obj = crate::pruner::fw_math::objective(&w, &mask, &g);
        let updated = lsq_update(&w, &g, &mask, DEFAULT_UPDATE_PERCDAMP);
        // zero exactly off-mask
        for (m, v) in mask.data.iter().zip(&updated.data) {
            if *m == 0.0 {
                assert_eq!(*v, 0.0);
            }
        }
        let err = recon_error(&w, &updated, &g);
        assert!(err < masked_obj, "update {err} !< masked {masked_obj}");
    }

    #[test]
    fn apply_refine_reports_nonnegative_delta() {
        let (w, g) = setup(10, 20, 80, 3);
        for pattern in patterns() {
            for passes in [
                vec![RefinePass::swaps()],
                vec![RefinePass::update()],
                vec![RefinePass::swaps(), RefinePass::update()],
            ] {
                let mask = saliency_mask(&wanda_scores(&w, &g), &pattern);
                let mut out =
                    LayerPruneOutput::from_mask(&NativeKernels, &w, &g, mask).unwrap();
                let obj_before = out.obj;
                apply_refine(&passes, &NativeKernels, &w, &g, &pattern, &mut out).unwrap();
                let delta = out.refine_obj_delta.expect("refine ran");
                assert!(delta >= 0.0, "{pattern:?} {passes:?}: delta {delta}");
                assert!(
                    out.obj <= obj_before * (1.0 + 1e-9),
                    "{pattern:?} {passes:?}: {} !<= {obj_before}",
                    out.obj
                );
                assert!(mask_satisfies(&out.mask, &pattern));
            }
        }
    }

    #[test]
    fn swaps_after_reconstruction_rebuild_weights() {
        let (w, g) = setup(8, 16, 64, 4);
        let pattern = SparsityPattern::PerRow { sparsity: 0.5 };
        let r = crate::pruner::sparsegpt::sparsegpt(&w, &g, &pattern, 0.01, 8).unwrap();
        let obj = crate::pruner::fw_math::objective(&w, &r.mask, &g);
        let mut out = LayerPruneOutput {
            mask: r.mask,
            obj,
            warm_obj: None,
            new_weights: Some(r.weights),
            trace: None,
            convergence: None,
            fw_iters: 0,
            refine_obj_delta: None,
        };
        let before = recon_error(&w, out.new_weights.as_ref().unwrap(), &g);
        apply_refine(
            &[RefinePass::swaps()],
            &NativeKernels,
            &w,
            &g,
            &pattern,
            &mut out,
        )
        .unwrap();
        let nw = out.new_weights.as_ref().expect("weights rebuilt");
        // reconstructed weights stay consistent with the (possibly
        // swapped) mask, and the realized error never regresses
        for (m, v) in out.mask.data.iter().zip(&nw.data) {
            if *m == 0.0 {
                assert_eq!(*v, 0.0);
            }
        }
        assert!(recon_error(&w, nw, &g) <= before * (1.0 + 1e-9));
    }

    #[test]
    fn parse_and_json_roundtrip() {
        assert_eq!(RefinePass::parse_list("").unwrap(), vec![]);
        assert_eq!(RefinePass::parse_list("none").unwrap(), vec![]);
        assert_eq!(
            RefinePass::parse_list("swaps,update").unwrap(),
            vec![RefinePass::swaps(), RefinePass::update()]
        );
        assert_eq!(
            RefinePass::parse_list("swaps+update").unwrap(),
            vec![RefinePass::swaps(), RefinePass::update()]
        );
        assert!(RefinePass::parse_list("polish").is_err());
        assert_eq!(
            RefinePass::list_label(&[RefinePass::swaps(), RefinePass::update()]),
            "swaps+update"
        );

        for passes in [
            vec![RefinePass::swaps()],
            vec![RefinePass::Swaps { max_swaps: 7 }],
            vec![RefinePass::WeightUpdate { percdamp: 0.1 }, RefinePass::swaps()],
        ] {
            let j = RefinePass::list_to_json(&passes);
            let text = json::to_string(&j);
            let back = RefinePass::list_from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(passes, back);
        }
        // strict fields inside object-form passes
        let bad = json::parse(r#"[{"kind": "swaps", "max_swap": 3}]"#).unwrap();
        let err = RefinePass::list_from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("max_swap"), "{err}");
    }
}
