//! Small dense linear algebra in f64 — the pieces SparseGPT needs.
//!
//! SparseGPT's greedy step requires `(XXᵀ + λI)⁻¹` (the damped inverse
//! Hessian of the reconstruction objective). At coordinator scale
//! (d_in ≤ 512) a straightforward Cholesky factorization is exact enough
//! and fast enough; we work in f64 for stability, converting from the
//! f32 gram matrices.

use super::Mat;

/// Symmetric positive-definite f64 matrix utilities.
#[derive(Clone)]
pub struct MatF64 {
    pub n: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    pub fn from_mat(m: &Mat) -> Self {
        assert_eq!(m.rows, m.cols);
        Self {
            n: m.rows,
            data: m.data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.n, self.n, self.data.iter().map(|&x| x as f32).collect())
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }

    pub fn add_diag(&mut self, lambda: f64) {
        for i in 0..self.n {
            *self.at_mut(i, i) += lambda;
        }
    }

    pub fn mean_diag(&self) -> f64 {
        (0..self.n).map(|i| self.at(i, i)).sum::<f64>() / self.n.max(1) as f64
    }
}

/// Lower-triangular Cholesky factor L with A = L·Lᵀ.
/// Fails (None) if A is not positive definite.
pub fn cholesky(a: &MatF64) -> Option<MatF64> {
    let n = a.n;
    let mut l = MatF64::zeros(n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j);
            for k in 0..j {
                s -= l.at(i, k) * l.at(j, k);
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = s.sqrt();
            } else {
                *l.at_mut(i, j) = s / l.at(j, j);
            }
        }
    }
    Some(l)
}

/// Solve A·x = b given the Cholesky factor L of A (forward+back substitution).
pub fn chol_solve(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.n;
    assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l.at(i, k) * y[k];
        }
        y[i] = s / l.at(i, i);
    }
    // backward: Lᵀ x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.at(k, i) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    x
}

/// Full inverse via Cholesky (n solves). Used once per layer by
/// SparseGPT, so O(n³) at n ≤ 512 is fine.
pub fn chol_inverse(a: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = a.n;
    let mut inv = MatF64::zeros(n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = chol_solve(&l, &e);
        e[j] = 0.0;
        for i in 0..n {
            *inv.at_mut(i, j) = col[i];
        }
    }
    Some(inv)
}

/// Largest eigenvalue of a symmetric PSD matrix by power iteration —
/// used to evaluate the Lemma 2 bound (λmax(Q)).
pub fn lambda_max(a: &MatF64, iters: usize) -> f64 {
    let n = a.n;
    if n == 0 {
        return 0.0;
    }
    let mut v: Vec<f64> = (0..n).map(|i| 1.0 + (i as f64 * 0.37).sin()).collect();
    let mut lam = 0.0;
    for _ in 0..iters {
        let mut w = vec![0.0; n];
        for i in 0..n {
            let row = &a.data[i * n..(i + 1) * n];
            w[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        lam = norm;
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / norm;
        }
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn random_spd(n: usize, seed: u64) -> MatF64 {
        let mut rng = Xoshiro256::new(seed);
        let x = Mat::gaussian(n, 2 * n, 1.0, &mut rng);
        let g = matmul_a_bt(&x, &x); // X Xᵀ is PSD (a.s. PD for fat X)
        let mut a = MatF64::from_mat(&g);
        a.add_diag(1e-3);
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(16, 5);
        let l = cholesky(&a).unwrap();
        for i in 0..a.n {
            for j in 0..a.n {
                let mut s = 0.0;
                for k in 0..a.n {
                    s += l.at(i, k) * l.at(j, k);
                }
                assert!((s - a.at(i, j)).abs() < 1e-6 * (1.0 + a.at(i, j).abs()));
            }
        }
    }

    #[test]
    fn solve_and_inverse() {
        let a = random_spd(12, 6);
        let l = cholesky(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|i| (i as f64) - 3.0).collect();
        let x = chol_solve(&l, &b);
        // check A x == b
        for i in 0..12 {
            let mut s = 0.0;
            for k in 0..12 {
                s += a.at(i, k) * x[k];
            }
            assert!((s - b[i]).abs() < 1e-8 * (1.0 + b[i].abs()));
        }
        let inv = chol_inverse(&a).unwrap();
        // A · A⁻¹ == I
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0;
                for k in 0..12 {
                    s += a.at(i, k) * inv.at(k, j);
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-7, "({i},{j}) -> {s}");
            }
        }
    }

    #[test]
    fn not_pd_fails() {
        let mut a = MatF64::zeros(3);
        *a.at_mut(0, 0) = -1.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn lambda_max_diagonal() {
        let mut a = MatF64::zeros(4);
        for (i, v) in [1.0, 5.0, 3.0, 2.0].into_iter().enumerate() {
            *a.at_mut(i, i) = v;
        }
        let lam = lambda_max(&a, 100);
        assert!((lam - 5.0).abs() < 1e-6);
    }
}
