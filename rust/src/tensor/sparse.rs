//! CSR sparse matrices — the deployment payoff of pruning.
//!
//! A pruned linear layer `y = x·Wᵀ` with mask sparsity s touches only
//! (1−s)·numel weights; this module materializes masked weights as CSR
//! and provides the sparse counterpart of the dense `matmul_a_bt` used
//! by the model forward.  `benches/gram.rs`/`fw_hot_loop.rs` quantify
//! the dense→sparse speedup at the paper's sparsity levels; the
//! `semi_structured` example shows n:m masks keeping perfectly balanced
//! rows (the hardware-friendliness argument for 2:4).

use super::Mat;
use crate::util::pool::{chunk_ranges, default_workers};

/// Compressed sparse row f32 matrix.
#[derive(Clone, Debug)]
pub struct CsrMat {
    pub rows: usize,
    pub cols: usize,
    /// row_ptr[i]..row_ptr[i+1] indexes into (col_idx, values) for row i.
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMat {
    /// Compress the nonzero pattern of `dense` (typically `W ⊙ M`).
    pub fn from_dense(dense: &Mat) -> Self {
        let mut row_ptr = Vec::with_capacity(dense.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..dense.rows {
            for (j, &v) in dense.row(i).iter().enumerate() {
                if v != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Self { rows: dense.rows, cols: dense.cols, row_ptr, col_idx, values }
    }

    /// Masked-weight constructor: CSR of `w ⊙ mask` (the deployment
    /// artifact of a pruning run).  Compresses by *mask membership*,
    /// not by value: a kept weight whose reconstructed value is exactly
    /// 0.0 (SparseGPT's `update` can produce these) stays addressable
    /// so the stored pattern is the mask, bit for bit.
    pub fn from_masked(w: &Mat, mask: &Mat) -> Self {
        assert_eq!((w.rows, w.cols), (mask.rows, mask.cols));
        let mut row_ptr = Vec::with_capacity(w.rows + 1);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        row_ptr.push(0u32);
        for i in 0..w.rows {
            for (j, (&m, &v)) in mask.row(i).iter().zip(w.row(i)).enumerate() {
                if m != 0.0 {
                    col_idx.push(j as u32);
                    values.push(v);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Self { rows: w.rows, cols: w.cols, row_ptr, col_idx, values }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            for t in s..e {
                out.data[i * self.cols + self.col_idx[t] as usize] = self.values[t];
            }
        }
        out
    }

    /// y = W·x for a single input vector (x length = cols).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.rows];
        self.matvec_into(x, &mut y, false);
        y
    }

    /// Zero-alloc twin of [`CsrMat::matvec`]: y = W·x, or y += W·x when
    /// `accumulate` (the residual fold-in of the batch=1 decode step).
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32], accumulate: bool) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let mut acc = 0.0f32;
            for t in s..e {
                acc += self.values[t] * x[self.col_idx[t] as usize];
            }
            if accumulate {
                y[i] += acc;
            } else {
                y[i] = acc;
            }
        }
    }

    /// C = A·Wᵀ with A (n × cols) dense — the sparse counterpart of
    /// `matmul_a_bt(a, w)` used by the linear layers.  Parallel over
    /// rows of A.
    pub fn matmul_a_bt(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, self.rows);
        self.matmul_a_bt_into(a, &mut c, false);
        c
    }

    /// Fused, zero-alloc C = A·Wᵀ (or C += A·Wᵀ when `accumulate`, the
    /// residual fold-in of the transformer block).  Parallel over row
    /// blocks of A via the same striping as the dense matmul; `c` must
    /// be pre-shaped (a.rows × self.rows).
    pub fn matmul_a_bt_into(&self, a: &Mat, c: &mut Mat, accumulate: bool) {
        assert_eq!(a.cols, self.cols, "sparse matmul_a_bt: inner dims");
        assert_eq!((c.rows, c.cols), (a.rows, self.rows), "sparse matmul_a_bt: out shape");
        let (n, m) = (a.rows, self.rows);
        let workers = default_workers(n);
        let ranges = chunk_ranges(n, workers);
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = &mut c.data;
            for r in &ranges {
                let (stripe, tail) = rest.split_at_mut(r.len() * m);
                rest = tail;
                let r = r.clone();
                s.spawn(move || {
                    for (li, ai) in r.clone().enumerate() {
                        let arow = a.row(ai);
                        let crow = &mut stripe[li * m..(li + 1) * m];
                        for i in 0..m {
                            let (st, e) =
                                (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
                            let mut acc = 0.0f32;
                            for t in st..e {
                                acc += self.values[t] * arow[self.col_idx[t] as usize];
                            }
                            if accumulate {
                                crow[i] += acc;
                            } else {
                                crow[i] = acc;
                            }
                        }
                    }
                });
            }
        });
    }

    /// Bytes of the CSR representation (deployment-size accounting).
    pub fn size_bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.values.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    fn sparse_random(rows: usize, cols: usize, density: f64, seed: u64) -> Mat {
        let mut rng = Xoshiro256::new(seed);
        Mat::from_fn(rows, cols, |_, _| {
            if rng.next_f64() < density {
                rng.next_gaussian() as f32
            } else {
                0.0
            }
        })
    }

    #[test]
    fn roundtrip_dense() {
        let d = sparse_random(17, 23, 0.4, 1);
        let csr = CsrMat::from_dense(&d);
        assert_eq!(csr.to_dense().data, d.data);
        assert_eq!(csr.nnz(), d.count_nonzero());
        assert!((csr.density() - 0.4).abs() < 0.15);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Xoshiro256::new(2);
        let d = sparse_random(12, 20, 0.3, 3);
        let csr = CsrMat::from_dense(&d);
        let x: Vec<f32> = (0..20).map(|_| rng.next_f32()).collect();
        let y = csr.matvec(&x);
        for i in 0..12 {
            let want: f32 = d.row(i).iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((y[i] - want).abs() < 1e-4, "row {i}");
        }
    }

    #[test]
    fn sparse_matmul_matches_dense() {
        let mut rng = Xoshiro256::new(4);
        let w = sparse_random(24, 32, 0.4, 5);
        let a = Mat::gaussian(10, 32, 1.0, &mut rng);
        let csr = CsrMat::from_dense(&w);
        let got = csr.matmul_a_bt(&a);
        let want = matmul_a_bt(&a, &w);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn from_masked_zeroes_off_mask() {
        let mut rng = Xoshiro256::new(6);
        let w = Mat::gaussian(8, 8, 1.0, &mut rng);
        let mask = Mat::from_fn(8, 8, |i, j| f32::from((i + j) % 2 == 0));
        let csr = CsrMat::from_masked(&w, &mask);
        assert_eq!(csr.nnz(), 32);
        let back = csr.to_dense();
        for i in 0..8 {
            for j in 0..8 {
                let want = if (i + j) % 2 == 0 { w.at(i, j) } else { 0.0 };
                assert_eq!(back.at(i, j), want);
            }
        }
    }

    #[test]
    fn from_masked_keeps_explicit_zeros() {
        // A reconstructed weight can be exactly 0.0 on a kept position;
        // the CSR pattern must still be the mask, not the value support.
        let mut w = Mat::ones(4, 4);
        *w.at_mut(1, 2) = 0.0; // kept by mask, value exactly zero
        *w.at_mut(3, 3) = 0.0; // pruned anyway
        let mask = Mat::from_fn(4, 4, |i, j| f32::from((i + j) % 2 == 0));
        let csr = CsrMat::from_masked(&w, &mask);
        assert_eq!(csr.nnz(), 8, "pattern follows the mask, incl. the kept zero");
        let row1: Vec<u32> =
            csr.col_idx[csr.row_ptr[1] as usize..csr.row_ptr[2] as usize].to_vec();
        assert!(row1.contains(&2), "kept zero at (1,2) stays addressable");
        assert_eq!(csr.to_dense().data, w.hadamard(&mask).data);
    }

    #[test]
    fn into_twins_match_and_accumulate() {
        let mut rng = Xoshiro256::new(8);
        let w = sparse_random(16, 24, 0.3, 9);
        let csr = CsrMat::from_dense(&w);
        let a = Mat::gaussian(7, 24, 1.0, &mut rng);

        let mut c = Mat::gaussian(7, 16, 1.0, &mut rng);
        let resid = c.clone();
        csr.matmul_a_bt_into(&a, &mut c, true);
        let mut want = csr.matmul_a_bt(&a);
        want.add_inplace(&resid);
        assert!(c.max_abs_diff(&want) < 1e-5);

        let x: Vec<f32> = (0..24).map(|_| rng.next_f32()).collect();
        let mut y = vec![1.0f32; 16];
        csr.matvec_into(&x, &mut y, true);
        let base = csr.matvec(&x);
        for i in 0..16 {
            assert!((y[i] - (base[i] + 1.0)).abs() < 1e-5, "row {i}");
        }
    }

    #[test]
    fn empty_and_full() {
        let z = CsrMat::from_dense(&Mat::zeros(4, 4));
        assert_eq!(z.nnz(), 0);
        assert_eq!(z.matvec(&[1.0; 4]), vec![0.0; 4]);
        let f = CsrMat::from_dense(&Mat::ones(3, 3));
        assert_eq!(f.nnz(), 9);
        assert_eq!(f.matvec(&[1.0, 2.0, 3.0]), vec![6.0; 3]);
    }

    #[test]
    fn size_accounting() {
        let d = sparse_random(100, 100, 0.4, 7);
        let csr = CsrMat::from_dense(&d);
        // at 60% sparsity CSR must be smaller than dense f32
        assert!(csr.size_bytes() < 100 * 100 * 4);
    }
}
