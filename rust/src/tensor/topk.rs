//! Top-k selection utilities — the heart of both the LMO (select the k
//! most-negative gradient entries, paper Eq. 12) and the thresholding
//! step (keep the k largest mask entries, Algorithm 1 line 7).
//!
//! Built on `select_nth_unstable` (expected O(n)); ties are broken by
//! index so results are deterministic.

/// Indices of the `k` smallest values (ascending ties broken by index).
pub fn bottom_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let mut buf = Vec::new();
    let n = bottom_k_into(values, k, &mut buf);
    buf[..n].iter().map(|&i| i as usize).collect()
}

/// Allocation-free twin of [`bottom_k_indices`] (which delegates here,
/// so the two can never disagree — the FW engines' exact-equivalence
/// rests on one shared comparator): reuses `buf` across calls (the FW
/// hot loop runs this every iteration) and leaves the selected
/// indices — unordered — in `buf[..returned]`.
pub fn bottom_k_into(values: &[f32], k: usize, buf: &mut Vec<u32>) -> usize {
    let k = k.min(values.len());
    buf.clear();
    if k == 0 {
        return 0;
    }
    buf.extend(0..values.len() as u32);
    if k < buf.len() {
        let cmp = |&a: &u32, &b: &u32| {
            let (va, vb) = (values[a as usize], values[b as usize]);
            va.partial_cmp(&vb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        };
        buf.select_nth_unstable_by(k - 1, cmp);
        buf.truncate(k);
    }
    k
}

/// Indices of the `k` largest values (ties broken by index).
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    let cmp = |&a: &u32, &b: &u32| {
        let (va, vb) = (values[a as usize], values[b as usize]);
        vb.partial_cmp(&va).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.into_iter().map(|i| i as usize).collect()
}

/// Binary vector with ones at the `k` largest entries of `values`.
pub fn top_k_mask(values: &[f32], k: usize) -> Vec<f32> {
    let mut mask = vec![0.0f32; values.len()];
    for i in top_k_indices(values, k) {
        mask[i] = 1.0;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_and_bottom() {
        let v = [3.0f32, -1.0, 4.0, -1.5, 0.0];
        assert_eq!(sorted(top_k_indices(&v, 2)), vec![0, 2]);
        assert_eq!(sorted(bottom_k_indices(&v, 2)), vec![1, 3]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(sorted(top_k_indices(&v, 99)), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn deterministic_tie_break() {
        let v = [1.0f32; 6];
        assert_eq!(sorted(top_k_indices(&v, 3)), vec![0, 1, 2]);
        assert_eq!(sorted(bottom_k_indices(&v, 3)), vec![0, 1, 2]);
    }

    #[test]
    fn mask_has_k_ones() {
        let v: Vec<f32> = (0..100).map(|i| ((i * 37) % 100) as f32).collect();
        let m = top_k_mask(&v, 30);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 30);
        // the selected ones must all be >= the largest unselected value
        let sel_min = v
            .iter()
            .zip(&m)
            .filter(|(_, &mk)| mk == 1.0)
            .map(|(&x, _)| x)
            .fold(f32::MAX, f32::min);
        let unsel_max = v
            .iter()
            .zip(&m)
            .filter(|(_, &mk)| mk == 0.0)
            .map(|(&x, _)| x)
            .fold(f32::MIN, f32::max);
        assert!(sel_min >= unsel_max);
    }

    fn sorted(mut v: Vec<usize>) -> Vec<usize> {
        v.sort_unstable();
        v
    }

    #[test]
    fn bottom_k_into_matches_allocating_variant() {
        let v: Vec<f32> = (0..200).map(|i| (((i * 53) % 97) as f32) - 48.0).collect();
        let mut buf = Vec::new();
        for k in [0usize, 1, 7, 50, 200, 500] {
            let n = bottom_k_into(&v, k, &mut buf);
            let mut got: Vec<usize> = buf[..n].iter().map(|&i| i as usize).collect();
            got.sort_unstable();
            assert_eq!(got, sorted(bottom_k_indices(&v, k)), "k={k}");
        }
    }
}
