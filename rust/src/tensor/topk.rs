//! Top-k selection utilities — the heart of both the LMO (select the k
//! most-negative gradient entries, paper Eq. 12) and the thresholding
//! step (keep the k largest mask entries, Algorithm 1 line 7).
//!
//! Built on `select_nth_unstable` (expected O(n)); ties are broken by
//! index so results are deterministic.

/// Indices of the `k` smallest values (ascending ties broken by index).
pub fn bottom_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    let cmp = |&a: &u32, &b: &u32| {
        let (va, vb) = (values[a as usize], values[b as usize]);
        va.partial_cmp(&vb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.into_iter().map(|i| i as usize).collect()
}

/// Indices of the `k` largest values (ties broken by index).
pub fn top_k_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<u32> = (0..values.len() as u32).collect();
    let cmp = |&a: &u32, &b: &u32| {
        let (va, vb) = (values[a as usize], values[b as usize]);
        vb.partial_cmp(&va).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    };
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.into_iter().map(|i| i as usize).collect()
}

/// Binary vector with ones at the `k` largest entries of `values`.
pub fn top_k_mask(values: &[f32], k: usize) -> Vec<f32> {
    let mut mask = vec![0.0f32; values.len()];
    for i in top_k_indices(values, k) {
        mask[i] = 1.0;
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_and_bottom() {
        let v = [3.0f32, -1.0, 4.0, -1.5, 0.0];
        assert_eq!(sorted(top_k_indices(&v, 2)), vec![0, 2]);
        assert_eq!(sorted(bottom_k_indices(&v, 2)), vec![1, 3]);
        assert_eq!(top_k_indices(&v, 0), Vec::<usize>::new());
        assert_eq!(sorted(top_k_indices(&v, 99)), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn deterministic_tie_break() {
        let v = [1.0f32; 6];
        assert_eq!(sorted(top_k_indices(&v, 3)), vec![0, 1, 2]);
        assert_eq!(sorted(bottom_k_indices(&v, 3)), vec![0, 1, 2]);
    }

    #[test]
    fn mask_has_k_ones() {
        let v: Vec<f32> = (0..100).map(|i| ((i * 37) % 100) as f32).collect();
        let m = top_k_mask(&v, 30);
        assert_eq!(m.iter().filter(|&&x| x == 1.0).count(), 30);
        // the selected ones must all be >= the largest unselected value
        let sel_min = v
            .iter()
            .zip(&m)
            .filter(|(_, &mk)| mk == 1.0)
            .map(|(&x, _)| x)
            .fold(f32::MAX, f32::min);
        let unsel_max = v
            .iter()
            .zip(&m)
            .filter(|(_, &mk)| mk == 0.0)
            .map(|(&x, _)| x)
            .fold(f32::MIN, f32::max);
        assert!(sel_min >= unsel_max);
    }

    fn sorted(mut v: Vec<usize>) -> Vec<usize> {
        v.sort_unstable();
        v
    }
}
