//! Sparse row-gather matmul kernels — the flop diet behind the
//! incremental FW engine (`pruner::fw_engine`).
//!
//! One FW step mixes a k-sparse binary vertex V into the mask, so the
//! maintained product `P = (W⊙M)·G` only needs the *new* term
//! `(W⊙V)·G`: for every nonzero (i,j) of V, gather row j of G scaled by
//! W[i,j] into row i of the output — O(nnz(V)·d_in) instead of the
//! dense O(d_out·d_in²).  [`masked_matmul_into`] is the exact-recompute
//! twin used for state initialization and the periodic drift refresh;
//! both accumulate rows in ascending column order, matching the panel
//! order of the dense [`super::matmul`] per output row.

use super::Mat;

/// `out = (W⊙V)·G` for a binary vertex V given as sorted flat indices
/// (`i·cols + j`) into the `rows×cols` block `w`.  `g` is the
/// `cols×cols` gram; `out` must hold `rows·cols` elements and is
/// overwritten.  O(nnz·cols).
pub fn vertex_matmul_into(w: &[f32], rows: usize, cols: usize, idx: &[u32], g: &Mat, out: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!((g.rows, g.cols), (cols, cols));
    debug_assert_eq!(out.len(), rows * cols);
    out.fill(0.0);
    for &flat in idx {
        let flat = flat as usize;
        debug_assert!(flat < rows * cols);
        let coeff = w[flat];
        if coeff == 0.0 {
            continue;
        }
        let (i, j) = (flat / cols, flat % cols);
        let grow = g.row(j);
        let orow = &mut out[i * cols..(i + 1) * cols];
        for (o, &gv) in orow.iter_mut().zip(grow) {
            *o += coeff * gv;
        }
    }
}

/// `out = (W⊙M)·G` over a `rows×cols` block, skipping M's zeros —
/// O(nnz(M)·cols).  Used to initialize the maintained FW state and for
/// the periodic exact refresh that bounds f32 drift.
pub fn masked_matmul_into(w: &[f32], m: &[f32], rows: usize, cols: usize, g: &Mat, out: &mut [f32]) {
    debug_assert_eq!(w.len(), rows * cols);
    debug_assert_eq!(m.len(), rows * cols);
    debug_assert_eq!((g.rows, g.cols), (cols, cols));
    debug_assert_eq!(out.len(), rows * cols);
    out.fill(0.0);
    for i in 0..rows {
        let base = i * cols;
        for j in 0..cols {
            let mv = m[base + j];
            if mv == 0.0 {
                continue;
            }
            let coeff = w[base + j] * mv;
            if coeff == 0.0 {
                continue;
            }
            let grow = g.row(j);
            // split the mutable row borrow out per (i,j) term
            let orow = &mut out[base..base + cols];
            for (o, &gv) in orow.iter_mut().zip(grow) {
                *o += coeff * gv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_a_bt};
    use crate::util::prng::Xoshiro256;

    fn setup(rows: usize, cols: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Xoshiro256::new(seed);
        let w = Mat::gaussian(rows, cols, 1.0, &mut rng);
        let x = Mat::gaussian(cols, 64, 1.0, &mut rng);
        (w, matmul_a_bt(&x, &x))
    }

    #[test]
    fn vertex_matmul_matches_dense() {
        let (w, g) = setup(9, 16, 1);
        let mut rng = Xoshiro256::new(2);
        // random sparse binary vertex
        let v = Mat::from_fn(9, 16, |_, _| f32::from(rng.next_f64() < 0.15));
        let idx: Vec<u32> = v
            .data
            .iter()
            .enumerate()
            .filter(|(_, &x)| x != 0.0)
            .map(|(i, _)| i as u32)
            .collect();
        let mut out = vec![0.0f32; 9 * 16];
        vertex_matmul_into(&w.data, 9, 16, &idx, &g, &mut out);
        let want = matmul(&w.hadamard(&v), &g);
        for (a, b) in out.iter().zip(&want.data) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn vertex_matmul_empty_vertex_is_zero() {
        let (w, g) = setup(4, 8, 3);
        let mut out = vec![1.0f32; 32]; // pre-polluted: must be overwritten
        vertex_matmul_into(&w.data, 4, 8, &[], &g, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn masked_matmul_matches_dense() {
        let (w, g) = setup(11, 12, 4);
        let mut rng = Xoshiro256::new(5);
        // fractional mask with plenty of exact zeros (the FW iterate shape)
        let m = Mat::from_fn(11, 12, |_, _| {
            if rng.next_f64() < 0.4 {
                0.0
            } else {
                rng.next_f32()
            }
        });
        let mut out = vec![0.0f32; 11 * 12];
        masked_matmul_into(&w.data, &m.data, 11, 12, &g, &mut out);
        let want = matmul(&w.hadamard(&m), &g);
        for (a, b) in out.iter().zip(&want.data) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn masked_matmul_zero_mask() {
        let (w, g) = setup(3, 4, 6);
        let mut out = vec![7.0f32; 12];
        masked_matmul_into(&w.data, &[0.0; 12], 3, 4, &g, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
