//! Blocked, multi-threaded f32 matmul kernels.
//!
//! Three contraction layouts cover every hot path in the coordinator
//! without materializing transposes:
//!
//! * [`matmul`]      — C = A·B        (native FW gradient `(W⊙M)·G`)
//! * [`matmul_a_bt`] — C = A·Bᵀ       (linear layers `x·Wᵀ`, gram `X·Xᵀ`)
//! * [`matmul_at_b`] — C = Aᵀ·B       (backprop-style contractions)
//!
//! Strategy: parallelize over row-blocks of C (one thread owns a
//! contiguous output stripe — no write sharing), micro-kernel is an
//! `ikj` loop over a `MC×KC` panel of A against cache-resident rows of
//! B, letting LLVM auto-vectorize the inner `axpy`.  Current throughput
//! on the build machine is tracked by `benches/fw_hot_loop.rs` and
//! recorded in `BENCH_fw.json` by `scripts/ci.sh` — the FW gradient no
//! longer leans on this kernel per-iteration at all when the
//! incremental engine (`pruner::fw_engine`) is selected; it remains the
//! substrate for H/gram precomputation and the dense A/B engine.

use super::Mat;
use crate::util::pool::{chunk_ranges, default_workers};

/// Panel height along the reduction dimension (fits L1/L2 comfortably).
const KC: usize = 256;

/// C = A·B, with A (m×k), B (k×n).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let workers = default_workers(m);
    let ranges = chunk_ranges(m, workers);

    std::thread::scope(|s| {
        // Split C into disjoint row stripes; each thread writes its own.
        let mut c_rest: &mut [f32] = &mut c.data;
        for r in &ranges {
            let (stripe, rest) = c_rest.split_at_mut(r.len() * n);
            c_rest = rest;
            let r = r.clone();
            s.spawn(move || {
                for k0 in (0..k).step_by(KC) {
                    let kend = (k0 + KC).min(k);
                    for (li, i) in r.clone().enumerate() {
                        let arow = &a.data[i * k..(i + 1) * k];
                        let crow = &mut stripe[li * n..(li + 1) * n];
                        for kk in k0..kend {
                            let aik = arow[kk];
                            if aik == 0.0 {
                                continue;
                            }
                            let brow = &b.data[kk * n..(kk + 1) * n];
                            for (cv, bv) in crow.iter_mut().zip(brow) {
                                *cv += aik * bv;
                            }
                        }
                    }
                }
            });
        }
    });
    c
}

/// C = A·Bᵀ, with A (m×k), B (n×k).  Inner loop is a dot product of two
/// contiguous rows — the layout used by linear layers (`x·Wᵀ`) and gram
/// accumulation (`X·Xᵀ`).
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_a_bt: inner dims");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Mat::zeros(m, n);
    let workers = default_workers(m);
    let ranges = chunk_ranges(m, workers);

    std::thread::scope(|s| {
        let mut c_rest: &mut [f32] = &mut c.data;
        for r in &ranges {
            let (stripe, rest) = c_rest.split_at_mut(r.len() * n);
            c_rest = rest;
            let r = r.clone();
            s.spawn(move || {
                for (li, i) in r.clone().enumerate() {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut stripe[li * n..(li + 1) * n];
                    for j in 0..n {
                        let brow = &b.data[j * k..(j + 1) * k];
                        crow[j] = dot(arow, brow);
                    }
                }
            });
        }
    });
    c
}

/// C = Aᵀ·B, with A (k×m), B (k×n).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_at_b: inner dims");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let workers = default_workers(m);
    let ranges = chunk_ranges(m, workers);

    std::thread::scope(|s| {
        let mut c_rest: &mut [f32] = &mut c.data;
        for r in &ranges {
            let (stripe, rest) = c_rest.split_at_mut(r.len() * n);
            c_rest = rest;
            let r = r.clone();
            s.spawn(move || {
                for kk in 0..k {
                    let arow = &a.data[kk * m..(kk + 1) * m];
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (li, i) in r.clone().enumerate() {
                        let aik = arow[i];
                        if aik == 0.0 {
                            continue;
                        }
                        let crow = &mut stripe[li * n..(li + 1) * n];
                        for (cv, bv) in crow.iter_mut().zip(brow) {
                            *cv += aik * bv;
                        }
                    }
                }
            });
        }
    });
    c
}

/// Unrolled dot product (8-wide accumulators help LLVM vectorize).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for c in 0..chunks {
        let i = c * 8;
        for l in 0..8 {
            acc[l] += a[i + l] * b[i + l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for kk in 0..a.cols {
                    s += a.at(i, kk) as f64 * b.at(kk, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Xoshiro256::new(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 300, 31)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            let r = naive(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "({m},{k},{n})");
        }
    }

    #[test]
    fn a_bt_matches() {
        let mut rng = Xoshiro256::new(2);
        for (m, k, n) in [(4, 7, 4), (31, 64, 15), (128, 256, 65)] {
            let a = Mat::gaussian(m, k, 1.0, &mut rng);
            let b = Mat::gaussian(n, k, 1.0, &mut rng);
            let c = matmul_a_bt(&a, &b);
            let r = naive(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3);
        }
    }

    #[test]
    fn at_b_matches() {
        let mut rng = Xoshiro256::new(3);
        for (k, m, n) in [(5, 3, 4), (64, 31, 15)] {
            let a = Mat::gaussian(k, m, 1.0, &mut rng);
            let b = Mat::gaussian(k, n, 1.0, &mut rng);
            let c = matmul_at_b(&a, &b);
            let r = naive(&a.transpose(), &b);
            assert!(c.max_abs_diff(&r) < 1e-3);
        }
    }

    #[test]
    fn dot_matches_scalar() {
        let mut rng = Xoshiro256::new(4);
        for n in [0usize, 1, 7, 8, 9, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - want).abs() < 1e-4 * (n.max(1) as f32));
        }
    }
}
