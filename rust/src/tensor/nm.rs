//! Packed interleaved n:m sparse matrices — the semi-structured lane.
//!
//! An n:m mask keeps at most `n` weights in every aligned group of `m`
//! columns, so every row stores *exactly* `groups × n` entries: rows
//! are perfectly balanced, work partitions statically (no `row_ptr`
//! indirection, no load-balance heuristics), and column positions
//! compress to a 4-bit in-group offset (`m ≤ 16`).  This is the
//! software analogue of the hardware 2:4 layout SparseSwaps targets:
//! one f32 value plus half a byte of index per kept weight, vs CSR's
//! f32 + u32.
//!
//! Groups with fewer than `n` survivors are padded with explicit 0.0
//! values at distinct unkept offsets, keeping the balance invariant;
//! groups with *more* than `n` survivors violate n:m and
//! [`NmMat::from_masked`] rejects them.

use anyhow::{bail, ensure, Result};

use super::Mat;
use crate::util::pool::chunk_ranges;

/// Packed n:m ("keep:block") f32 matrix.
#[derive(Clone, Debug)]
pub struct NmMat {
    pub rows: usize,
    pub cols: usize,
    /// Kept weights per group (the `n` of n:m).
    pub keep: usize,
    /// Group width in columns (the `m` of n:m); ≤ 16 so offsets pack
    /// into nibbles.
    pub block: usize,
    /// rows × (cols/block) × keep values, row-major then group-major.
    pub values: Vec<f32>,
    /// One 4-bit in-group column offset per value, two per byte
    /// (low nibble = even entry index).
    pub offsets: Vec<u8>,
}

impl NmMat {
    /// Entries stored per row: (cols/block) · keep, identical for every
    /// row — the balance property that makes static partitioning exact.
    #[inline]
    pub fn entries_per_row(&self) -> usize {
        (self.cols / self.block) * self.keep
    }

    #[inline]
    fn offset_at(&self, e: usize) -> usize {
        let b = self.offsets[e >> 1];
        (if e & 1 == 0 { b & 0x0F } else { b >> 4 }) as usize
    }

    /// Pack `w ⊙ mask` under the n:m invariant.  Like
    /// [`super::sparse::CsrMat::from_masked`] this compresses by mask
    /// membership (kept zeros stay addressable).  Errors when any
    /// aligned `block`-group keeps more than `keep` entries, when
    /// `block` doesn't divide `cols`, or when `block > 16`.
    pub fn from_masked(w: &Mat, mask: &Mat, keep: usize, block: usize) -> Result<Self> {
        ensure!(
            (w.rows, w.cols) == (mask.rows, mask.cols),
            "nm from_masked: shape mismatch {}x{} vs {}x{}",
            w.rows,
            w.cols,
            mask.rows,
            mask.cols
        );
        ensure!(block >= 2 && block <= 16, "nm block must be in 2..=16, got {block}");
        ensure!(keep >= 1 && keep < block, "nm keep must be in 1..block, got {keep}:{block}");
        ensure!(
            w.cols % block == 0,
            "nm block {} does not divide cols {}",
            block,
            w.cols
        );
        let groups = w.cols / block;
        let entries = w.rows * groups * keep;
        let mut values = Vec::with_capacity(entries);
        let mut offsets = vec![0u8; (entries + 1) / 2];
        let mut push = |e: usize, off: usize, values: &mut Vec<f32>, v: f32| {
            values.push(v);
            let nib = (off as u8) & 0x0F;
            if e & 1 == 0 {
                offsets[e >> 1] |= nib;
            } else {
                offsets[e >> 1] |= nib << 4;
            }
        };
        let mut e = 0usize;
        for i in 0..w.rows {
            let wrow = w.row(i);
            let mrow = mask.row(i);
            for g in 0..groups {
                let base = g * block;
                let mut taken = 0usize;
                for off in 0..block {
                    if mrow[base + off] != 0.0 {
                        if taken == keep {
                            bail!(
                                "mask violates {keep}:{block} at row {i}, group {g}: \
                                 more than {keep} kept entries"
                            );
                        }
                        push(e, off, &mut values, wrow[base + off]);
                        taken += 1;
                        e += 1;
                    }
                }
                // pad underfull groups with explicit zeros at distinct
                // unkept offsets so every row stores exactly the same
                // entry count
                let mut off = 0usize;
                while taken < keep {
                    while mrow[base + off] != 0.0 {
                        off += 1;
                    }
                    push(e, off, &mut values, 0.0);
                    taken += 1;
                    e += 1;
                    off += 1;
                }
            }
        }
        Ok(Self { rows: w.rows, cols: w.cols, keep, block, values, offsets })
    }

    /// Detect an n:m structure in `mask`: the smallest-density
    /// `(keep, block)` over block ∈ {4, 8, 16} whose aligned groups
    /// never exceed `keep` survivors and whose packed density does not
    /// exceed `max_density`.  Returns `None` for masks that are not
    /// (near-)balanced — those belong in CSR.
    pub fn detect(mask: &Mat, max_density: f64) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize, f64)> = None;
        for block in [4usize, 8, 16] {
            if mask.cols % block != 0 || mask.cols == 0 {
                continue;
            }
            let groups = mask.cols / block;
            let mut max_keep = 0usize;
            for i in 0..mask.rows {
                let row = mask.row(i);
                for g in 0..groups {
                    let k = row[g * block..(g + 1) * block]
                        .iter()
                        .filter(|&&m| m != 0.0)
                        .count();
                    max_keep = max_keep.max(k);
                }
            }
            if max_keep == 0 || max_keep >= block {
                continue;
            }
            let packed = max_keep as f64 / block as f64;
            if packed <= max_density && best.map_or(true, |(_, _, d)| packed < d) {
                best = Some((max_keep, block, packed));
            }
        }
        best.map(|(k, b, _)| (k, b))
    }

    /// Stored entries (incl. balance padding).
    pub fn stored(&self) -> usize {
        self.values.len()
    }

    /// Nonzero stored values (excludes padding and kept zeros).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Stored density keep/block — the compute cost per output, padding
    /// included.
    pub fn density(&self) -> f64 {
        self.keep as f64 / self.block as f64
    }

    /// Bytes of the packed representation: 4 per value + half a byte
    /// per offset.
    pub fn size_bytes(&self) -> usize {
        self.values.len() * 4 + self.offsets.len()
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let groups = self.cols / self.block;
        let mut e = 0usize;
        for i in 0..self.rows {
            for g in 0..groups {
                for _ in 0..self.keep {
                    let j = g * self.block + self.offset_at(e);
                    out.data[i * self.cols + j] += self.values[e];
                    e += 1;
                }
            }
        }
        out
    }

    /// y = W·x (or y += W·x when `accumulate`) for one input vector —
    /// the batch=1 decode kernel.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32], accumulate: bool) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let groups = self.cols / self.block;
        let per_row = self.entries_per_row();
        for i in 0..self.rows {
            let mut e = i * per_row;
            let mut acc = 0.0f32;
            for g in 0..groups {
                let base = g * self.block;
                for _ in 0..self.keep {
                    acc += self.values[e] * x[base + self.offset_at(e)];
                    e += 1;
                }
            }
            if accumulate {
                y[i] += acc;
            } else {
                y[i] = acc;
            }
        }
    }

    /// C = A·Wᵀ (or C += A·Wᵀ when `accumulate`) with A (n × cols)
    /// dense.  Rows of A partition *statically* across workers — every
    /// W row costs exactly `entries_per_row` MACs, so equal chunks are
    /// equal work by construction.
    pub fn matmul_a_bt_into(&self, a: &Mat, c: &mut Mat, accumulate: bool) {
        assert_eq!(a.cols, self.cols, "nm matmul_a_bt: inner dims");
        assert_eq!((c.rows, c.cols), (a.rows, self.rows), "nm matmul_a_bt: out shape");
        let (n, m) = (a.rows, self.rows);
        let workers = crate::util::pool::default_workers(n);
        let ranges = chunk_ranges(n, workers);
        let groups = self.cols / self.block;
        let per_row = self.entries_per_row();
        std::thread::scope(|s| {
            let mut rest: &mut [f32] = &mut c.data;
            for r in &ranges {
                let (stripe, tail) = rest.split_at_mut(r.len() * m);
                rest = tail;
                let r = r.clone();
                s.spawn(move || {
                    for (li, ai) in r.clone().enumerate() {
                        let arow = a.row(ai);
                        let crow = &mut stripe[li * m..(li + 1) * m];
                        let mut e = 0usize;
                        for i in 0..m {
                            let mut acc = 0.0f32;
                            for g in 0..groups {
                                let base = g * self.block;
                                for _ in 0..self.keep {
                                    acc += self.values[e] * arow[base + self.offset_at(e)];
                                    e += 1;
                                }
                            }
                            if accumulate {
                                crow[i] += acc;
                            } else {
                                crow[i] = acc;
                            }
                        }
                        debug_assert_eq!(e, m * per_row);
                    }
                });
            }
        });
    }

    /// Allocating convenience wrapper over [`NmMat::matmul_a_bt_into`].
    pub fn matmul_a_bt(&self, a: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, self.rows);
        self.matmul_a_bt_into(a, &mut c, false);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_a_bt;
    use crate::util::prng::Xoshiro256;

    /// Top-`keep` |w| per aligned group — a by-construction n:m mask.
    fn nm_mask(w: &Mat, keep: usize, block: usize) -> Mat {
        let mut mask = Mat::zeros(w.rows, w.cols);
        for i in 0..w.rows {
            for g in 0..w.cols / block {
                let base = g * block;
                let mut idx: Vec<usize> = (0..block).collect();
                idx.sort_by(|&a, &b| {
                    w.at(i, base + b)
                        .abs()
                        .partial_cmp(&w.at(i, base + a).abs())
                        .unwrap()
                });
                for &o in idx.iter().take(keep) {
                    *mask.at_mut(i, base + o) = 1.0;
                }
            }
        }
        mask
    }

    #[test]
    fn dense_equivalence_2_4() {
        let mut rng = Xoshiro256::new(11);
        let w = Mat::gaussian(24, 32, 1.0, &mut rng);
        let mask = nm_mask(&w, 2, 4);
        let nm = NmMat::from_masked(&w, &mask, 2, 4).unwrap();
        assert_eq!(nm.stored(), 24 * 8 * 2);
        assert_eq!(nm.to_dense().data, w.hadamard(&mask).data);

        let a = Mat::gaussian(9, 32, 1.0, &mut rng);
        let got = nm.matmul_a_bt(&a);
        let want = matmul_a_bt(&a, &w.hadamard(&mask));
        assert!(got.max_abs_diff(&want) < 1e-4);

        let x: Vec<f32> = (0..32).map(|_| rng.next_f32()).collect();
        let mut y = vec![0.0f32; 24];
        nm.matvec_into(&x, &mut y, false);
        let masked = w.hadamard(&mask);
        for i in 0..24 {
            let dot: f32 = masked.row(i).iter().zip(&x).map(|(a, b)| a * b).sum();
            assert!((y[i] - dot).abs() < 1e-4, "row {i}");
        }
    }

    #[test]
    fn underfull_groups_pad_balanced() {
        // row 0 keeps nothing in group 0 → padded with zeros, balance holds
        let w = Mat::ones(2, 8);
        let mut mask = nm_mask(&w, 1, 4);
        *mask.at_mut(0, 0) = 0.0;
        let m0: usize = (0..4).map(|j| (mask.at(0, j) != 0.0) as usize).sum();
        assert_eq!(m0, 0);
        let nm = NmMat::from_masked(&w, &mask, 1, 4).unwrap();
        assert_eq!(nm.stored(), 2 * 2); // still exactly keep per group
        assert_eq!(nm.to_dense().data, w.hadamard(&mask).data);
    }

    #[test]
    fn rejects_invariant_violation() {
        let w = Mat::ones(2, 8);
        let mask = Mat::ones(2, 8); // 4 kept in every group of 4
        let err = NmMat::from_masked(&w, &mask, 2, 4).unwrap_err();
        assert!(err.to_string().contains("violates 2:4"), "{err}");
        assert!(NmMat::from_masked(&w, &mask, 1, 5).is_err()); // 5 ∤ 8
        assert!(NmMat::from_masked(&w, &mask, 8, 8).is_err()); // keep == block
    }

    #[test]
    fn detect_finds_structure() {
        let mut rng = Xoshiro256::new(13);
        let w = Mat::gaussian(8, 16, 1.0, &mut rng);
        let mask = nm_mask(&w, 2, 4);
        assert_eq!(NmMat::detect(&mask, 0.55), Some((2, 4)));
        // unstructured 50% mask: some group of 4 holds 3+ survivors,
        // packed density blows past the cap
        let unst = Mat::from_fn(8, 16, |i, j| f32::from((i * 7 + j * 3) % 16 < 8));
        assert_eq!(NmMat::detect(&unst, 0.55), None);
        assert_eq!(NmMat::detect(&Mat::zeros(4, 16), 0.55), None);
    }

    #[test]
    fn kept_zero_stays_addressable() {
        let mut w = Mat::ones(1, 4);
        *w.at_mut(0, 1) = 0.0;
        let mut mask = Mat::zeros(1, 4);
        *mask.at_mut(0, 1) = 1.0;
        *mask.at_mut(0, 3) = 1.0;
        let nm = NmMat::from_masked(&w, &mask, 2, 4).unwrap();
        assert_eq!(nm.stored(), 2);
        assert_eq!(nm.to_dense().data, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn size_beats_csr_at_same_pattern() {
        let mut rng = Xoshiro256::new(17);
        let w = Mat::gaussian(32, 64, 1.0, &mut rng);
        let mask = nm_mask(&w, 1, 4);
        let nm = NmMat::from_masked(&w, &mask, 1, 4).unwrap();
        let csr = crate::tensor::sparse::CsrMat::from_masked(&w, &mask);
        assert!(nm.size_bytes() < csr.size_bytes());
    }
}
