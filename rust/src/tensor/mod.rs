//! Dense f32 matrix substrate.
//!
//! Everything the coordinator computes natively (gram accumulation,
//! baseline pruners, the native FW backend, the transformer forward)
//! runs on [`Mat`]: a row-major, heap-backed f32 matrix with a blocked,
//! multi-threaded matmul (see `matmul.rs`) and the small amount of
//! linear algebra SparseGPT needs (`linalg.rs`).

pub mod gather;
pub mod linalg;
pub mod matmul;
pub mod nm;
pub mod sparse;
pub mod topk;

pub use matmul::{matmul, matmul_at_b, matmul_a_bt};

use crate::util::prng::Xoshiro256;

/// Row-major dense f32 matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl std::fmt::Debug for Mat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn ones(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![1.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    /// i.i.d. N(0, std²) entries (deterministic from `rng`).
    pub fn gaussian(rows: usize, cols: usize, std: f32, rng: &mut Xoshiro256) -> Self {
        let data = (0..rows * cols)
            .map(|_| rng.next_gaussian() as f32 * std)
            .collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn numel(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Elementwise product (Hadamard).
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a * b)
            .collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    pub fn hadamard_inplace(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn add_inplace(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// self ← a·self + b·other (the FW convex-combination update).
    pub fn axby(&mut self, a: f32, b: f32, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x = a * *x + b * y;
        }
    }

    pub fn frob_sq(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// ℓ₁ distance to another matrix (threshold-residual metric, Fig 4R).
    pub fn l1_dist(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum()
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    /// Max |a−b| against another matrix (test helper).
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let a = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(a.at(1, 2), 5.0);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), 5.0);
        assert_eq!(t.transpose().data, a.data);

        let h = a.hadamard(&a);
        assert_eq!(h.at(1, 2), 25.0);
        assert_eq!(a.frob_sq(), (0..6).map(|x| (x * x) as f64).sum::<f64>());
    }

    #[test]
    fn axby_is_convex_update() {
        let mut m = Mat::ones(2, 2);
        let v = Mat::from_vec(2, 2, vec![0.0, 2.0, 4.0, 6.0]);
        m.axby(0.5, 0.5, &v); // (1-eta)m + eta v with eta=0.5
        assert_eq!(m.data, vec![0.5, 1.5, 2.5, 3.5]);
    }

    #[test]
    fn l1_dist_and_nnz() {
        let a = Mat::from_vec(1, 4, vec![0.0, 1.0, 0.0, -2.0]);
        let b = Mat::zeros(1, 4);
        assert_eq!(a.l1_dist(&b), 3.0);
        assert_eq!(a.count_nonzero(), 2);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        let _ = Mat::from_vec(2, 2, vec![0.0; 3]);
    }
}
