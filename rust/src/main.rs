//! `sparsefw` — CLI launcher for the pruning coordinator.
//!
//! Subcommands:
//!   inspect                      — summarize the artifacts workspace
//!   methods                      — list the open method registry
//!   prune    [--model --method --pattern|--owl --backend --refine …]
//!            [--spec job.json --save-spec job.json]
//!   eval     [--model --masks file --sparse --sparse-format]
//!   generate                     — sample tokens from a compiled sparse model
//!   selfcheck                    — PJRT vs native numerical cross-check
//!   analyze                      — project-invariant static analysis (lints)
//!   trace                        — render FW convergence certificates
//!   serve    [--addr --workers --queue-cap --calib-cache --compiled-cache
//!             --demo --trace-out]
//!   submit / status / shutdown   — client side of a running server
//!   report-table1 / report-table2 / report-fig2 / report-fig3 / report-fig4
//!
//! `prune` lowers its flags into a declarative [`JobSpec`] (replayable
//! via `--spec job.json`) and executes it through a [`PruneSession`];
//! method flags parse through the global method registry (`--method
//! NAME` for any registered method, `--refine` for composable
//! post-passes); `serve` runs the same jobs behind a multi-client HTTP
//! JSON API with a priority queue and per-worker session memoization.
//!
//! Common flags: --artifacts DIR (default ./artifacts or
//! $SPARSEFW_ARTIFACTS), --models a,b, --iters N, --samples N, --fast.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use sparsefw::calib::CalibPolicy;
use sparsefw::config::cli::{parse_method, parse_pattern, parse_refine, Args};
use sparsefw::config::{self, Backend, Workspace};
use sparsefw::coordinator::job::DEFAULT_CALIB_CACHE_CAP;
use sparsefw::coordinator::{Allocation, EvalSpec, EvalSummary, JobSpec, PruneSession};
use sparsefw::model::safetensors::{self, TensorData};
use sparsefw::prelude::*;
use sparsefw::report::{figs, tables, ReportCtx};
use sparsefw::server;
use sparsefw::util::json::Json;
use sparsefw::{info, runtime};

const USAGE: &str = "\
sparsefw — pruning LLMs via Frank-Wolfe (paper reproduction)

USAGE: sparsefw <subcommand> [flags]

  inspect                         summarize artifacts + models
  methods    [--addr HOST:PORT]   list the method registry (local, or a
                                  running server's via GET /methods)
  prune      --model M --method NAME  (any registered method; built-ins:
             sparsefw|wanda|ria|magnitude|sparsegpt)
             [--method-json '{\"kind\": …}'  arbitrary method config]
             --pattern {unstructured:S|per-row:S|K:B} | --owl TARGET
             [--iters N --alpha A --warmstart wanda|ria|magnitude]
             [--fw-engine incremental|dense] [--fw-refresh N]
             [--samples N --seed S --backend native|pjrt|pjrt-chunk]
             [--propagate off|block|layer]
             [--refine swaps|update|swaps,update]
             [--spec job.json] [--save-spec job.json]
             [--out masks.safetensors] [--eval]
             [--trace-every N] [--trace-out trace.ndjson]
             [--result-out result.json]
             [--journal DIR] [--job-timeout SECS]
  eval       --model M [--masks masks.safetensors] [--pjrt] [--demo]
             [--sparse [--sparse-format auto|dense|csr|nm]]
                                  --sparse compiles the masked model into
                                  packed sparse formats and cross-checks
                                  logits + perplexity vs the masked dense
  generate   [--model M | --demo] [--masks masks.safetensors]
             [--prompt T1,T2,…] [--max-new N] [--temperature T]
             [--seed S] [--sparse-format auto|dense|csr|nm]
                                  KV-cached decode from the compiled
                                  model (temperature <= 0 is greedy)
  selfcheck                       cross-check PJRT kernels vs native math
  analyze    [--src DIR] [--deny-warnings]
                                  run the project lints over the source
                                  tree (default DIR: src)
  trace      --from result.json [--gap-threshold G]
             --job ID --addr HOST:PORT
                                  per-layer FW convergence certificate
                                  tables (gap decay; layers whose final
                                  duality gap exceeds G are flagged)
  serve      [--addr HOST:PORT] [--workers N] [--queue-cap N]
             [--calib-cache N] [--compiled-cache N] [--conn-threads N]
             [--history-cap N] [--demo] [--trace-out trace.ndjson]
             [--journal DIR] [--job-timeout SECS]
             [--auth-token TOKEN] [--coordinator]
             [--fleet-timeout-secs S]
  serve      --worker --coordinator-addr HOST:PORT [--label NAME]
             [--poll-ms MS] [--demo] [--auth-token TOKEN]
                                  join a coordinator's fleet: no
                                  listener, pulls shards over HTTP
  resume     --journal DIR [--demo] [--job-timeout SECS]
                                  finish interrupted prune runs from
                                  their on-disk checkpoints
  submit     <prune flags…> --addr HOST:PORT [--priority N]
             [--wait] [--stream] [--corr-id ID] [--token TOKEN]
  status     --addr HOST:PORT [--job ID]
  shutdown   --addr HOST:PORT [--drain]
  report-table1 | report-table2 | report-fig2 | report-fig3 | report-fig4
             [--models a,b --iters N --samples N --fast]

Jobs are declarative: `prune` lowers its flags into a JobSpec
(--save-spec writes it as JSON, --spec replays one from disk with any
explicitly-passed flags overriding the file), executed by a
PruneSession that caches models and calibration grams across jobs.
--owl switches from a uniform pattern to OWL-style non-uniform
per-layer sparsities (works on every backend).

--fw-engine picks the native SparseFW hot loop: `incremental` (the
default) maintains P_t = (W(.)M_t)G across iterations — each FW step
only mixes in a k-sparse vertex V, so P updates as
(1-eta)P + eta(W(.)V)G, an O(nnz) sparse gather instead of the dense
O(d_out*d_in^2) matmul — with row-block intra-layer parallelism and a
periodic exact refresh every --fw-refresh iterations to bound f32
drift.  `dense` is the reference per-iteration matmul, kept one flag
away for A/B runs (BENCH_fw.json tracks both).

--propagate selects the calibration pipeline.  `off` (default) is the
paper's protocol: one forward over the dense model, all 4*n_layers
grams held at once — O(model) calibration memory.  `block` and `layer`
run the staged block-sequential pipeline instead: grams stream one
block at a time from the hiddens of the pruned-so-far model, so
compounding error is priced into every layer's objective and peak
calibration memory is O(block):

    embed --> [ grams(b) -> prune block b -> re-forward masked b ] --> b+1
              `block`: the 4 layers prune in parallel off shared grams
              `layer`: strictly sequential; wo/wdown grams recomputed
                       after wqkv/wup are pruned

--propagate off is bit-identical to the pre-staged pipeline
(regression-tested), and saved specs without a calib_policy field
replay on it unchanged.

Methods are open: every method is a LayerPruner trait impl registered
in the MethodRegistry, which drives --method parsing, JobSpec JSON,
server-side validation (unknown methods are a 400 naming the known
set), and the `methods` listing — implement the trait, register it,
and the whole CLI/JSON/server surface picks it up with zero parser
changes (the crate docs carry an end-to-end "adding a pruning method"
walkthrough).  --refine appends composable
post-passes to any method: `swaps` (SparseSwaps-style greedy 1-swap
mask refinement, never raising the layer objective) and `update`
(least-squares masked weight update); job summaries then report the
aggregate improvement as refine_obj_delta.

`analyze` is the project's own static-analysis pass (CI runs it with
--deny-warnings).  It tokenizes the source tree with the in-crate
lexer and enforces the invariants the std-only server stack depends
on.  Lint catalog:

    lock-order            two locks acquired in inconsistent order
                          anywhere in the tree (incl. re-entrant
                          self-cycles on std::Mutex)
    lock-across-blocking  a guard held across blocking I/O, a Condvar
                          wait on a different lock, or a progress
                          callback
    panic-path            unwrap()/expect()/panic!-family macros in
                          request-serving code (server/)
    unchecked-index       x[i] indexing in request-serving code
    registry-coverage     a registered method missing from the registry
                          test, the table1_methods bench, or this USAGE
    metrics-coverage      a metric in the server's METRIC_CATALOG
                          missing from this USAGE's metric catalog
    route-coverage        a route in the server's API dispatch missing
                          from this USAGE's endpoint table
    codec-fields          a to_json/from_json pair whose key sets differ
    stale-allow           an allow annotation that suppresses nothing
    unbounded-retry       a retry loop with neither an attempt cap nor
                          a deadline (can spin forever on a fault that
                          never clears)

False positives are silenced in place, on the offending line or the
line directly above it, and every suppression must name its reason:

    // analyze: allow(<lint>, \"<reason>\")

A marker comment `// analyze: request-path` opts any file into the
panic-path lints (fixtures use this).  Allows that stop matching are
themselves reported (stale-allow), so suppressions can't outlive the
code they excused.  To add a lint: implement a check in
src/analyze/, name it in kebab-case, and add a violating +
allow-annotated fixture pair under tests/analyze_fixtures/ (see the
module docs in src/analyze/mod.rs).

`serve` runs a long-lived job server over the workspace: POST /jobs
takes a JobSpec, workers execute jobs off a bounded priority queue
with per-worker model + calibration memoization, GET /jobs/:id (and
the chunked /jobs/:id/events stream) reports per-layer progress, and
GET /metrics exposes queue depth / cache hits / worker utilization.
`submit` sends the same flags `prune` takes to a server (--wait polls
to completion, --stream follows live progress); port 0 in --addr
picks an ephemeral port (printed as `listening on …`).  --demo serves
a randomly-initialized tiny model without an artifacts workspace.

SERVING PRUNED MODELS

A pruned model is more than masks: the sparse inference fast path
packs each pruned linear into the cheapest format its mask supports
and runs the forward pass on the packed data, never materializing the
masked dense weights.  Formats:

    dense   W⊙M, plain matmul       masks too dense to pay for
                                    indirection (density > 0.4)
    csr     row-ptr + col-idx + val unstructured / per-row masks
    nm      interleaved n:m groups  n kept values per m-column group,
            (values + offset nibbles)  balanced rows, no row pointers

--sparse-format auto (the default everywhere) picks per layer: n:m
when the mask satisfies a uniform n:m invariant (m in {4,8,16}), dense
above the density crossover, CSR otherwise.  `eval --sparse` proves
the compiled model faithful (logit max|Δ| vs the masked dense model,
plus both perplexities); `generate` runs the KV-cached decode loop on
it; benches/sparse_infer.rs A/Bs dense vs csr vs nm on prefill and
decode shapes (BENCH_infer.json in CI).

A serving server compiles each completed job's result once
(worker-side, before the job flips to done) into an LRU cache
(--compiled-cache N models, default 4), then answers inference
requests from the cache.  Endpoint table:

    POST   /jobs                   submit a JobSpec
    GET    /jobs                   list jobs (?after=ID&limit=N pages)
    GET    /jobs/:id               status + progress + result summary
    GET    /jobs/:id/events        chunked NDJSON live progress
    GET    /jobs/:id/trace         trace spans for the job's corr ID
    POST   /jobs/:id/eval          perplexity of the compiled model
                                   (body {\"max_seqs\": N}, optional)
    POST   /jobs/:id/generate      sample from the compiled model
                                   (body {\"prompt\": [...], \"max_new\",
                                   \"temperature\", \"seed\"})
    DELETE /jobs/:id               cancel a queued job
    GET    /methods                the method registry
    GET    /healthz                liveness + build info
    GET    /metrics                metrics (JSON / ?format=prometheus)
    POST   /shutdown               graceful shutdown (?drain=1)
    GET    /spec                   machine-readable API description
                                   (routes + metric catalog as JSON)
    GET    /fleet                  fleet status: workers, shard table
    POST   /fleet/workers          register a fleet worker (body
                                   {\"label\": …}; returns worker id)
    POST   /fleet/workers/:id/poll heartbeat + lease the next ready
                                   shard (body {\"busy\": bool})
    POST   /fleet/shards/:id/result  report a shard's pruned layers
                                   (or failure) back to the coordinator

The route-coverage lint keeps this table in sync with the server's
actual dispatch (src/server/api.rs); GET /spec serves the same table
as JSON, generated from the same parsed source.

Auth.  `serve --auth-token TOKEN` (or the SPARSEFW_AUTH_TOKEN env var)
requires `Authorization: Bearer TOKEN` on every mutating route (POST /
DELETE); reads stay open.  Requests with a missing or wrong token get
401 + WWW-Authenticate.  The client side sends the token via
`submit/status/shutdown --token TOKEN` (or the same env var), and
fleet workers pass it with `serve --worker --auth-token`.

DURABILITY & FAILURE HANDLING

Journal + checkpoints.  `--journal DIR` (on `serve` and `prune`) makes
runs crash-safe.  The server appends every accepted submission and
every terminal transition to DIR/jobs.ndjson before acknowledging it;
on restart the journal replays and every job that was Queued or
Running when the process died (kill -9 included) is re-queued with its
original id, priority, and correlation ID.  Separately, workers write
one checkpoint artifact per completed unit — per block under
--propagate block|layer, per layer for one-shot dense runs — into a
per-spec subdirectory (DIR/ckpt-<spec-hash>/).  A resumed job verifies
each checkpoint (content checksum, spec hash, calibration-state entry
digest for staged runs) and restarts from the first incomplete or
unverifiable unit; anything that fails verification is recomputed, so
resume never trades correctness for speed.  Resumed masks are
bit-identical to an uninterrupted run, and job summaries report
resumed_units plus a mask_digest certificate to prove it.  Checkpoints
clear on success; `sparsefw resume --journal DIR` finishes interrupted
CLI runs.

Retries + timeouts.  Transient per-layer failures retry with
exponential backoff and full jitter (3 attempts); `--job-timeout SECS`
bounds a whole job, failing it cleanly between units with a "deadline
exceeded" error.  The client side carries connect/read/write socket
timeouts, and `submit --wait` auto-reconnects a dropped /events stream
with backoff, resuming after the last event it saw.  Queue saturation
and abusive submit rates are shed with 429 + Retry-After (the
sparsefw_jobs_shed_total counter); GET /jobs pages with
?after=ID&limit=N for large registries.

Fault injection.  SPARSEFW_FAULTS arms deterministic faults at named
sites for chaos testing (CI sweeps the full matrix).  Plans are
comma-separated site:kind[:at[:ms]] entries (kind: error|panic|delay;
`at` = fire on the at-th hit, once; `ms` = delay length) or a JSON
plan ({"seed": …, "rules": [{"site", "kind", "at", "times"}…]}, where
times=0 means every hit from `at` on).  Sites:

    io.read              checkpoint / artifact reads
    io.write.checkpoint  checkpoint writes
    gram.compute         calibration gram assembly
    fw.iter              per-layer pruning (inside the retry scope)
    worker.panic         worker thread before job execution
    net.accept           connection accept on the server
    net.mid-response     /events stream, between chunks

    SPARSEFW_FAULTS='fw.iter:error:2' sparsefw prune --model tiny …
    SPARSEFW_FAULTS='net.mid-response:error' sparsefw serve --demo

Injected faults flow through the same retry/journal machinery as real
ones: an `error` retries (then fails the job cleanly), a `panic` is
contained to the worker/connection that hit it, a `delay` exercises
timeouts.  sparsefw_faults_injected_total counts fired faults.

FLEET (DISTRIBUTED PRUNING)

The layer-wise FW objective is block-decomposable, so one job shards
at transformer-block granularity across machines.  `serve
--coordinator` accepts jobs through the normal API and, instead of
pruning locally, partitions each job into contiguous block-range
shards (LPT over per-block FLOP costs) and hands them to fleet
workers; `serve --worker --coordinator-addr HOST:PORT` joins the
fleet:

    client ── POST /jobs ──▶ coordinator (serve --coordinator)
                                │ plan shards, pull-based dispatch
                 ┌──────────────┼───────────────┐
                 ▼              ▼               ▼
             worker 0       worker 1   …   worker N-1
           (serve --worker: poll, prune shard, report)

Worker lifecycle: register (POST /fleet/workers) -> poll
(/fleet/workers/:id/poll, which doubles as the heartbeat) -> lease the
costliest ready shard -> prune it with the ordinary PruneSession path
-> report (/fleet/shards/:id/result) -> poll again.  Under --propagate
block|layer the coordinator threads the staged hand-off between
shards: shard i's exit hidden states (an O(shard)-memory EmbedPrefix,
digest-checked on both ends) become shard i+1's calibration entry, so
shard i+1 only becomes ready once i completes; dense jobs run all
shards concurrently.  A worker that misses heartbeats for
--fleet-timeout-secs (default 10) is reaped and its leased shards
requeue on live workers (bounded attempts, then the job fails
cleanly); results for stale leases are dropped so duplicated work
stays deterministic.  Assembled results are bit-identical to a
single-node run — same mask_digest for every --propagate policy — and
worker trace spans ship back with results so `trace --job` shows one
tree.  With no live workers the coordinator falls back to pruning
locally.  Shard-level transitions land in the PR 8 journal; fleet
gauges (sparsefw_fleet_*) land in /metrics.

OBSERVABILITY

Tracing.  The whole pipeline emits nested spans (calib, gram, fw,
refine, io, plus a per-job `job` span) through util::telemetry.  Sinks
are pluggable and cheap to leave off — with no sink installed a span
is one atomic load:

    SPARSEFW_TRACE=stderr          pretty-print spans as they close
    --trace-out trace.ndjson       mirror spans to NDJSON, one event
                                   per line (prune and serve)
    GET /jobs/:id/trace            the server's bounded in-memory ring,
                                   sliced per job correlation ID

Correlation IDs join the client, queue, worker, and engine: `submit`
mints one (or takes --corr-id), sends it as the X-Sparsefw-Corr-Id
header, the server stores it on the job record, and the worker
executes under it — so every span and log line for one job carries the
same ID end to end.  SPARSEFW_LOG=debug|info|warn|error sets log
verbosity; lines are stamped with the current correlation ID.

Convergence certificates.  --trace-every N records every Nth FW
iteration's objective, duality gap, step size, and refresh drift into
a per-layer ConvergenceTrace, attached to job summaries (and to
--result-out result.json).  The FW duality gap certifies convergence:
gap(M_t) >= f(M_t) - f(M*), so a small final gap is a proof of
near-optimality, not a heuristic.  `sparsefw trace` renders the
per-layer gap-decay table and flags layers whose final gap exceeds
--gap-threshold (certificate failed: raise --iters for those layers).

Metrics.  GET /metrics serves JSON; GET /metrics?format=prometheus
serves the standard text exposition.  Histograms are fixed log-scale
buckets (1ms..2min) with p50/p95/p99 in the JSON form.  Catalog:

    sparsefw_jobs_submitted_total      counter    jobs accepted
    sparsefw_jobs_done_total           counter    jobs succeeded
    sparsefw_jobs_failed_total         counter    jobs errored/panicked
    sparsefw_jobs_propagated_total     counter    staged-calibration jobs
    sparsefw_jobs_replayed_total       counter    jobs re-queued from the
                                                  journal at startup
    sparsefw_jobs_shed_total           counter    submissions shed with
                                                  429 (rate limit / full
                                                  queue)
    sparsefw_faults_injected_total     counter    injected faults fired
                                                  (SPARSEFW_FAULTS)
    sparsefw_calib_cache_hits_total    counter    calibration memo hits
    sparsefw_calib_cache_misses_total  counter    calibration memo misses
    sparsefw_fw_iters_total            counter    FW iterations executed
    sparsefw_workers                   gauge      pruning worker threads
    sparsefw_busy_workers              gauge      workers mid-job
    sparsefw_queue_depth               gauge      queued jobs
    sparsefw_uptime_seconds            gauge      seconds since bind
    sparsefw_peak_gram_bytes           gauge      staged-gram high-water
    sparsefw_models_compiled_total     counter    serving models compiled
                                                  at job completion
    sparsefw_compiled_cache_hits_total counter    compiled-model cache hits
    sparsefw_compiled_cache_misses_total counter  compiled-model cache
                                                  misses
    sparsefw_compiled_cache_models     gauge      compiled models resident
    sparsefw_queue_wait_seconds        histogram  submit -> start
    sparsefw_job_wall_seconds          histogram  per-job wall time
    sparsefw_phase_calib_seconds       histogram  calibration spans
    sparsefw_phase_gram_seconds        histogram  gram assembly spans
    sparsefw_phase_fw_seconds          histogram  per-layer FW spans
    sparsefw_phase_refine_seconds      histogram  refine spans
    sparsefw_phase_io_seconds          histogram  result/eval spans
    sparsefw_eval_request_seconds      histogram  POST /jobs/:id/eval
    sparsefw_generate_request_seconds  histogram  POST /jobs/:id/generate
    sparsefw_fleet_workers_registered_total counter  fleet workers ever
                                                  registered
    sparsefw_fleet_workers_live        gauge      fleet workers within
                                                  the heartbeat window
    sparsefw_fleet_shards_dispatched_total counter  shard leases handed
                                                  to fleet workers
    sparsefw_fleet_shards_requeued_total counter  shards requeued after
                                                  worker loss / defects
    sparsefw_fleet_handoff_bytes_total counter    staged hidden-state
                                                  hand-off bytes shipped

The catalog lives in server::METRIC_CATALOG; the metrics-coverage lint
keeps this table and that list in sync.

Examples:

    sparsefw prune --model tiny --method sparsefw --trace-every 10 \\
        --result-out r.json && sparsefw trace --from r.json
    sparsefw serve --demo --trace-out /tmp/sfw.ndjson
    sparsefw submit --model demo --addr HOST:PORT --wait \\
        --trace-every 10 && sparsefw trace --job 1 --addr HOST:PORT
    curl HOST:PORT/metrics?format=prometheus

Flags everywhere: --artifacts DIR (default $SPARSEFW_ARTIFACTS or ./artifacts)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn open_ws(args: &Args) -> Result<Workspace> {
    match args.get("artifacts") {
        Some(dir) => Workspace::open(dir),
        None => Workspace::open_default(),
    }
}

fn open_session(args: &Args) -> Result<PruneSession> {
    Ok(PruneSession::new(open_ws(args)?))
}

/// `--demo` swaps the artifacts workspace for the in-memory demo model
/// (same model `serve --demo` uses) — prune/eval/generate all honour it.
fn open_session_or_demo(args: &Args) -> Result<PruneSession> {
    if args.has("demo") {
        server::demo_sessions(1)
            .into_iter()
            .next()
            .context("building the demo session")
    } else {
        open_session(args)
    }
}

/// Default model name: the demo session only knows "demo".
fn default_model(args: &Args) -> &'static str {
    if args.has("demo") {
        "demo"
    } else {
        "tiny"
    }
}

fn run(args: &Args) -> Result<()> {
    // SPARSEFW_TRACE=stderr installs the pretty-printing span sink
    sparsefw::util::telemetry::install_from_env();
    // SPARSEFW_FAULTS arms the deterministic fault-injection plan
    sparsefw::util::fault::install_from_env()?;
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("inspect") => inspect(args),
        Some("methods") => methods_cmd(args),
        Some("prune") => prune(args),
        Some("eval") => eval_cmd(args),
        Some("generate") => generate_cmd(args),
        Some("selfcheck") => selfcheck(args),
        Some("analyze") => analyze_cmd(args),
        Some("trace") => trace_cmd(args),
        Some("serve") => serve(args),
        Some("resume") => resume(args),
        Some("submit") => submit(args),
        Some("status") => status_cmd(args),
        Some("shutdown") => shutdown_cmd(args),
        Some(report) if report.starts_with("report-") => report_cmd(args, report),
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn inspect(args: &Args) -> Result<()> {
    let ws = open_ws(args)?;
    println!("workspace: {:?}", ws.dir);
    println!("seq_len={} vocab={}", ws.manifest.seq_len(), ws.manifest.vocab());
    for name in ws.manifest.model_names() {
        let model = ws.load_model(&name)?;
        println!(
            "model {name}: d_model={} layers={} heads={} d_ff={} params={} dense_ppl={:?}",
            model.cfg.d_model,
            model.cfg.n_layers,
            model.cfg.n_heads,
            model.cfg.d_ff,
            model.n_params(),
            ws.manifest.dense_test_ppl(&name),
        );
        for l in model.cfg.layers().iter().take(4) {
            println!("  layer {} ({}) {}x{}", l.name, l.family, l.d_out, l.d_in);
        }
        println!("  … {} pruned linears total", model.cfg.layers().len());
    }
    Ok(())
}

/// `--eval-seqs` / `--zs-items` lowered into an [`EvalSpec`].
fn eval_spec(args: &Args) -> Result<EvalSpec> {
    Ok(EvalSpec {
        seqs: args.get_usize("eval-seqs", 64)?,
        zs_items: args.get_usize("zs-items", 60)?,
    })
}

/// Parse the `--owl` / `--pattern` flags into an [`Allocation`].
fn parse_allocation(args: &Args) -> Result<Allocation> {
    if let Some(t) = args.get("owl") {
        let target: f64 = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--owl must be a target sparsity in (0,1)"))?;
        Ok(Allocation::Owl {
            target,
            lambda: args.get_f64("owl-lambda", 5.0)?,
            max_shift: args.get_f64("owl-max-shift", 0.08)?,
        })
    } else {
        Ok(Allocation::Uniform(parse_pattern(
            args.get("pattern").unwrap_or("per-row:0.5"),
        )?))
    }
}

/// Lower CLI flags into a [`JobSpec`].  With `--spec FILE` the file is
/// the base and explicitly-passed flags override its fields (a flag
/// that is absent leaves the spec untouched).
fn build_spec(args: &Args) -> Result<JobSpec> {
    if let Some(path) = args.get("spec") {
        let mut spec = JobSpec::load(Path::new(path))?;
        if let Some(model) = args.get("model") {
            spec.model = model.to_string();
        }
        if args.get("method").is_some() || args.get("method-json").is_some() {
            spec.method = parse_method(args)?;
        } else if (args.get("fw-engine").is_some() || args.get("fw-refresh").is_some())
            && spec.method.name() == "sparsefw"
        {
            // engine flags override a loaded spec even without --method:
            // round-trip the method through its JSON form with the
            // overridden fields (the registry re-validates)
            let mut mj = config::method_to_json(&spec.method);
            let refresh = args.get_usize(
                "fw-refresh",
                mj.at(&["refresh_every"]).as_usize().unwrap_or(0),
            )?;
            if let Json::Obj(obj) = &mut mj {
                if let Some(e) = args.get("fw-engine") {
                    obj.insert("engine".to_string(), Json::Str(e.to_string()));
                }
                if args.get("fw-refresh").is_some() {
                    obj.insert("refresh_every".to_string(), Json::Num(refresh as f64));
                }
            }
            spec.method = config::method_from_json(&mj)?;
        }
        if args.get("refine").is_some() {
            spec.refine = parse_refine(args)?;
        }
        if args.get("owl").is_some() || args.get("pattern").is_some() {
            spec.allocation = parse_allocation(args)?;
        }
        if let Some(b) = args.get("backend") {
            spec.backend = Backend::parse(b)?;
        }
        if args.get("samples").is_some() {
            spec.calib_samples = args.get_usize("samples", spec.calib_samples)?;
        }
        if args.get("seed").is_some() {
            spec.calib_seed = args.get_u64("seed", spec.calib_seed)?;
        }
        if let Some(p) = args.get("propagate") {
            spec.calib_policy = CalibPolicy::parse(p)?;
        }
        if args.get("trace-every").is_some() {
            spec.trace_every = args.get_usize("trace-every", spec.trace_every)?;
        }
        if args.has("eval") && spec.eval.is_none() {
            spec.eval = Some(EvalSpec::default());
        }
        if let Some(e) = spec.eval.as_mut() {
            if args.get("eval-seqs").is_some() {
                e.seqs = args.get_usize("eval-seqs", e.seqs)?;
            }
            if args.get("zs-items").is_some() {
                e.zs_items = args.get_usize("zs-items", e.zs_items)?;
            }
        }
        return Ok(spec);
    }
    Ok(JobSpec {
        model: args.get("model").unwrap_or("tiny").to_string(),
        method: parse_method(args)?,
        allocation: parse_allocation(args)?,
        backend: Backend::parse(args.get("backend").unwrap_or("native"))?,
        calib_samples: args.get_usize("samples", 128)?,
        calib_seed: args.get_u64("seed", 7)?,
        calib_policy: CalibPolicy::parse(args.get("propagate").unwrap_or("off"))?,
        trace_every: args.get_usize("trace-every", 0)?,
        refine: parse_refine(args)?,
        eval: if args.has("eval") { Some(eval_spec(args)?) } else { None },
    })
}

/// `sparsefw methods [--addr HOST:PORT]` — list the method registry:
/// locally (the registry compiled into this binary), or a running
/// server's via `GET /methods`.
fn methods_cmd(args: &Args) -> Result<()> {
    let listing = if args.get("addr").is_some() {
        let client = client_from(args);
        println!("methods registered at {}:", client.addr());
        client.methods()?
    } else {
        println!("methods registered in this binary:");
        sparsefw::server::api::methods_json()
    };
    for m in listing.at(&["methods"]).as_arr().unwrap_or(&[]) {
        let caps = m.at(&["caps"]);
        println!(
            "  {:<10} reconstructs_weights={} supports_pjrt={} iterative={}",
            m.at(&["name"]).as_str().unwrap_or("?"),
            caps.at(&["reconstructs_weights"]).as_bool().unwrap_or(false),
            caps.at(&["supports_pjrt"]).as_bool().unwrap_or(false),
            caps.at(&["iterative"]).as_bool().unwrap_or(false),
        );
        println!(
            "             default: {}",
            sparsefw::util::json::to_string(m.at(&["default_config"]))
        );
    }
    Ok(())
}

/// Shared result printing for `prune --eval` and the `eval` subcommand.
fn print_eval(model_name: &str, ev: &EvalSummary, sparsity: Option<f64>) {
    let zs = &ev.zero_shot;
    println!(
        "{model_name}: ppl={:.3} zero-shot={:.2}% (cloze {:.1}%, copy {:.1}%, bigram {:.1}%){}",
        ev.ppl,
        zs.mean() * 100.0,
        zs.cloze * 100.0,
        zs.copy_detect * 100.0,
        zs.bigram * 100.0,
        sparsity
            .map(|s| format!("  [sparsity {s:.3}]"))
            .unwrap_or_default(),
    );
}

fn prune(args: &Args) -> Result<()> {
    use sparsefw::util::telemetry::{self, NdjsonSink, TraceSink};
    let mut session = open_session_or_demo(args)?;
    let mut spec = build_spec(args)?;
    if args.has("demo") && args.get("model").is_none() {
        spec.model = default_model(args).to_string();
    }
    if let Some(path) = args.get("save-spec") {
        spec.save(Path::new(path))?;
        info!("job spec written to {path}");
    }

    // one corr ID per CLI run, so --trace-out / SPARSEFW_TRACE output
    // from this process joins with any server-side lines
    let _corr = telemetry::with_correlation(&telemetry::gen_corr_id());
    let trace_sink: Option<std::sync::Arc<dyn TraceSink>> = match args.get("trace-out") {
        Some(path) => {
            let s = NdjsonSink::create(Path::new(path))
                .with_context(|| format!("opening --trace-out {path}"))?;
            let s: std::sync::Arc<dyn TraceSink> = std::sync::Arc::new(s);
            telemetry::add_sink(s.clone());
            Some(s)
        }
        None => None,
    };

    // durability: with --journal DIR every completed unit (block, or
    // layer for one-shot runs) checkpoints under DIR; an interrupted
    // run finishes via `sparsefw resume --journal DIR`
    if let Some(dir) = args.get("journal") {
        session.set_checkpoint_root(Path::new(dir));
    }
    session.set_job_timeout(args.get_f64_opt("job-timeout")?);

    info!("executing job: {}", spec.label());
    session.on_progress(|e| {
        info!("  [{}/{}] {} pruned (err {:.4e})", e.index + 1, e.total, e.layer, e.obj);
    });
    let result = session.execute(&spec)?;

    info!(
        "pruned {} layers in {:.1}s; Σ layer error = {:.4e}{}{}",
        result.masks().len(),
        result.wall_seconds(),
        result.total_err(),
        result
            .mean_rel_reduction()
            .map(|r| format!(", mean reduction vs warmstart = {:.1}%", r * 100.0))
            .unwrap_or_default(),
        result
            .prune
            .refine_obj_delta
            .map(|d| format!(", refine Δobj = {d:.4e}"))
            .unwrap_or_default()
    );

    if let Some(out) = args.get("out") {
        let tensors: BTreeMap<String, TensorData> = result
            .masks()
            .iter()
            .map(|(k, m)| {
                (
                    k.clone(),
                    TensorData { shape: vec![m.rows, m.cols], data: m.data.clone() },
                )
            })
            .collect();
        safetensors::save(Path::new(out), &tensors)?;
        info!("masks written to {out}");
    }

    if let Some(path) = args.get("result-out") {
        // the same summary JSON a server job record carries — so
        // `sparsefw trace --from FILE` reads both interchangeably
        let summary = server::JobSummary::from_result(&result);
        std::fs::write(path, sparsefw::util::json::to_string(&summary.to_json()))
            .with_context(|| format!("writing --result-out {path}"))?;
        info!("job summary written to {path}");
    }

    if let Some(ev) = &result.eval {
        print_eval(&spec.model, ev, result.pruned_sparsity);
    }
    if let Some(s) = trace_sink {
        telemetry::remove_sink(&s);
    }
    Ok(())
}

/// Load `--masks FILE` as mask matrices (empty map without the flag).
fn load_masks(args: &Args) -> Result<BTreeMap<String, Mat>> {
    match args.get("masks") {
        Some(mask_file) => safetensors::load(Path::new(mask_file))?
            .into_iter()
            .map(|(k, t)| Ok((k, t.to_mat()?)))
            .collect::<Result<_>>(),
        None => Ok(BTreeMap::new()),
    }
}

fn eval_cmd(args: &Args) -> Result<()> {
    let mut session = open_session_or_demo(args)?;
    let model_name = args.get("model").unwrap_or(default_model(args)).to_string();
    let mut model = session.model(&model_name)?.clone();

    let masks = load_masks(args)?;
    if !masks.is_empty() {
        model = model.apply_masks(&masks)?;
        info!("applied masks; sparsity = {:.3}", model.pruned_sparsity());
    }

    if args.has("sparse") {
        return eval_sparse(args, &mut session, &model_name, &model, &masks);
    }

    let espec = eval_spec(args)?;
    let summary = if args.has("pjrt") {
        session.evaluate_pjrt(&model, &model_name, &espec)?
    } else {
        session.evaluate(&model, &espec)?
    };
    print_eval(&model_name, &summary, None);
    Ok(())
}

/// `eval --sparse` — compile the masked model into packed sparse
/// formats and cross-check it against the masked dense model: logit
/// max-abs-diff on a few held-out sequences, then both perplexities.
/// Exits non-zero if the compiled forward drifts past tolerance, so CI
/// can lean on it as an end-to-end equivalence gate.
fn eval_sparse(
    args: &Args,
    session: &mut PruneSession,
    model_name: &str,
    masked: &Gpt,
    masks: &BTreeMap<String, Mat>,
) -> Result<()> {
    use sparsefw::eval::perplexity_native;
    use sparsefw::model::compiled::{CompiledModel, SparseFormat, DEFAULT_CROSSOVER};
    use sparsefw::model::forward::forward;

    const LOGIT_TOL: f32 = 1e-3;

    let format = SparseFormat::parse(args.get("sparse-format").unwrap_or("auto"))?;
    let compiled = {
        let base = session.model(model_name)?;
        CompiledModel::compile(base, masks, &BTreeMap::new(), format, DEFAULT_CROSSOVER)?
    };
    println!("{model_name} [--sparse-format {}]: {}", format.label(), compiled.summary());

    let espec = eval_spec(args)?;
    let bin = session.test_bin()?;
    let seqs = bin.sequential(masked.cfg.seq_len, 4);
    anyhow::ensure!(!seqs.is_empty(), "test bin shorter than one sequence");
    let mut max_diff = 0.0f32;
    for s in &seqs {
        let dense_out = forward(masked, s, false);
        let sparse_out = forward(&compiled, s, false);
        max_diff = max_diff.max(dense_out.logits.max_abs_diff(&sparse_out.logits));
    }
    println!("logit max|Δ| vs masked dense = {max_diff:.3e} over {} seq(s)", seqs.len());
    anyhow::ensure!(
        max_diff < LOGIT_TOL,
        "compiled forward drifted from the masked dense model: \
         logit max|Δ| = {max_diff:.3e} (tolerance {LOGIT_TOL:.0e})"
    );

    let dense_ppl = perplexity_native(masked, bin, espec.seqs)?;
    let sparse_ppl = perplexity_native(&compiled, bin, espec.seqs)?;
    println!(
        "ppl masked-dense={dense_ppl:.3} compiled={sparse_ppl:.3} (rel diff {:.2e})",
        (dense_ppl - sparse_ppl).abs() / dense_ppl.max(1e-12),
    );
    Ok(())
}

/// `sparsefw generate` — compile the (optionally masked) model and run
/// the KV-cached decode loop.  Deterministic for a fixed seed: the
/// `tokens:` line is stable across runs, which the CI smoke lane
/// asserts.
fn generate_cmd(args: &Args) -> Result<()> {
    use sparsefw::model::compiled::{
        CompiledModel, GenerateParams, SparseFormat, DEFAULT_CROSSOVER,
    };

    let mut session = open_session_or_demo(args)?;
    let model_name = args.get("model").unwrap_or(default_model(args)).to_string();
    let masks = load_masks(args)?;
    let format = SparseFormat::parse(args.get("sparse-format").unwrap_or("auto"))?;
    let compiled = {
        let base = session.model(&model_name)?;
        CompiledModel::compile(base, &masks, &BTreeMap::new(), format, DEFAULT_CROSSOVER)?
    };
    info!("compiled {model_name}: {}", compiled.summary());

    let prompt: Vec<u8> = match args.get("prompt") {
        Some(s) => s
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<u8>()
                    .context("--prompt must be comma-separated token ids (0-255)")
            })
            .collect::<Result<_>>()?,
        None => vec![1, 2, 3],
    };
    let params = GenerateParams {
        max_new: args.get_usize("max-new", 16)?,
        temperature: args.get_f64("temperature", 0.0)?,
        seed: args.get_u64("seed", 7)?,
    };

    let started = std::time::Instant::now();
    let generated = compiled.generate(&prompt, &params)?;
    let wall_ms = started.elapsed().as_secs_f64() * 1e3;
    let rendered: Vec<String> = generated.tokens.iter().map(|t| t.to_string()).collect();
    println!("tokens: {}", rendered.join(" "));
    println!(
        "generated {} token(s) from a {}-token prompt in {wall_ms:.1} ms ({:.3} ms/token)",
        generated.tokens.len() - generated.prompt_len,
        generated.prompt_len,
        wall_ms / generated.decode_steps.max(1) as f64,
    );
    Ok(())
}

/// Run the pruning job server (blocks until `POST /shutdown` or
/// `sparsefw shutdown`).
/// `--auth-token TOKEN` with the SPARSEFW_AUTH_TOKEN env var as the
/// fallback (empty env values count as unset).
fn auth_token(args: &Args) -> Option<String> {
    args.get("auth-token")
        .map(String::from)
        .or_else(|| std::env::var("SPARSEFW_AUTH_TOKEN").ok())
        .filter(|t| !t.is_empty())
}

fn serve(args: &Args) -> Result<()> {
    if args.has("worker") {
        // Fleet worker mode: no listener of our own — join a
        // coordinator's fleet and pull shards until killed.
        let coordinator = args
            .get("coordinator-addr")
            .context("serve --worker needs --coordinator-addr HOST:PORT")?;
        let mut opts = server::fleet::WorkerOptions::new(
            coordinator,
            args.get("label").unwrap_or("worker"),
        );
        opts.token = auth_token(args);
        opts.poll_ms = args.get_u64("poll-ms", 100)?;
        let session = if args.has("demo") {
            info!("fleet worker on the --demo in-memory model");
            server::demo_sessions(1)
                .into_iter()
                .next()
                .context("building the demo session")?
        } else {
            server::workspace_sessions(args.get("artifacts"), 1)?
                .into_iter()
                .next()
                .context("building the workspace session")?
        };
        return server::fleet::run_worker(&opts, session);
    }
    let cfg = ServerConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7878").to_string(),
        workers: args.get_usize("workers", 2)?.max(1),
        queue_capacity: args.get_usize("queue-cap", 256)?,
        calib_cache_cap: args.get_usize("calib-cache", DEFAULT_CALIB_CACHE_CAP)?,
        compiled_cache_cap: args
            .get_usize("compiled-cache", server::DEFAULT_COMPILED_CACHE_CAP)?,
        conn_threads: args.get_usize("conn-threads", 8)?,
        job_history_cap: args.get_usize("history-cap", 1024)?,
        trace_out: args.get("trace-out").map(String::from),
        journal: args.get("journal").map(String::from),
        job_timeout_secs: args.get_f64_opt("job-timeout")?,
        auth_token: auth_token(args),
        coordinator: args.has("coordinator"),
        fleet_timeout_secs: args.get_f64("fleet-timeout-secs", 10.0)?,
    };
    let sessions = if args.has("demo") {
        info!("serving the --demo in-memory model (no artifacts workspace)");
        server::demo_sessions(cfg.workers)
    } else {
        server::workspace_sessions(args.get("artifacts"), cfg.workers)?
    };
    let handle = Server::bind(&cfg, sessions)?;
    // scripts parse this line to learn the ephemeral port
    println!("listening on {}", handle.addr());
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    handle.join();
    info!("server stopped");
    Ok(())
}

/// `sparsefw resume --journal DIR` — finish interrupted CLI prune runs.
/// Every spec checkpointed under DIR re-executes with its checkpoint
/// store attached: verified completed units restore instead of
/// recomputing, and only the remaining units run.  Masks are
/// bit-identical to an uninterrupted run.  `--demo` resumes runs made
/// against the in-memory demo model (e.g. from a killed `serve --demo
/// --journal DIR`).
fn resume(args: &Args) -> Result<()> {
    let root = args
        .get("journal")
        .context("resume needs --journal DIR (the directory the interrupted run used)")?
        .to_string();
    let root_path = Path::new(&root);
    let saved = server::journal::saved_specs(root_path)?;
    if saved.is_empty() {
        println!("no checkpointed runs under {root}");
        return Ok(());
    }
    let mut session = if args.has("demo") {
        server::demo_sessions(1)
            .into_iter()
            .next()
            .context("building the demo session")?
    } else {
        open_session(args)?
    };
    session.set_checkpoint_root(root_path);
    session.set_job_timeout(args.get_f64_opt("job-timeout")?);
    session.on_progress(|e| {
        info!("  [{}/{}] {} pruned (err {:.4e})", e.index + 1, e.total, e.layer, e.obj);
    });
    for (dir, spec) in saved {
        info!("resuming {} (checkpoints in {})", spec.label(), dir.display());
        let result = session.execute(&spec)?;
        let summary = server::JobSummary::from_result(&result);
        println!(
            "resumed {}: {} unit(s) restored from checkpoints, mask_digest={}, \
             Σ layer error = {:.4e}",
            spec.label(),
            result.prune.resumed_units,
            summary.mask_digest,
            summary.total_err,
        );
    }
    Ok(())
}

fn client_from(args: &Args) -> server::Client {
    let client = server::Client::new(args.get("addr").unwrap_or("127.0.0.1:7878"));
    match args
        .get("token")
        .map(String::from)
        .or_else(|| std::env::var("SPARSEFW_AUTH_TOKEN").ok())
        .filter(|t| !t.is_empty())
    {
        Some(token) => client.with_token(token),
        None => client,
    }
}

/// One line per job the server reports.
fn print_job_line(v: &Json) {
    let id = v.at(&["id"]).as_usize().unwrap_or(0);
    let state = v.at(&["state"]).as_str().unwrap_or("?");
    let completed = v.at(&["progress", "completed"]).as_usize().unwrap_or(0);
    let total = v.at(&["progress", "total"]).as_usize().unwrap_or(0);
    let mut line = format!("job {id}: state={state} progress={completed}/{total}");
    if let Some(r) = v.get("result") {
        line.push_str(&format!(
            " mask_layers={} mask_nnz={} total_err={:.4e} wall_seconds={:.2}",
            r.at(&["mask_layers"]).as_usize().unwrap_or(0),
            r.at(&["mask_nnz"]).as_usize().unwrap_or(0),
            r.at(&["total_err"]).as_f64().unwrap_or(0.0),
            r.at(&["wall_seconds"]).as_f64().unwrap_or(0.0),
        ));
        if let Some(d) = r.at(&["mask_digest"]).as_str() {
            line.push_str(&format!(" mask_digest={d}"));
        }
        if let Some(red) = r.at(&["mean_rel_reduction"]).as_f64() {
            line.push_str(&format!(" mean_rel_reduction={:.1}%", red * 100.0));
        }
        if let Some(d) = r.at(&["refine_obj_delta"]).as_f64() {
            line.push_str(&format!(" refine_obj_delta={d:.4e}"));
        }
        if let Some(ppl) = r.at(&["ppl"]).as_f64() {
            line.push_str(&format!(" ppl={ppl:.3}"));
        }
    }
    if let Some(e) = v.at(&["error"]).as_str() {
        line.push_str(&format!(" error={e:?}"));
    }
    println!("{line}");
}

/// Submit a job (same flags as `prune`) to a running server.
fn submit(args: &Args) -> Result<()> {
    let spec = build_spec(args)?;
    // tag the job with a correlation ID so client and server telemetry
    // join; the server mints one anyway, but a client-supplied ID is
    // the one the operator already has in their own logs
    let corr = args
        .get("corr-id")
        .map(String::from)
        .unwrap_or_else(sparsefw::util::telemetry::gen_corr_id);
    let client = client_from(args).with_corr_id(corr.clone());
    let priority = args.get_f64("priority", 0.0)? as i64;
    let id = client.submit(&spec, priority)?;
    info!("job {id} submitted to {} ({}) [corr {corr}]", client.addr(), spec.label());
    if args.has("stream") {
        client.stream(id, |e| {
            info!(
                "  [{}/{}] {} pruned (err {:.4e})",
                e.at(&["index"]).as_usize().unwrap_or(0) + 1,
                e.at(&["total"]).as_usize().unwrap_or(0),
                e.at(&["layer"]).as_str().unwrap_or("?"),
                e.at(&["obj"]).as_f64().unwrap_or(0.0),
            );
        })?;
        // the stream trailer has no progress object; re-fetch the record
        print_job_line(&client.job(id)?);
    } else if args.has("wait") {
        let timeout = std::time::Duration::from_secs(args.get_u64("timeout-secs", 600)?);
        print_job_line(&client.wait(id, timeout)?);
    } else {
        println!("job {id} submitted");
    }
    Ok(())
}

/// Show one job (`--job ID`) or the full server picture.
fn status_cmd(args: &Args) -> Result<()> {
    let client = client_from(args);
    if let Some(id) = args.get("job") {
        let id: u64 = id.parse().context("--job must be an integer id")?;
        print_job_line(&client.job(id)?);
        return Ok(());
    }
    let listing = client.jobs()?;
    let jobs = listing.at(&["jobs"]).as_arr().unwrap_or(&[]).to_vec();
    println!("{} job(s), queue depth {}", jobs.len(),
        listing.at(&["queue_depth"]).as_usize().unwrap_or(0));
    for j in &jobs {
        println!(
            "  job {}: {} [prio {}] {}",
            j.at(&["id"]).as_usize().unwrap_or(0),
            j.at(&["state"]).as_str().unwrap_or("?"),
            j.at(&["priority"]).as_f64().unwrap_or(0.0),
            j.at(&["label"]).as_str().unwrap_or(""),
        );
    }
    let m = client.metrics()?;
    println!(
        "served={} queued={} calib hits/misses={}/{} workers busy={}/{}",
        m.at(&["jobs_served"]).as_usize().unwrap_or(0),
        m.at(&["queue_depth"]).as_usize().unwrap_or(0),
        m.at(&["calib_cache", "hits"]).as_usize().unwrap_or(0),
        m.at(&["calib_cache", "misses"]).as_usize().unwrap_or(0),
        m.at(&["workers", "busy"]).as_usize().unwrap_or(0),
        m.at(&["workers", "total"]).as_usize().unwrap_or(0),
    );
    Ok(())
}

fn shutdown_cmd(args: &Args) -> Result<()> {
    let client = client_from(args);
    client.shutdown(args.has("drain"))?;
    println!("shutdown requested at {}", client.addr());
    Ok(())
}

/// Cross-check the PJRT (AOT Pallas) kernels against the native math on
/// real model layers — the fastest way to verify artifacts are sane.
fn selfcheck(args: &Args) -> Result<()> {
    use sparsefw::pruner::fw_math;
    let ws = open_ws(args)?;
    let rt = ws.runtime()?;
    let model_name = ws
        .manifest
        .model_names()
        .first()
        .context("no models in manifest")?
        .clone();
    let model = ws.load_model(&model_name)?;
    let calib = Calibration::collect(&model, &ws.train_bin()?, 8, 3)?;

    let mut worst = 0.0f32;
    for l in model.cfg.layers().iter().take(4) {
        let w = model.mat(&l.name);
        let g = calib.gram(&l.name);
        let h = fw_math::precompute_h(w, g);
        let mut m = Mat::ones(l.d_out, l.d_in);
        for (i, v) in m.data.iter_mut().enumerate() {
            *v = ((i * 2654435761) % 1000) as f32 / 1000.0;
        }
        let g_native = fw_math::fw_grad(w, &m, g, &h);
        let g_pjrt = rt.fw_grad(w, &m, g, &h)?;
        let scale = g_native.abs_max().max(1.0);
        let diff = g_native.max_abs_diff(&g_pjrt) / scale;
        worst = worst.max(diff);
        let obj_native = fw_math::objective(w, &m, g);
        let obj_pjrt = rt.objective(w, &m, g)?;
        let obj_diff = ((obj_native - obj_pjrt).abs() / (1.0 + obj_native.abs())) as f32;
        worst = worst.max(obj_diff);
        println!(
            "layer {:<16} grad rel-diff {:.2e}, objective rel-diff {:.2e}",
            l.name, diff, obj_diff
        );
    }
    anyhow::ensure!(worst < 1e-3, "PJRT/native mismatch: {worst}");
    println!("selfcheck OK (worst rel-diff {worst:.2e})");
    Ok(())
}

fn analyze_cmd(args: &Args) -> Result<()> {
    use sparsefw::analyze::{analyze_tree, AnalyzeConfig};
    let src = args.get("src").unwrap_or("src");
    anyhow::ensure!(
        Path::new(src).is_dir(),
        "--src {src:?} is not a directory (run from rust/, or pass --src path/to/src)"
    );
    let findings = analyze_tree(&AnalyzeConfig::new(src))?;
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("analyze: clean");
    } else if args.has("deny-warnings") {
        bail!("analyze: {} warning(s) (--deny-warnings)", findings.len());
    } else {
        println!("analyze: {} warning(s)", findings.len());
    }
    Ok(())
}

/// `sparsefw trace` — render per-layer FW convergence certificates
/// from a `--result-out` summary file (`--from result.json`) or a
/// server job (`--job ID --addr HOST:PORT`).
///
/// The duality gap is a certificate: gap(M_t) ≥ f(M_t) − f(M*), so the
/// final recorded gap upper-bounds how far each layer's relaxed mask is
/// from the constrained optimum.  Layers whose final gap exceeds
/// `--gap-threshold` are flagged.
fn trace_cmd(args: &Args) -> Result<()> {
    use sparsefw::pruner::ConvergenceTrace;
    let threshold = args.get_f64("gap-threshold", 1e-3)?;
    let payload: Json = if let Some(path) = args.get("from") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --from {path}"))?;
        sparsefw::util::json::parse(&text).with_context(|| format!("parsing {path}"))?
    } else if let Some(id) = args.get("job") {
        let id: u64 = id.parse().context("--job must be an integer id")?;
        let client = client_from(args);
        // span roll-up first: where did the job's wall time go?
        if let Ok(tr) = client.trace(id) {
            let events = tr.at(&["events"]).as_arr().unwrap_or(&[]).to_vec();
            let mut phases: BTreeMap<String, (usize, f64)> = BTreeMap::new();
            for e in &events {
                let name = e.at(&["name"]).as_str().unwrap_or("?").to_string();
                let secs = e.at(&["dur_us"]).as_f64().unwrap_or(0.0) / 1e6;
                let entry = phases.entry(name).or_insert((0, 0.0));
                entry.0 += 1;
                entry.1 += secs;
            }
            println!(
                "job {id}: {} trace span(s) [corr {}]",
                events.len(),
                tr.at(&["corr_id"]).as_str().unwrap_or("?"),
            );
            for (name, (n, secs)) in &phases {
                println!("  {name:<8} x{n:<4} {secs:8.3}s total");
            }
        }
        client.job(id)?
    } else {
        bail!("trace needs --from result.json or --job ID --addr HOST:PORT");
    };

    // "convergence" sits at the top level in a --result-out summary and
    // under "result" in a GET /jobs/:id record
    let conv = if payload.get("convergence").is_some() {
        payload.at(&["convergence"])
    } else {
        payload.at(&["result", "convergence"])
    };
    let Json::Obj(layers) = conv else {
        bail!(
            "no convergence traces in the input — rerun the job with \
             --trace-every N (N > 0) to record certificates"
        );
    };

    println!(
        "{:<20} {:>5} {:>12} {:>12} {:>12} {:>8}  cert",
        "layer", "pts", "gap[first]", "gap[last]", "objective", "decay"
    );
    let mut flagged = Vec::new();
    for (name, cj) in layers {
        let cv = ConvergenceTrace::from_json(cj);
        let first = cv.gap.first().copied().unwrap_or(0.0);
        let last = cv.final_gap().unwrap_or(0.0);
        let obj = cv.objective.last().copied().unwrap_or(0.0);
        let decay = if first.abs() > 0.0 { last / first } else { 0.0 };
        let ok = last <= threshold;
        if !ok {
            flagged.push(name.clone());
        }
        println!(
            "{name:<20} {:>5} {first:>12.4e} {last:>12.4e} {obj:>12.4e} {decay:>8.1e}  {}",
            cv.len(),
            if ok { "ok" } else { "FLAG" },
        );
    }
    if flagged.is_empty() {
        println!("all {} layer(s) certified (final gap <= {threshold:e})", layers.len());
    } else {
        println!(
            "{}/{} layer(s) exceed --gap-threshold {threshold:e}: {} — raise --iters \
             or loosen the pattern for these layers",
            flagged.len(),
            layers.len(),
            flagged.join(", ")
        );
    }
    Ok(())
}

fn report_cmd(args: &Args, which: &str) -> Result<()> {
    let ws = open_ws(args)?;
    let mut ctx = ReportCtx::new(ws, args.get_list("models"))?;
    if args.has("fast") {
        ctx.fast();
    }
    if let Some(n) = args.get("iters") {
        ctx.iters = n.parse()?;
    }
    if let Some(n) = args.get("samples") {
        ctx.calib_samples = n.parse()?;
    }
    if let Some(n) = args.get("eval-seqs") {
        ctx.eval_seqs = n.parse()?;
    }
    match which {
        "report-table1" => {
            tables::table1(&mut ctx)?;
        }
        "report-table2" => {
            tables::table2(&mut ctx)?;
        }
        "report-fig2" => {
            figs::fig2(&mut ctx)?;
        }
        "report-fig3" => {
            let axis = args.get("axis").unwrap_or("both");
            if axis == "iters" || axis == "both" {
                let grid = if args.has("fast") {
                    vec![0, 10, 40]
                } else {
                    vec![0, 10, 50, 100, 250, 500, 1000, 2000]
                };
                figs::fig3_iters(&mut ctx, &grid)?;
            }
            if axis == "samples" || axis == "both" {
                let grid = if args.has("fast") {
                    vec![8, 16]
                } else {
                    vec![16, 32, 64, 128, 256, 512]
                };
                figs::fig3_samples(&mut ctx, &grid)?;
            }
        }
        "report-fig4" => {
            figs::fig4(&mut ctx)?;
        }
        other => bail!("unknown report {other:?}"),
    }
    Ok(())
}

// keep the runtime module linked even in minimal builds
#[allow(unused_imports)]
use runtime as _runtime_linked;

#[allow(dead_code)]
fn _assert_json_api(v: &Json) -> bool {
    v.is_null()
}
