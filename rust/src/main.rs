//! `sparsefw` — CLI launcher for the pruning coordinator.
//!
//! Subcommands:
//!   inspect                      — summarize the artifacts workspace
//!   prune    [--model --method --pattern --backend …]
//!   eval     [--model --masks file]
//!   selfcheck                    — PJRT vs native numerical cross-check
//!   report-table1 / report-table2 / report-fig2 / report-fig3 / report-fig4
//!
//! Common flags: --artifacts DIR (default ./artifacts or
//! $SPARSEFW_ARTIFACTS), --models a,b, --iters N, --samples N, --fast.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use sparsefw::config::cli::{parse_method, parse_pattern, Args};
use sparsefw::config::{Backend, Workspace};
use sparsefw::coordinator::PrunePipeline;
use sparsefw::eval::{perplexity_native, perplexity_pjrt, zero_shot};
use sparsefw::model::safetensors::{self, TensorData};
use sparsefw::prelude::*;
use sparsefw::report::{figs, tables, ReportCtx};
use sparsefw::util::json::Json;
use sparsefw::{info, runtime};

const USAGE: &str = "\
sparsefw — pruning LLMs via Frank-Wolfe (paper reproduction)

USAGE: sparsefw <subcommand> [flags]

  inspect                         summarize artifacts + models
  prune      --model M --method {sparsefw|wanda|ria|magnitude|sparsegpt}
             --pattern {unstructured:S|per-row:S|K:B}
             [--iters N --alpha A --warmstart wanda|ria|magnitude]
             [--samples N --seed S --backend native|pjrt|pjrt-chunk]
             [--out masks.safetensors] [--eval]
  eval       --model M [--masks masks.safetensors]
  selfcheck                       cross-check PJRT kernels vs native math
  report-table1 | report-table2 | report-fig2 | report-fig3 | report-fig4
             [--models a,b --iters N --samples N --fast]

Flags everywhere: --artifacts DIR (default $SPARSEFW_ARTIFACTS or ./artifacts)
";

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn open_ws(args: &Args) -> Result<Workspace> {
    match args.get("artifacts") {
        Some(dir) => Workspace::open(dir),
        None => Workspace::open_default(),
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        None | Some("help") => {
            println!("{USAGE}");
            Ok(())
        }
        Some("inspect") => inspect(args),
        Some("prune") => prune(args),
        Some("eval") => eval_cmd(args),
        Some("selfcheck") => selfcheck(args),
        Some(report) if report.starts_with("report-") => report_cmd(args, report),
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
}

fn inspect(args: &Args) -> Result<()> {
    let ws = open_ws(args)?;
    println!("workspace: {:?}", ws.dir);
    println!("seq_len={} vocab={}", ws.manifest.seq_len(), ws.manifest.vocab());
    for name in ws.manifest.model_names() {
        let model = ws.load_model(&name)?;
        println!(
            "model {name}: d_model={} layers={} heads={} d_ff={} params={} dense_ppl={:?}",
            model.cfg.d_model,
            model.cfg.n_layers,
            model.cfg.n_heads,
            model.cfg.d_ff,
            model.n_params(),
            ws.manifest.dense_test_ppl(&name),
        );
        for l in model.cfg.layers().iter().take(4) {
            println!("  layer {} ({}) {}x{}", l.name, l.family, l.d_out, l.d_in);
        }
        println!("  … {} pruned linears total", model.cfg.layers().len());
    }
    Ok(())
}

fn prune(args: &Args) -> Result<()> {
    let ws = open_ws(args)?;
    let model_name = args.get("model").unwrap_or("tiny").to_string();
    let method = parse_method(args)?;
    let pattern = parse_pattern(args.get("pattern").unwrap_or("per-row:0.5"))?;
    let samples = args.get_usize("samples", 128)?;
    let seed = args.get_u64("seed", 7)?;
    let backend = Backend::parse(args.get("backend").unwrap_or("native"))?;

    let model = ws.load_model(&model_name)?;
    info!(
        "pruning {model_name} with {} to {} ({} backend, {} calib samples)",
        method.label(),
        pattern.label(),
        backend.label(),
        samples
    );
    let calib = Calibration::collect(&model, &ws.train_bin()?, samples, seed)?;
    let pipe = PrunePipeline::new(&model, &calib);

    let rt;
    let result = match backend {
        Backend::Native => pipe.run(&method, &pattern)?,
        _ => {
            rt = ws.runtime()?;
            pipe.run_with_backend(backend, Some(&rt), &method, &pattern)?
        }
    };

    let total_err: f64 = result.layer_objs.values().sum();
    info!(
        "pruned {} layers in {:.1}s; Σ layer error = {:.4e}{}",
        result.masks.len(),
        result.wall_seconds,
        total_err,
        result
            .mean_rel_reduction()
            .map(|r| format!(", mean reduction vs warmstart = {:.1}%", r * 100.0))
            .unwrap_or_default()
    );

    if let Some(out) = args.get("out") {
        let tensors: BTreeMap<String, TensorData> = result
            .masks
            .iter()
            .map(|(k, m)| {
                (
                    k.clone(),
                    TensorData { shape: vec![m.rows, m.cols], data: m.data.clone() },
                )
            })
            .collect();
        safetensors::save(std::path::Path::new(out), &tensors)?;
        info!("masks written to {out}");
    }

    if args.has("eval") {
        let pruned = result.apply(&model)?;
        let ppl = perplexity_native(&pruned, &ws.test_bin()?, args.get_usize("eval-seqs", 64)?)?;
        let zs = zero_shot(&pruned, 0xE7A1, args.get_usize("zs-items", 60)?)?;
        println!(
            "pruned model: ppl={ppl:.3} zero-shot={:.2}% (cloze {:.1}%, copy {:.1}%, bigram {:.1}%)",
            zs.mean() * 100.0,
            zs.cloze * 100.0,
            zs.copy_detect * 100.0,
            zs.bigram * 100.0
        );
    }
    Ok(())
}

fn eval_cmd(args: &Args) -> Result<()> {
    let ws = open_ws(args)?;
    let model_name = args.get("model").unwrap_or("tiny").to_string();
    let mut model = ws.load_model(&model_name)?;

    if let Some(mask_file) = args.get("masks") {
        let tensors = safetensors::load(std::path::Path::new(mask_file))?;
        let masks: BTreeMap<String, Mat> = tensors
            .into_iter()
            .map(|(k, t)| Ok((k, t.to_mat()?)))
            .collect::<Result<_>>()?;
        model = model.apply_masks(&masks)?;
        info!("applied {mask_file}; sparsity = {:.3}", model.pruned_sparsity());
    }

    let test = ws.test_bin()?;
    let n = args.get_usize("eval-seqs", 64)?;
    let ppl = if args.has("pjrt") {
        let rt = ws.runtime()?;
        perplexity_pjrt(&rt, &model, &model_name, &test, n)?
    } else {
        perplexity_native(&model, &test, n)?
    };
    let zs = zero_shot(&model, 0xE7A1, args.get_usize("zs-items", 60)?)?;
    println!(
        "{model_name}: ppl={ppl:.3} zero-shot={:.2}% (cloze {:.1}%, copy {:.1}%, bigram {:.1}%)",
        zs.mean() * 100.0,
        zs.cloze * 100.0,
        zs.copy_detect * 100.0,
        zs.bigram * 100.0
    );
    Ok(())
}

/// Cross-check the PJRT (AOT Pallas) kernels against the native math on
/// real model layers — the fastest way to verify artifacts are sane.
fn selfcheck(args: &Args) -> Result<()> {
    use sparsefw::pruner::fw_math;
    let ws = open_ws(args)?;
    let rt = ws.runtime()?;
    let model_name = ws
        .manifest
        .model_names()
        .first()
        .context("no models in manifest")?
        .clone();
    let model = ws.load_model(&model_name)?;
    let calib = Calibration::collect(&model, &ws.train_bin()?, 8, 3)?;

    let mut worst = 0.0f32;
    for l in model.cfg.layers().iter().take(4) {
        let w = model.mat(&l.name);
        let g = calib.gram(&l.name);
        let h = fw_math::precompute_h(w, g);
        let mut m = Mat::ones(l.d_out, l.d_in);
        for (i, v) in m.data.iter_mut().enumerate() {
            *v = ((i * 2654435761) % 1000) as f32 / 1000.0;
        }
        let g_native = fw_math::fw_grad(w, &m, g, &h);
        let g_pjrt = rt.fw_grad(w, &m, g, &h)?;
        let scale = g_native.abs_max().max(1.0);
        let diff = g_native.max_abs_diff(&g_pjrt) / scale;
        worst = worst.max(diff);
        let obj_native = fw_math::objective(w, &m, g);
        let obj_pjrt = rt.objective(w, &m, g)?;
        let obj_diff = ((obj_native - obj_pjrt).abs() / (1.0 + obj_native.abs())) as f32;
        worst = worst.max(obj_diff);
        println!(
            "layer {:<16} grad rel-diff {:.2e}, objective rel-diff {:.2e}",
            l.name, diff, obj_diff
        );
    }
    anyhow::ensure!(worst < 1e-3, "PJRT/native mismatch: {worst}");
    println!("selfcheck OK (worst rel-diff {worst:.2e})");
    Ok(())
}

fn report_cmd(args: &Args, which: &str) -> Result<()> {
    let ws = open_ws(args)?;
    let mut ctx = ReportCtx::new(ws, args.get_list("models"))?;
    if args.has("fast") {
        ctx.fast();
    }
    if let Some(n) = args.get("iters") {
        ctx.iters = n.parse()?;
    }
    if let Some(n) = args.get("samples") {
        ctx.calib_samples = n.parse()?;
    }
    if let Some(n) = args.get("eval-seqs") {
        ctx.eval_seqs = n.parse()?;
    }
    match which {
        "report-table1" => {
            tables::table1(&mut ctx)?;
        }
        "report-table2" => {
            tables::table2(&mut ctx)?;
        }
        "report-fig2" => {
            figs::fig2(&mut ctx)?;
        }
        "report-fig3" => {
            let axis = args.get("axis").unwrap_or("both");
            if axis == "iters" || axis == "both" {
                let grid = if args.has("fast") {
                    vec![0, 10, 40]
                } else {
                    vec![0, 10, 50, 100, 250, 500, 1000, 2000]
                };
                figs::fig3_iters(&mut ctx, &grid)?;
            }
            if axis == "samples" || axis == "both" {
                let grid = if args.has("fast") {
                    vec![8, 16]
                } else {
                    vec![16, 32, 64, 128, 256, 512]
                };
                figs::fig3_samples(&mut ctx, &grid)?;
            }
        }
        "report-fig4" => {
            figs::fig4(&mut ctx)?;
        }
        other => bail!("unknown report {other:?}"),
    }
    Ok(())
}

// keep the runtime module linked even in minimal builds
#[allow(unused_imports)]
use runtime as _runtime_linked;

#[allow(dead_code)]
fn _assert_json_api(v: &Json) -> bool {
    v.is_null()
}
